"""Pallas kernel validation: interpret-mode kernel vs pure-jnp oracle,
sweeping shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import paged_attention
from repro.kernels.ragged_attention import ragged_segment_attention
from repro.kernels.ref import paged_attention_ref, ragged_segment_attention_ref


def _make_case(key, b, kv, g, hd, bs, nb_per_seq, n_pool, dtype):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, kv, g, hd), dtype)
    k_pool = jax.random.normal(ks[1], (n_pool, bs, kv, hd), dtype)
    v_pool = jax.random.normal(ks[2], (n_pool, bs, kv, hd), dtype)
    # unique block ids per sequence (like a real allocator would hand out)
    perm = jax.random.permutation(ks[3], n_pool)[: b * nb_per_seq]
    block_tables = perm.reshape(b, nb_per_seq).astype(jnp.int32)
    max_ctx = bs * nb_per_seq
    context_lens = jax.random.randint(ks[4], (b,), 1, max_ctx + 1).astype(jnp.int32)
    return q, k_pool, v_pool, block_tables, context_lens


SHAPES = [
    # b, kv, g, hd, bs, nb_per_seq, n_pool
    (2, 2, 4, 64, 8, 3, 16),
    (1, 1, 8, 128, 16, 2, 8),
    (3, 4, 2, 64, 4, 5, 64),
    (2, 8, 1, 32, 8, 4, 64),   # MQA-ish: G=1
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_oracle(shape, dtype):
    b, kv, g, hd, bs, nb, n_pool = shape
    args = _make_case(jax.random.PRNGKey(42), b, kv, g, hd, bs, nb, n_pool, dtype)
    out_kernel = paged_attention(*args, interpret=True)
    out_ref = paged_attention_ref(*args)
    assert out_kernel.shape == out_ref.shape == (b, kv, g, hd)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_kernel, np.float32),
                               np.asarray(out_ref, np.float32), rtol=tol, atol=tol)


def test_paged_attention_single_token_context():
    """context_len=1: attends to exactly the first token."""
    b, kv, g, hd, bs = 1, 1, 2, 64, 8
    q, k_pool, v_pool, bt, _ = _make_case(jax.random.PRNGKey(0), b, kv, g, hd, bs, 2, 8, jnp.float32)
    cl = jnp.array([1], jnp.int32)
    out = paged_attention(q, k_pool, v_pool, bt, cl, interpret=True)
    expect = jnp.broadcast_to(k_pool[bt[0, 0], 0][None, :, None], (b, kv, g, hd)) * 0 \
        + v_pool[bt[0, 0], 0].transpose(0, 1)[None, :, None, :]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)


def test_paged_attention_ignores_garbage_beyond_context():
    """Pages past context_len must not affect the output."""
    b, kv, g, hd, bs, nb = 1, 2, 2, 64, 8, 4
    q, k_pool, v_pool, bt, _ = _make_case(jax.random.PRNGKey(7), b, kv, g, hd, bs, nb, 32, jnp.float32)
    cl = jnp.array([11], jnp.int32)  # 1.375 pages valid
    out1 = paged_attention(q, k_pool, v_pool, bt, cl, interpret=True)
    # poison everything beyond page 2
    k2 = k_pool.at[bt[0, 2]].set(1e4)
    v2 = v_pool.at[bt[0, 3]].set(-1e4)
    out2 = paged_attention(q, k2, v2, bt, cl, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# =============================================================================
# native ragged segment-attention kernel
# =============================================================================


def _make_ragged_case(key, seg_specs, kv, g, hd, bs, nb, n_pool, dtype):
    """Segments of (length, n_cached): each segment's queries sit at
    absolute positions [n_cached, n_cached + length) of its own sequence
    — mid-block boundaries whenever n_cached % bs != 0 — tiled into a
    dense (S, L) block with padding rows where length < L."""
    ks = jax.random.split(key, 4)
    k_pool = jax.random.normal(ks[0], (n_pool, bs, kv, hd), dtype)
    v_pool = jax.random.normal(ks[1], (n_pool, bs, kv, hd), dtype)
    perm = np.asarray(jax.random.permutation(ks[2], n_pool))
    s, lmax = len(seg_specs), max(n for n, _ in seg_specs)
    tables = np.stack([perm[i * nb:(i + 1) * nb] for i in range(s)])
    positions = np.zeros((s, lmax), np.int32)
    for i, (seg_len, n_cached) in enumerate(seg_specs):
        positions[i, :seg_len] = np.arange(n_cached, n_cached + seg_len)
    q = jax.random.normal(ks[3], (s, lmax, kv, g, hd), dtype)
    return (q, k_pool, v_pool, jnp.asarray(tables, jnp.int32),
            jnp.asarray(positions, jnp.int32))


RAGGED_SWEEP = [
    # (seg_specs [(len, n_cached)...], kv, g, hd, bs, nb, n_pool)
    # uneven lengths + padding rows, chunks starting mid-block (13 % 8)
    ([(6, 0), (3, 13), (1, 20)], 2, 4, 64, 8, 4, 40),
    # n_cached > 0 everywhere: every chunk resumes a partially-written
    # last resident block
    ([(5, 3), (5, 11), (5, 19)], 1, 8, 128, 16, 2, 8),
    # chunk both starting AND ending mid-block, wide table
    ([(7, 9)], 4, 2, 64, 4, 6, 32),
    # MQA-ish single-group heads, single-token segments (decode-like)
    ([(1, 0), (1, 7), (1, 15), (1, 30)], 8, 1, 32, 8, 4, 64),
]


@pytest.mark.parametrize("case", RAGGED_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_kernel_matches_oracle_sweep(case, dtype):
    """Native kernel (interpret mode) vs the jnp oracle across shapes,
    head groupings, mid-block chunk boundaries, resumed contexts
    (n_cached > 0), uneven segment lengths, and padded tile rows —
    padding rows compare too (both paths compute position-0 attention
    for them, and they must stay NaN-free)."""
    seg_specs, kv, g, hd, bs, nb, n_pool = case
    args = _make_ragged_case(jax.random.PRNGKey(11), seg_specs,
                             kv, g, hd, bs, nb, n_pool, dtype)
    out_k = ragged_segment_attention(*args, interpret=True)
    out_r = ragged_segment_attention_ref(*args)
    assert out_k.shape == out_r.shape == args[0].shape
    assert not np.isnan(np.asarray(out_k, np.float32)).any()
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


def test_ragged_kernel_page_bounds_ignore_out_of_reach_pages():
    """A segment never visits pages past max(positions)//bs: poisoning
    the table entries beyond a segment's bound — even with garbage
    *block ids* — cannot change its output (the index map clamps to the
    bound page)."""
    seg_specs = [(4, 6), (2, 0)]           # bounds: page 1, page 0
    q, kp, vp, bt, pos = _make_ragged_case(
        jax.random.PRNGKey(3), seg_specs, 2, 2, 64, 8, 4, 40, jnp.float32)
    out = ragged_segment_attention(q, kp, vp, bt, pos, interpret=True)
    poisoned = np.array(bt)
    poisoned[0, 2:] = 39                   # unrelated garbage block
    poisoned[1, 1:] = 39
    kp2 = kp.at[39].set(1e4)
    vp2 = vp.at[39].set(-1e4)
    out2 = ragged_segment_attention(q, kp2, vp2, jnp.asarray(poisoned),
                                    pos, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-6)
