"""Pallas kernel validation: interpret-mode kernel vs pure-jnp oracle,
sweeping shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref


def _make_case(key, b, kv, g, hd, bs, nb_per_seq, n_pool, dtype):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, kv, g, hd), dtype)
    k_pool = jax.random.normal(ks[1], (n_pool, bs, kv, hd), dtype)
    v_pool = jax.random.normal(ks[2], (n_pool, bs, kv, hd), dtype)
    # unique block ids per sequence (like a real allocator would hand out)
    perm = jax.random.permutation(ks[3], n_pool)[: b * nb_per_seq]
    block_tables = perm.reshape(b, nb_per_seq).astype(jnp.int32)
    max_ctx = bs * nb_per_seq
    context_lens = jax.random.randint(ks[4], (b,), 1, max_ctx + 1).astype(jnp.int32)
    return q, k_pool, v_pool, block_tables, context_lens


SHAPES = [
    # b, kv, g, hd, bs, nb_per_seq, n_pool
    (2, 2, 4, 64, 8, 3, 16),
    (1, 1, 8, 128, 16, 2, 8),
    (3, 4, 2, 64, 4, 5, 64),
    (2, 8, 1, 32, 8, 4, 64),   # MQA-ish: G=1
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_oracle(shape, dtype):
    b, kv, g, hd, bs, nb, n_pool = shape
    args = _make_case(jax.random.PRNGKey(42), b, kv, g, hd, bs, nb, n_pool, dtype)
    out_kernel = paged_attention(*args, interpret=True)
    out_ref = paged_attention_ref(*args)
    assert out_kernel.shape == out_ref.shape == (b, kv, g, hd)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_kernel, np.float32),
                               np.asarray(out_ref, np.float32), rtol=tol, atol=tol)


def test_paged_attention_single_token_context():
    """context_len=1: attends to exactly the first token."""
    b, kv, g, hd, bs = 1, 1, 2, 64, 8
    q, k_pool, v_pool, bt, _ = _make_case(jax.random.PRNGKey(0), b, kv, g, hd, bs, 2, 8, jnp.float32)
    cl = jnp.array([1], jnp.int32)
    out = paged_attention(q, k_pool, v_pool, bt, cl, interpret=True)
    expect = jnp.broadcast_to(k_pool[bt[0, 0], 0][None, :, None], (b, kv, g, hd)) * 0 \
        + v_pool[bt[0, 0], 0].transpose(0, 1)[None, :, None, :]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)


def test_paged_attention_ignores_garbage_beyond_context():
    """Pages past context_len must not affect the output."""
    b, kv, g, hd, bs, nb = 1, 2, 2, 64, 8, 4
    q, k_pool, v_pool, bt, _ = _make_case(jax.random.PRNGKey(7), b, kv, g, hd, bs, nb, 32, jnp.float32)
    cl = jnp.array([11], jnp.int32)  # 1.375 pages valid
    out1 = paged_attention(q, k_pool, v_pool, bt, cl, interpret=True)
    # poison everything beyond page 2
    k2 = k_pool.at[bt[0, 2]].set(1e4)
    v2 = v_pool.at[bt[0, 3]].set(-1e4)
    out2 = paged_attention(q, k2, v2, bt, cl, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)
