"""Property-based tests (hypothesis): BlockManager invariants (including
ref-counting / copy-on-write block sharing), the prefix cache, and the
time-slot memory model (Eqs. 1–3)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.dispatcher import _slot_usage_matrix
from repro.core.memory_model import make_ramp
from repro.serving.kv_cache import BlockManager, NoFreeBlocks
from repro.serving.prefix_cache import PrefixCache


@settings(max_examples=60, deadline=None)
@given(
    num_blocks=st.integers(4, 64),
    block_size=st.integers(1, 32),
    ops=st.lists(
        st.tuples(st.sampled_from(["alloc", "grow", "free"]),
                  st.integers(0, 7),            # seq id
                  st.integers(1, 256)),         # token count
        max_size=40),
)
def test_block_manager_invariants(num_blocks, block_size, ops):
    bm = BlockManager(num_blocks, block_size)
    tokens = {}
    for op, seq, n in ops:
        if op == "free":
            bm.free(seq)
            tokens.pop(seq, None)
        else:
            want = tokens.get(seq, 0) + n if op == "grow" else n
            try:
                table = bm.allocate(seq, want)
            except NoFreeBlocks:
                continue
            tokens[seq] = max(tokens.get(seq, 0), want)
            assert len(table) == bm.blocks_needed(max(tokens[seq], want)) or \
                len(table) >= bm.blocks_needed(want)
        # invariant 1: conservation
        assert bm.free_blocks + bm.used_blocks == num_blocks
        # invariant 2: no block owned twice
        owned = [b for s in bm.owned_seqs() for b in bm.block_table(s)]
        assert len(owned) == len(set(owned))
        # invariant 3: free list disjoint from owned
        assert not (set(owned) & set(bm._free))
    # free everything -> all blocks returned
    for s in list(bm.owned_seqs()):
        bm.free(s)
    assert bm.free_blocks == num_blocks


def _check_sharing_invariants(bm: BlockManager):
    """Core conservation + refcount laws for the shared block manager."""
    tables = [bm.block_table(s) for s in bm.owned_seqs()]
    multiplicity = {}
    for t in tables:
        for b in t:
            multiplicity[b] = multiplicity.get(b, 0) + 1
    # refcount == number of tables referencing the block
    for b, n in multiplicity.items():
        assert bm.ref_count(b) == n
    active = set(multiplicity)
    free = set(bm._free)
    parked = set(bm._parked)
    # a referenced (shared or not) block is never free, never parked
    assert not (active & free)
    assert not (active & parked)
    assert not (free & parked)
    # conservation: free + active + cached == num_blocks
    assert len(free) + len(active) + len(parked) == bm.num_blocks
    assert bm.free_blocks + bm.active_blocks + bm.cached_blocks == bm.num_blocks


@settings(max_examples=60, deadline=None)
@given(
    num_blocks=st.integers(6, 48),
    block_size=st.integers(1, 8),
    ops=st.lists(
        st.one_of(
            st.tuples(st.just("admit"), st.integers(0, 5),   # seq id
                      st.integers(0, 3),                     # prompt family
                      st.integers(2, 64)),                   # prompt tokens
            st.tuples(st.just("free"), st.integers(0, 5)),
            st.tuples(st.just("evict"), st.integers(1, 8)),
            st.tuples(st.just("cow"), st.integers(0, 5)),
        ),
        max_size=40),
)
def test_refcount_cow_invariants(num_blocks, block_size, ops):
    """Ref-counted COW sharing through the prefix cache: a shared block is
    never freed while referenced; free + active + cached == num_blocks;
    copy-on-write always yields a privately owned block."""
    bm = BlockManager(num_blocks, block_size)
    cache = PrefixCache(block_size)
    live = {}
    for op in ops:
        if op[0] == "admit":
            _, seq, family, n_tok = op
            if seq in live:
                continue
            # same family => same token stream => shareable prefix
            tokens = (np.arange(n_tok, dtype=np.int64) + 1000 * family)
            hashes = cache.hash_tokens(tokens, block_size)
            cached = cache.match(hashes[:cache.usable_prefix_blocks(n_tok)], bm)
            need = bm.blocks_needed(n_tok + 1) - len(cached)
            if need > bm.free_blocks:
                cache.evict(bm, need - bm.free_blocks)
            if need > bm.free_blocks:
                for b in cached:
                    bm.ref_release(b)
            else:
                table = (bm.allocate_shared(seq, cached, n_tok + 1) if cached
                         else bm.allocate(seq, n_tok + 1))
                full = n_tok // block_size
                cache.insert(hashes[:full], table[:full], bm)
                live[seq] = n_tok
        elif op[0] == "free":
            bm.free(op[1])
            live.pop(op[1], None)
        elif op[0] == "evict":
            cache.evict(bm, op[1])
        elif op[0] == "cow":
            seq = op[1]
            if seq not in live:
                continue
            # block 0 is the most likely to be shared (cached prefix head)
            idx = 0
            old_b = bm.block_table(seq)[idx]
            try:
                res = bm.copy_on_write(seq, idx)
            except NoFreeBlocks:
                continue
            new_b = bm.block_table(seq)[idx]
            assert bm.ref_count(new_b) == 1
            assert not bm.is_shared(new_b)
            if res is not None:
                assert res == (old_b, new_b) and old_b != new_b
            else:
                assert new_b == old_b
        _check_sharing_invariants(bm)
    # teardown: free every sequence, evict the whole cache -> all blocks free
    for seq in list(live):
        bm.free(seq)
    cache.evict(bm, bm.num_blocks)
    assert bm.free_blocks == bm.num_blocks


@settings(max_examples=60, deadline=None)
@given(
    block_size=st.integers(1, 8),
    a=st.lists(st.integers(0, 7), min_size=1, max_size=40),
    b=st.lists(st.integers(0, 7), min_size=1, max_size=40),
)
def test_hash_chain_prefix_property(block_size, a, b):
    """hash_tokens is a radix: chains agree exactly on the shared full-block
    prefix of the two token streams."""
    ha = PrefixCache.hash_tokens(np.asarray(a), block_size)
    hb = PrefixCache.hash_tokens(np.asarray(b), block_size)
    common = 0
    while (common < min(len(a), len(b))
           and a[common] == b[common]):
        common += 1
    shared_blocks = common // block_size
    for i in range(min(len(ha), len(hb))):
        if i < shared_blocks:
            assert ha[i] == hb[i]


@settings(max_examples=60, deadline=None)
@given(
    prompt=st.integers(1, 2000),
    exec_t=st.floats(0.01, 100.0),
    speed=st.floats(0.1, 200.0),
    t0=st.floats(0.0, 50.0),
    slot_len=st.floats(0.05, 2.0),
)
def test_ramp_slot_bounds(prompt, exec_t, speed, t0, slot_len):
    """Slot usage is monotone, bounded by the ramp peak, and zero outside."""
    ramp = make_ramp(prompt, exec_t, speed, t0)
    starts = np.arange(0.0, t0 + exec_t + 3 * slot_len, slot_len)
    usage = _slot_usage_matrix([ramp], starts, slot_len)[0]
    assert np.all(usage >= 0.0)
    assert np.all(usage <= ramp.peak + 1e-6)
    # slots entirely before start or after end are zero
    before = starts + slot_len <= ramp.t_start
    after = starts >= ramp.t_end
    assert np.all(usage[before] == 0.0)
    assert np.all(usage[after] == 0.0)
    # active usage is non-decreasing (linear growth)
    active = usage[~(before | after)]
    act = active[active > 0]
    assert np.all(np.diff(act) >= -1e-9)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_slot_matrix_superposition(data):
    """Eq. 3: F_j(t) = sum_i f_i(t) — matrix rows sum linearly."""
    n = data.draw(st.integers(1, 6))
    ramps = [make_ramp(data.draw(st.integers(1, 500)),
                       data.draw(st.floats(0.1, 20.0)),
                       data.draw(st.floats(0.1, 50.0)),
                       data.draw(st.floats(0.0, 10.0))) for _ in range(n)]
    starts = np.arange(0.0, 40.0, 0.5)
    mat = _slot_usage_matrix(ramps, starts, 0.5)
    total = _slot_usage_matrix(ramps, starts, 0.5).sum(0)
    np.testing.assert_allclose(mat.sum(0), total)
    singles = sum(_slot_usage_matrix([r], starts, 0.5)[0] for r in ramps)
    np.testing.assert_allclose(total, singles, rtol=1e-9)
