"""Property-based tests (hypothesis): BlockManager invariants and the
time-slot memory model (Eqs. 1–3)."""
import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core.dispatcher import _slot_usage_matrix
from repro.core.memory_model import make_ramp
from repro.serving.kv_cache import BlockManager, NoFreeBlocks


@settings(max_examples=60, deadline=None)
@given(
    num_blocks=st.integers(4, 64),
    block_size=st.integers(1, 32),
    ops=st.lists(
        st.tuples(st.sampled_from(["alloc", "grow", "free"]),
                  st.integers(0, 7),            # seq id
                  st.integers(1, 256)),         # token count
        max_size=40),
)
def test_block_manager_invariants(num_blocks, block_size, ops):
    bm = BlockManager(num_blocks, block_size)
    tokens = {}
    for op, seq, n in ops:
        if op == "free":
            bm.free(seq)
            tokens.pop(seq, None)
        else:
            want = tokens.get(seq, 0) + n if op == "grow" else n
            try:
                table = bm.allocate(seq, want)
            except NoFreeBlocks:
                continue
            tokens[seq] = max(tokens.get(seq, 0), want)
            assert len(table) == bm.blocks_needed(max(tokens[seq], want)) or \
                len(table) >= bm.blocks_needed(want)
        # invariant 1: conservation
        assert bm.free_blocks + bm.used_blocks == num_blocks
        # invariant 2: no block owned twice
        owned = [b for s in bm.owned_seqs() for b in bm.block_table(s)]
        assert len(owned) == len(set(owned))
        # invariant 3: free list disjoint from owned
        assert not (set(owned) & set(bm._free))
    # free everything -> all blocks returned
    for s in list(bm.owned_seqs()):
        bm.free(s)
    assert bm.free_blocks == num_blocks


@settings(max_examples=60, deadline=None)
@given(
    prompt=st.integers(1, 2000),
    exec_t=st.floats(0.01, 100.0),
    speed=st.floats(0.1, 200.0),
    t0=st.floats(0.0, 50.0),
    slot_len=st.floats(0.05, 2.0),
)
def test_ramp_slot_bounds(prompt, exec_t, speed, t0, slot_len):
    """Slot usage is monotone, bounded by the ramp peak, and zero outside."""
    ramp = make_ramp(prompt, exec_t, speed, t0)
    starts = np.arange(0.0, t0 + exec_t + 3 * slot_len, slot_len)
    usage = _slot_usage_matrix([ramp], starts, slot_len)[0]
    assert np.all(usage >= 0.0)
    assert np.all(usage <= ramp.peak + 1e-6)
    # slots entirely before start or after end are zero
    before = starts + slot_len <= ramp.t_start
    after = starts >= ramp.t_end
    assert np.all(usage[before] == 0.0)
    assert np.all(usage[after] == 0.0)
    # active usage is non-decreasing (linear growth)
    active = usage[~(before | after)]
    act = active[active > 0]
    assert np.all(np.diff(act) >= -1e-9)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_slot_matrix_superposition(data):
    """Eq. 3: F_j(t) = sum_i f_i(t) — matrix rows sum linearly."""
    n = data.draw(st.integers(1, 6))
    ramps = [make_ramp(data.draw(st.integers(1, 500)),
                       data.draw(st.floats(0.1, 20.0)),
                       data.draw(st.floats(0.1, 50.0)),
                       data.draw(st.floats(0.0, 10.0))) for _ in range(n)]
    starts = np.arange(0.0, 40.0, 0.5)
    mat = _slot_usage_matrix(ramps, starts, 0.5)
    total = _slot_usage_matrix(ramps, starts, 0.5).sum(0)
    np.testing.assert_allclose(mat.sum(0), total)
    singles = sum(_slot_usage_matrix([r], starts, 0.5)[0] for r in ramps)
    np.testing.assert_allclose(total, singles, rtol=1e-9)
