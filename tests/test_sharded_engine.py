"""Tensor-parallel paged engine: mesh validation + sharded-vs-single
differential drains.

Mesh/spec validation runs on any device count.  The differential drains
need >= 4 local devices (the multi-device CI job forces 8 with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and pin:

* full traced drain (prefix cache + chunked prefill + preemption
  pressure) token-bit-identical at tp=2 and tp=4 vs the tp=1 oracle —
  fp32 model, where the engine's fp32-accumulated psums leave summation
  order as the only sharded-vs-unsharded difference,
* obs event streams and counter metrics identical between the tp=2 and
  tp=1 drains (same scheduling decisions, same token streams),
* per-shard pool buffer addresses stable across the whole drain
  (donation survives sharding: one resident sharded buffer),
* ``clone()`` shares every compiled step fn but owns a fresh pool,
* ``ServingCluster.on_mesh_slices`` places instances on disjoint
  devices and its metrics carry the ``engine{i}.`` prefixes.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh, make_slice_meshes
from repro.models import build_model

multi_device = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count)")


# =============================================================================
# mesh construction validation (any device count)
# =============================================================================


def test_make_local_mesh_rejects_bad_model_parallel():
    devs = jax.devices()[:1]
    with pytest.raises(ValueError, match="must be >= 1"):
        make_local_mesh(0, devices=devs)
    with pytest.raises(ValueError, match="does not divide"):
        make_local_mesh(2, devices=devs)
    m = make_local_mesh(1, devices=devs)
    assert m.axis_names == ("data", "model") and m.shape["model"] == 1


def test_make_slice_meshes_rejects_insufficient_devices():
    devs = jax.devices()[:1]
    with pytest.raises(ValueError, match="needs 2 devices"):
        make_slice_meshes(2, 1, devices=devs)
    with pytest.raises(ValueError, match="n_slices"):
        make_slice_meshes(0, 1, devices=devs)
    (m,) = make_slice_meshes(1, 1, devices=devs)
    assert m.shape["model"] == 1


@multi_device
def test_slice_meshes_are_disjoint():
    meshes = make_slice_meshes(2, 2, devices=jax.devices()[:4])
    sets = [set(d.id for d in m.devices.flat) for m in meshes]
    assert sets[0].isdisjoint(sets[1])
    assert all(len(s) == 2 for s in sets)


# =============================================================================
# sharded runner construction + differential drains
# =============================================================================


@pytest.fixture(scope="module")
def model_and_params():
    # reduced qwen3 widened so 4-way TP divides; fp32 for the exact
    # differential (bf16 psum reassociation can flip argmax near-ties)
    cfg = get_config("qwen3-1.7b").reduced()
    cfg = dataclasses.replace(cfg, num_heads=8, num_kv_heads=4,
                              head_dim=64, dtype="float32")
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


def _reqs(n=6, max_new=5):
    from repro.serving import Request
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, 500, 16).astype(np.int32)
    out = []
    for i in range(n):
        toks = np.concatenate(
            [prefix, rng.integers(0, 500, 5 + i).astype(np.int32)])
        out.append(Request(agent_name=f"a{i % 2}", msg_id=f"m{i}",
                           prompt_len=len(toks), prompt_tokens=toks,
                           max_new_tokens=max_new, arrival_time=float(i)))
    return out


def _drain(model_and_params, tp, num_blocks=9, tracer=None):
    """One engine, prefix cache + chunked prefill; num_blocks=9 is
    preemption pressure for this mix (asserted below).  Returns
    (sorted token streams, engine, per-shard address stability)."""
    from repro.obs.trace import NULL_TRACER
    from repro.serving import LLMEngine, PagedModelRunner, reset_request_ids
    model, params = model_and_params
    mesh = make_local_mesh(tp, devices=jax.devices()[:tp]) if tp else None
    runner = PagedModelRunner(model, params, num_blocks=num_blocks,
                              block_size=8, max_batch=4, mesh=mesh)
    eng = LLMEngine(runner, max_batch=4, enable_prefix_cache=True,
                    prefill_chunk_tokens=8,
                    tracer=tracer or NULL_TRACER)
    reset_request_ids()
    pending = _reqs()
    done = []
    addr0 = runner.pool_address()
    stable = True
    for _ in range(4000):
        if pending:
            eng.submit(pending.pop(0))
        done.extend(eng.step())
        if runner.pool_address() != addr0:
            stable = False
        if not pending and not eng.running and not eng.waiting:
            break
    assert len(done) == 6
    return (sorted((r.msg_id, tuple(int(t) for t in r.output_tokens))
                   for r in done), eng, stable)


_COUNTERS = ("n_finished", "n_admitted", "n_preempted", "prefill_tokens",
             "prefill_tokens_saved", "n_dispatches", "pool_bytes",
             "prefix_cache_hit_rate")


@multi_device
def test_sharded_drain_token_identity_events_and_metrics(model_and_params):
    from repro.obs.trace import Tracer
    tr1, tr2 = Tracer(), Tracer()
    out1, eng1, stable1 = _drain(model_and_params, None, tracer=tr1)
    out2, eng2, stable2 = _drain(model_and_params, 2, tracer=tr2)
    out4, eng4, stable4 = _drain(model_and_params, 4)

    assert out2 == out1, "tp=2 tokens must be bit-identical to tp=1"
    assert out4 == out1, "tp=4 tokens must be bit-identical to tp=1"
    assert eng1.stats.n_preempted > 0, \
        "workload must actually exercise preemption pressure"

    # identical scheduling -> identical event streams (timestamps aside)
    ev1 = [(e.kind, e.req_id, e.instance_id) for e in tr1.events()]
    ev2 = [(e.kind, e.req_id, e.instance_id) for e in tr2.events()]
    assert ev1 == ev2

    m1, m2 = eng1.metrics_snapshot(), eng2.metrics_snapshot()
    assert set(m1) == set(m2)
    for k in _COUNTERS:
        assert m1[k] == m2[k], f"counter {k}: tp1={m1[k]} tp2={m2[k]}"

    # donation survives sharding: every shard's buffer address stable
    assert stable1 and stable2 and stable4
    addr = eng2.runner.pool_address()
    assert isinstance(addr, tuple) and len(addr) == 2, \
        "sharded pool must witness one buffer address per shard"


@multi_device
def test_sharded_runner_validates_config(model_and_params):
    from repro.serving import PagedModelRunner
    mesh = make_local_mesh(4, devices=jax.devices()[:4])
    cfg = get_config("qwen3-1.7b").reduced()      # 2 kv heads: 4 won't divide
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="num_heads|num_kv_heads"):
        PagedModelRunner(model, params, num_blocks=8, block_size=8,
                         max_batch=2, mesh=mesh)


@multi_device
def test_sharded_clone_shares_fns_owns_pool(model_and_params):
    from repro.serving import PagedModelRunner
    model, params = model_and_params
    mesh = make_local_mesh(2, devices=jax.devices()[:2])
    r = PagedModelRunner(model, params, num_blocks=8, block_size=8,
                         max_batch=2, mesh=mesh)
    c = r.clone()
    assert c._fused_fn is r._fused_fn
    assert c._decode_fn is r._decode_fn
    assert c._suffix_fn is r._suffix_fn
    assert c.pool is not r.pool
    assert c.pool.sharding == r.pool.sharding
    assert c.pool_address() != r.pool_address()


@multi_device
def test_cluster_on_mesh_slices_disjoint_and_prefixed(model_and_params):
    from repro.core.orchestrator import HardwareProfile, Orchestrator
    from repro.serving import ServingCluster, reset_request_ids
    model, params = model_and_params
    orch = Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0, kv_capacity_tokens=16 * 8))
    cluster = ServingCluster.on_mesh_slices(
        model, params, orch, n_instances=2, model_parallel=2,
        devices=jax.devices()[:4],
        runner_kwargs=dict(num_blocks=16, block_size=8, max_batch=4),
        engine_kwargs=dict(max_batch=4, enable_prefix_cache=True,
                           prefill_chunk_tokens=8))
    devs = [set(d.id for d in e.runner.mesh.devices.flat)
            for e in cluster.engines]
    assert devs[0].isdisjoint(devs[1])
    reset_request_ids()
    pending = _reqs(n=8)
    done = []
    for _ in range(4000):
        if pending:
            cluster.submit(pending.pop(0))
        done.extend(cluster.step())
        if not pending and not cluster.has_work:
            break
    cluster.close()
    assert len(done) == 8
    assert {r.instance_id for r in done} == {0, 1}
    snap = cluster.metrics_snapshot()
    assert any(k.startswith("engine0.") for k in snap)
    assert any(k.startswith("engine1.") for k in snap)
