"""Unit tests for the Kairos core: distributions, workflow analysis,
MDS priority, memory model, dispatchers, schedulers."""
import numpy as np
import pytest

from repro.core import (
    ConvergenceTracker,
    EmpiricalDistribution,
    FCFSScheduler,
    InstanceModel,
    KairosScheduler,
    RoundRobinDispatcher,
    TimeSlotDispatcher,
    TopoScheduler,
    WorkflowAnalyzer,
    agent_priorities,
    classical_mds_1d,
    make_ramp,
    wasserstein_1d,
)
from repro.serving.request import CompletionRecord, Request

rng = np.random.default_rng(0)


# --------------------------------------------------------------------------- #
# distributions
# --------------------------------------------------------------------------- #
def test_wasserstein_basics():
    a = rng.normal(10, 1, 500)
    assert wasserstein_1d(a, a) < 1e-9
    b = a + 5.0
    assert abs(wasserstein_1d(a, b) - 5.0) < 0.1
    assert wasserstein_1d(a, b) == pytest.approx(wasserstein_1d(b, a))


def test_convergence_tracker_converges_on_stationary_stream():
    tr = ConvergenceTracker(threshold=0.15)
    samples = []
    for x in rng.normal(2.0, 0.3, 600):
        samples.append(float(x))
        tr.observe(samples)
    assert tr.converged


def test_convergence_tracker_not_converged_on_drift():
    tr = ConvergenceTracker(threshold=0.02)
    samples = list(rng.normal(1.0, 0.1, 64))
    tr.observe(samples)
    samples += list(rng.normal(50.0, 0.1, 64))   # drastic shift at the doubling point
    tr.observe(samples)
    assert not tr.converged


def test_mode_estimate():
    d = EmpiricalDistribution(list(rng.normal(5, 0.5, 400)) + list(rng.normal(20, 3, 50)))
    assert 3.5 < d.mode() < 6.5   # dominant mode, robust to the tail


# --------------------------------------------------------------------------- #
# workflow analysis (§4.2): parallel vs sequential fan-out via sweep-line
# --------------------------------------------------------------------------- #
def _rec(agent, msg, up, app, t0, t1, out=10):
    return CompletionRecord(agent_name=agent, msg_id=msg, upstream_name=up,
                            app_name=app, start_time=t0, end_time=t1,
                            prompt_len=16, output_len=out)


def test_parallel_fanout_detected():
    wa = WorkflowAnalyzer()
    for i in range(4):
        m = f"m{i}"
        wa.add_record(_rec("A", m, None, "app", 0, 1))
        wa.add_record(_rec("B", m, "A", "app", 1.1, 3))    # B,C overlap
        wa.add_record(_rec("C", m, "A", "app", 1.2, 2.5))
        wa.finalize_trace(m)
    g = wa.graphs["app"]
    assert g.edge_kind("A", "B") == "parallel"
    assert g.edge_kind("A", "C") == "parallel"


def test_sequential_fanout_detected():
    wa = WorkflowAnalyzer()
    for i in range(4):
        m = f"s{i}"
        wa.add_record(_rec("A", m, None, "app", 0, 1))
        wa.add_record(_rec("B", m, "A", "app", 1.1, 2.0))  # disjoint spans
        wa.add_record(_rec("C", m, "A", "app", 2.1, 3.0))
        wa.finalize_trace(m)
    g = wa.graphs["app"]
    assert g.edge_kind("A", "B") == "sequential"
    assert g.edge_kind("A", "C") == "sequential"
    # remaining-stage topology: A -> {B, C} sinks
    assert g.remaining_stages("A") == 2
    assert g.remaining_stages("B") == 1


def test_remaining_latency_samples():
    wa = WorkflowAnalyzer()
    wa.add_record(_rec("A", "x", None, "app", 0, 1))
    wa.add_record(_rec("B", "x", "A", "app", 1, 5))
    wa.finalize_trace("x")
    assert wa.remaining_samples("app", "A") == [5.0]   # from A's start to end
    assert wa.remaining_samples("app", "B") == [4.0]


# --------------------------------------------------------------------------- #
# MDS priority (§5.1)
# --------------------------------------------------------------------------- #
def test_mds_recovers_line():
    pts = np.array([0.0, 1.0, 4.0, 9.0])
    d = np.abs(pts[:, None] - pts[None, :])
    c = classical_mds_1d(d)
    # pairwise distances preserved up to sign/offset
    d2 = np.abs(c[:, None] - c[None, :])
    np.testing.assert_allclose(d2, d, atol=1e-8)


def test_agent_priorities_order_matches_remaining_latency():
    samples = {
        ("app", "fast"): list(rng.normal(1.0, 0.1, 200)),
        ("app", "mid"): list(rng.normal(5.0, 0.5, 200)),
        ("app", "slow"): list(rng.normal(20.0, 2.0, 200)),
    }
    pr = agent_priorities(samples)
    assert pr[("app", "fast")] < pr[("app", "mid")] < pr[("app", "slow")]
    # anchor orientation: fast agent is closest to zero-latency anchor
    assert pr[("app", "fast")] >= 0


# --------------------------------------------------------------------------- #
# schedulers (§5)
# --------------------------------------------------------------------------- #
def _q(agent, arr, app_start, app="app"):
    return Request(agent_name=agent, msg_id=f"{agent}{arr}", app_name=app,
                   arrival_time=arr, app_start_time=app_start, prompt_len=8)


def test_kairos_scheduler_inter_and_intra_agent_order():
    score = {"fast": 0.0, "slow": 10.0}
    sched = KairosScheduler(lambda app, a: score[a])
    q = [_q("slow", 0.0, 0.0), _q("fast", 1.0, 0.9), _q("fast", 0.5, 0.1)]
    ordered = sched.order(q)
    assert [r.agent_name for r in ordered] == ["fast", "fast", "slow"]
    # intra-agent: earlier application-level start first (§5.2)
    assert ordered[0].app_start_time == 0.1


def test_fcfs_scheduler():
    sched = FCFSScheduler()
    q = [_q("a", 2.0, 0), _q("b", 1.0, 0)]
    assert [r.arrival_time for r in sched.order(q)] == [1.0, 2.0]


def test_topo_scheduler():
    stages = {"early": 3, "late": 1}
    sched = TopoScheduler(lambda app, a: stages[a])
    q = [_q("early", 0.0, 0), _q("late", 1.0, 0)]
    assert [r.agent_name for r in sched.order(q)] == ["late", "early"]


# --------------------------------------------------------------------------- #
# memory model + dispatcher (§6)
# --------------------------------------------------------------------------- #
def test_memory_ramp():
    ramp = make_ramp(prompt_len=100, expected_exec_time=10.0,
                     decode_tok_per_s=20.0, t_start=0.0)
    assert ramp.usage(-1) == 0
    assert ramp.usage(5.0) == pytest.approx(200.0)
    assert ramp.peak == pytest.approx(300.0)
    assert ramp.usage(11.0) == 0


def test_ssm_ramp_is_flat():
    ramp = make_ramp(100, 10.0, 20.0, 0.0, kv_ratio=0.0, state_tokens=64.0)
    assert ramp.usage(5.0) == pytest.approx(64.0)
    assert ramp.peak == pytest.approx(64.0)


def test_timeslot_dispatcher_picks_min_peak_and_respects_capacity():
    insts = [InstanceModel(0, capacity_tokens=1000),
             InstanceModel(1, capacity_tokens=1000)]
    disp = TimeSlotDispatcher(insts)
    r1, r2, r3 = (_q("a", i, i) for i in range(3))
    big = make_ramp(700, 10, 10, 0.0)
    small = make_ramp(100, 10, 10, 0.0)
    assert disp.dispatch(r1, big, 0.0) in (0, 1)
    first = r1.req_id in disp.instances[0].ramps
    # second big request must go to the other instance (load balance by peak)
    iid2 = disp.dispatch(r2, big, 0.0)
    assert iid2 == (1 if first else 0)
    # a third big one doesn't fit anywhere -> rejected
    r4 = _q("a", 4, 4)
    assert disp.dispatch(r4, make_ramp(700, 10, 10, 0.0), 0.0) is None
    # but a small one still fits
    assert disp.dispatch(r3, small, 0.0) is not None


def test_timeslot_dispatcher_time_release():
    insts = [InstanceModel(0, capacity_tokens=500)]
    disp = TimeSlotDispatcher(insts)
    r1, r2 = _q("a", 0, 0), _q("a", 1, 1)
    assert disp.dispatch(r1, make_ramp(400, 2.0, 0, 0.0), 0.0) == 0
    # overlapping in time -> rejected
    assert disp.dispatch(r2, make_ramp(400, 2.0, 0, 0.5), 0.5) is None
    # after r1's expected end, slots are free again
    assert disp.dispatch(r2, make_ramp(400, 2.0, 0, 3.0), 3.0) == 0


def test_oom_fencing():
    insts = [InstanceModel(0, 1000), InstanceModel(1, 1000)]
    disp = TimeSlotDispatcher(insts, oom_cooldown=5.0)
    disp.on_oom(0, now=0.0)
    r = _q("a", 0, 0)
    assert disp.dispatch(r, make_ramp(10, 1, 1, 0.0), 0.0) == 1  # 0 is fenced


def test_round_robin_rotation():
    insts = [InstanceModel(i, 1e9) for i in range(3)]
    disp = RoundRobinDispatcher(insts)
    ids = [disp.dispatch(_q("a", i, i), make_ramp(1, 1, 1, 0), 0.0) for i in range(6)]
    assert ids == [0, 1, 2, 0, 1, 2]
