"""Listing-1 API integration: a two-stage workflow runs through the real
engine with identifier propagation and workflow reconstruction."""
import pytest

from repro.agents import BaseAgent, Workflow
from repro.serving import ServingConfig


class Stage1(BaseAgent):
    def _run_impl(self, input_data, metadata):
        toks = self.generate(self.encode_prompt("stage one", 10), metadata,
                             max_new_tokens=3)
        return {"x": len(toks)}, "Stage2"


class Stage2(BaseAgent):
    def _run_impl(self, input_data, metadata):
        toks = self.generate(self.encode_prompt("stage two", 14), metadata,
                             max_new_tokens=4)
        return {"done": True, "x": input_data["x"], "y": len(toks)}, None


@pytest.mark.slow
def test_two_stage_workflow_end_to_end():
    wf = Workflow(app_name="test", config=ServingConfig(
        n_instances=1, num_blocks=64, block_size=8, max_batch=4))
    wf.add_engine("e0", model="qwen3-1.7b")
    wf.add_agent("Stage1", Stage1)
    wf.add_agent("Stage2", Stage2)
    ids = [wf.submit_task("Stage1", {"q": i}) for i in range(3)]
    results = wf.run(timeout=120)
    assert len(results) == 3
    for mid in ids:
        assert results[mid] == {"done": True, "x": 3, "y": 4}
    # identifiers propagated: the orchestrator saw both stages and the edge
    wf.orch.analyzer  # traces were finalized on completion
    g = wf.orch.analyzer.graphs["test"]
    assert ("Stage1", "Stage2") in g.edges
    assert g.remaining_stages("Stage1") == 2
    # latency distributions collected per agent
    assert set(wf.orch.profiler.agents()) == {"Stage1", "Stage2"}
