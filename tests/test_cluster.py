"""Pipelined multi-instance cluster runtime (serving/cluster.py).

Covered here:

* token identity of the pipelined ``ServingCluster`` vs the legacy
  serial loop — multi-instance, prefix caching + chunked prefill on,
  preemption pressure;
* a dispatch-overlap guard: the pipelined loop issues all engine
  dispatches before the first collect (verified with a barrier the
  serial loop could never pass);
* OOM feedback: a real preemption fences the instance via
  ``dispatcher.on_oom`` (and the legacy ``oom_feedback=False`` baseline
  leaves fencing dead);
* the dispatcher admit probe is ``BatchScheduler.can_admit`` (memory
  watermark), not the legacy queue-length check;
* ``Workflow._llm_call`` raises ``TimeoutError`` instead of returning
  ``[]``, and a failed agent stage surfaces in ``run()`` results;
* deferred-sync ``TokenRef`` semantics.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import Orchestrator
from repro.core.orchestrator import HardwareProfile
from repro.serving import (
    LLMEngine,
    PagedModelRunner,
    Request,
    ServingCluster,
    TokenBuffer,
    TokenRef,
    reset_request_ids,
)


@pytest.fixture(scope="module")
def model_and_params():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


def _reqs(seed=11, sys_len=16, n=6, uniq=7, max_new=4):
    """Shared-prefix requests (full-block cached prefix when caching on)."""
    rng = np.random.default_rng(seed)
    sys_toks = rng.integers(0, 500, sys_len).astype(np.int32)
    reqs = []
    for i in range(n):
        toks = np.concatenate(
            [sys_toks, rng.integers(0, 500, uniq + i).astype(np.int32)])
        reqs.append(Request(agent_name="a", msg_id=f"m{i}", prompt_len=len(toks),
                            prompt_tokens=toks, max_new_tokens=max_new))
    return reqs


def _cluster(model_and_params, *, n_instances=2, num_blocks=64, cache=False,
             chunk=None, pipelined=True, **kw):
    model, params = model_and_params
    runner0 = PagedModelRunner(model, params, num_blocks=num_blocks,
                               block_size=8, max_batch=4)
    engines = [
        LLMEngine(runner0 if i == 0 else runner0.clone(), instance_id=i,
                  max_batch=4, enable_prefix_cache=cache,
                  prefill_chunk_tokens=chunk)
        for i in range(n_instances)]
    orch = Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0, kv_capacity_tokens=num_blocks * 8))
    return ServingCluster(engines, orch, pipelined=pipelined, **kw)


def _drain(cluster, reqs, max_steps=4000):
    pending = list(reqs)
    done = []
    for _ in range(max_steps):
        if pending:
            r = pending.pop(0)
            r.arrival_time = time.monotonic()
            cluster.submit(r)
        done.extend(cluster.step())
        if not pending and not cluster.has_work:
            break
    cluster.close()
    assert not cluster.has_work, "cluster failed to drain"
    return sorted((r.msg_id, tuple(r.output_tokens)) for r in done)


# =============================================================================
# token identity: pipelined vs legacy serial loop
# =============================================================================


def test_pipelined_token_identical_multi_instance(model_and_params):
    """2 instances, prefix caching + chunked prefill on: the pipelined
    breadth-first loop generates exactly the serial loop's tokens."""
    kw = dict(n_instances=2, cache=True, chunk=16)
    reset_request_ids()
    serial = _drain(_cluster(model_and_params, pipelined=False, **kw), _reqs())
    reset_request_ids()
    pipelined = _drain(_cluster(model_and_params, pipelined=True, **kw), _reqs())
    assert len(serial) == 6
    assert pipelined == serial


def test_pipelined_token_identical_under_preemption(model_and_params):
    """Tight pools force preemption-by-recompute; the pipelined cluster
    still drains with tokens identical to the serial loop."""
    kw = dict(n_instances=2, num_blocks=12, cache=False, chunk=8)
    mk = lambda: _reqs(seed=3, sys_len=8, n=6, uniq=2, max_new=24)
    reset_request_ids()
    cl_s = _cluster(model_and_params, pipelined=False, **kw)
    serial = _drain(cl_s, mk())
    reset_request_ids()
    cl_p = _cluster(model_and_params, pipelined=True, **kw)
    pipelined = _drain(cl_p, mk())
    assert sum(e.stats.n_preempted for e in cl_s.engines) > 0, \
        "workload must actually exercise preemption"
    assert pipelined == serial


# =============================================================================
# dispatch overlap guard
# =============================================================================


def test_pipelined_issues_all_dispatches_before_first_collect(model_and_params):
    """Both engines' dispatches must be in flight concurrently before any
    collect runs: each dispatch waits on a 2-party barrier, which only
    passes if the loop issues every dispatch before collecting (a serial
    dispatch->collect->dispatch loop would deadlock here)."""
    cluster = _cluster(model_and_params, n_instances=2)
    barrier = threading.Barrier(2)
    events = []
    lock = threading.Lock()
    for e in cluster.engines:
        orig_d, orig_c = e.dispatch_iteration, e.collect

        def dispatch(e=e, f=orig_d):
            barrier.wait(timeout=30)       # both dispatches concurrent
            with lock:
                events.append(("dispatch", e.instance_id))
            return f()

        def collect(force_sync=False, e=e, f=orig_c):
            with lock:
                events.append(("collect", e.instance_id))
            return f(force_sync=force_sync)

        e.dispatch_iteration = dispatch
        e.collect = collect
    # seed both engines directly so the step has work everywhere
    for i, e in enumerate(cluster.engines):
        rng = np.random.default_rng(i)
        e.submit(Request(agent_name="a", msg_id=f"g{i}", prompt_len=12,
                         prompt_tokens=rng.integers(0, 500, 12).astype(np.int32),
                         max_new_tokens=2))
    cluster.step()
    cluster.close()
    kinds = [k for k, _ in events]
    assert kinds.index("collect") == 2, \
        f"all dispatches must precede the first collect: {events}"
    assert kinds.count("dispatch") == 2 and kinds.count("collect") == 2


def test_serial_mode_interleaves_dispatch_and_collect(model_and_params):
    """The legacy loop steps one engine at a time: dispatch/collect
    strictly interleaved, in instance order."""
    cluster = _cluster(model_and_params, n_instances=2, pipelined=False)
    events = []
    for e in cluster.engines:
        orig_d, orig_c = e.dispatch_iteration, e.collect
        e.dispatch_iteration = (lambda e=e, f=orig_d:
                                (events.append(("dispatch", e.instance_id)),
                                 f())[1])
        e.collect = (lambda force_sync=False, e=e, f=orig_c:
                     (events.append(("collect", e.instance_id)),
                      f(force_sync=force_sync))[1])
    for i, e in enumerate(cluster.engines):
        rng = np.random.default_rng(i)
        e.submit(Request(agent_name="a", msg_id=f"g{i}", prompt_len=12,
                         prompt_tokens=rng.integers(0, 500, 12).astype(np.int32),
                         max_new_tokens=2))
    cluster.step()
    assert events == [("dispatch", 0), ("collect", 0),
                      ("dispatch", 1), ("collect", 1)]


# =============================================================================
# control-plane feedback
# =============================================================================


def _pressure_reqs(n=5, max_new=12):
    rng = np.random.default_rng(7)
    return [Request(agent_name="a", msg_id=f"p{i}", prompt_len=14,
                    prompt_tokens=rng.integers(0, 500, 14).astype(np.int32),
                    max_new_tokens=max_new) for i in range(n)]


def test_preemption_fences_instance_via_on_oom(model_and_params):
    """A real preemption must reach ``dispatcher.on_oom``: the instance
    is fenced for the OOM cooldown (§6 adaptive), exactly like the
    simulator's control plane."""
    reset_request_ids()
    cluster = _cluster(model_and_params, n_instances=1, num_blocks=12)
    for r in _pressure_reqs():
        r.arrival_time = time.monotonic()
        cluster.submit(r)
    fenced_seen = False
    for _ in range(2000):
        cluster.step()
        e = cluster.engines[0]
        if e.stats.n_preempted > 0 and not fenced_seen:
            # fencing happens at the collect that observed the OOM
            fenced_seen = cluster.dispatcher.is_fenced(0, cluster.clock())
        if not cluster.has_work:
            break
    assert cluster.engines[0].stats.n_preempted > 0, \
        "workload must actually exercise preemption"
    assert fenced_seen, "preemption never fenced the instance"


def test_legacy_loop_leaves_fencing_dead(model_and_params):
    """``oom_feedback=False`` reproduces the old driver: preemptions
    happen but the dispatcher never fences (the §6 hook stays dead)."""
    reset_request_ids()
    cluster = _cluster(model_and_params, n_instances=1, num_blocks=12,
                       pipelined=False, oom_feedback=False)
    for r in _pressure_reqs():
        r.arrival_time = time.monotonic()
        cluster.submit(r)
    ever_fenced = False
    for _ in range(2000):
        cluster.step()
        ever_fenced = ever_fenced or cluster.dispatcher.is_fenced(
            0, cluster.clock())
        if not cluster.has_work:
            break
    assert cluster.engines[0].stats.n_preempted > 0
    assert not ever_fenced


def test_admit_probe_is_can_admit_watermark(model_and_params):
    """The dispatcher's admit probe must track the scheduler's memory
    watermark: an instance whose pool is nearly committed rejects a new
    prompt even though the legacy queue-length probe (running + waiting
    < max_batch) would admit it."""
    reset_request_ids()
    cluster = _cluster(model_and_params, n_instances=1, num_blocks=16)
    e = cluster.engines[0]
    rng = np.random.default_rng(1)
    # occupy most of the 16-block pool: 2 running requests x ~6 blocks
    for i in range(2):
        r = Request(agent_name="a", msg_id=f"big{i}", prompt_len=44,
                    prompt_tokens=rng.integers(0, 500, 44).astype(np.int32),
                    max_new_tokens=16)
        e.submit(r)
    cluster.step()
    assert len(e.running) == 2
    probe_req = Request(agent_name="a", msg_id="probe", prompt_len=20,
                        prompt_tokens=rng.integers(0, 500, 20).astype(np.int32),
                        max_new_tokens=4)
    # legacy probe would say yes (2 running + 0 waiting < max_batch=4)...
    assert len(e.running) + len(e.waiting) < e.max_batch
    # ...but the watermark probe refuses: no admission capacity
    assert cluster.can_admit(0, probe_req) is False
    assert cluster.dispatcher.admit_probe == cluster.can_admit
    probe_req.arrival_time = time.monotonic()
    cluster.submit(probe_req)
    cluster.step()
    assert probe_req in cluster.balancer.queue, \
        "the dispatcher must keep the request queued, not place it"


def test_workflow_wires_cluster_probe_and_feedback():
    """Workflow.add_engine builds a ServingCluster whose dispatcher
    probes ``can_admit`` (not the old ad-hoc queue-length lambda)."""
    from repro.agents import Workflow
    from repro.serving import ServingConfig
    wf = Workflow(app_name="t", config=ServingConfig(
        n_instances=2, num_blocks=32, block_size=8, max_batch=4))
    wf.add_engine("e0")
    assert wf.cluster is not None
    assert wf.cluster.dispatcher.admit_probe == wf.cluster.can_admit
    assert wf.cluster.oom_feedback
    assert wf.balancer is wf.cluster.balancer          # back-compat alias
    assert len(wf.cluster.engines) == 2
    # cloned runners share the compiled step functions
    r0, r1 = (e.runner for e in wf.cluster.engines)
    assert r0._fused_fn is r1._fused_fn and r0.pool is not r1.pool


def test_cluster_rejects_engines_sharing_a_runner(model_and_params):
    """Donated in-place pools make a shared PagedModelRunner structurally
    unsafe (instance A's dispatch overwrites — in place — the buffer
    instance B is about to read): the cluster refuses to build one."""
    model, params = model_and_params
    runner = PagedModelRunner(model, params, num_blocks=16, block_size=8,
                              max_batch=2)
    engines = [LLMEngine(runner, instance_id=i, max_batch=2)
               for i in range(2)]
    orch = Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0, kv_capacity_tokens=128))
    with pytest.raises(AssertionError, match="share a PagedModelRunner"):
        ServingCluster(engines, orch)


# =============================================================================
# Workflow failure surfacing
# =============================================================================


def test_llm_call_timeout_raises():
    """An unserved LLM call must raise TimeoutError, not return []."""
    from repro.agents import Workflow
    from repro.agents.messaging import Headers
    wf = Workflow(app_name="t", llm_timeout_s=0.05)
    h = Headers(msg_id="m1", app_name="t", upstream_name=None,
                app_start_time=0.0)
    with pytest.raises(TimeoutError, match="timed out"):
        wf._llm_call("agent", np.zeros(4, np.int32), h, max_new_tokens=2)


def test_failed_agent_stage_surfaces_in_results():
    """An agent stage that raises ends its workflow with a failed result
    (and decrements the outstanding count) instead of hanging run()."""
    from repro.agents import BaseAgent, Workflow

    class Exploding(BaseAgent):
        def _run_impl(self, input_data, metadata):
            raise RuntimeError("boom")

    wf = Workflow(app_name="t")
    wf.add_agent("Boom", Exploding)
    msg_id = wf.submit_task("Boom", {})
    wf.bus.drain()
    for t in wf._threads:
        t.join(timeout=10)
    assert wf._outstanding == 0
    res = wf._results[msg_id]
    assert res["failed"] and "RuntimeError: boom" in res["error"]


# =============================================================================
# deferred-sync token references
# =============================================================================


def test_token_ref_defers_and_materializes():
    import jax.numpy as jnp
    buf = TokenBuffer(jnp.asarray([7, 11, 13], jnp.int32))
    ref = TokenRef(buf, 1)
    assert buf._host is None, "construction must not sync"
    assert int(ref) == 11 and ref == 11 and ref == TokenRef(buf, 1)
    assert buf._host is not None and buf._dev is None
    # numpy consumes refs through __index__ (flatten_plan's tokens_d)
    arr = np.zeros(2, np.int32)
    arr[0] = int(ref)
    assert arr[0] == 11
    assert hash(ref) == hash(11)
