"""Unified ServingConfig (serving/config.py): one dataclass, every layer.

The load-bearing assertion is FIELD PARITY: ``SIM_FIELD_MAP`` must name
every :class:`ServingConfig` field, and every plain (non-derived) target
must be a real :class:`SimConfig` field — so a knob added on one side
cannot silently not exist on the other.  Around that: the
``from_config`` builders consume the config faithfully, the simulator
mapping translates policy/backend spellings, the removed Workflow
legacy kwargs fail loudly (``TypeError`` naming ``ServingConfig``), and
the cluster's public submit/drain/metrics_snapshot contract holds.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.serving import SIM_FIELD_MAP, ServingConfig
from repro.sim.simulator import SimConfig
from repro.sim.workload import make_app


@pytest.fixture(scope="module")
def model_and_params():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


# =============================================================================
# real <-> sim field parity (the api_redesign invariant)
# =============================================================================


def test_sim_field_map_is_total_over_serving_config():
    serving_fields = {f.name for f in dataclasses.fields(ServingConfig)}
    assert set(SIM_FIELD_MAP) == serving_fields, \
        "every ServingConfig field must state how the simulator consumes " \
        f"it (diff: {set(SIM_FIELD_MAP) ^ serving_fields})"


def test_sim_field_map_targets_are_real_sim_fields():
    sim_fields = {f.name for f in dataclasses.fields(SimConfig)}
    for src, dst in SIM_FIELD_MAP.items():
        dst = dst.lstrip("->")   # "->x" marks a derived value, target x
        assert dst in sim_fields, \
            f"SIM_FIELD_MAP[{src!r}] -> {dst!r} is not a SimConfig field"


def test_from_serving_config_maps_every_knob():
    serving = ServingConfig(num_blocks=96, block_size=16, max_batch=24,
                            prefill_chunk_tokens=64, prefix_caching=True,
                            fused_iteration=False, donate_pool=False,
                            ragged_backend="flat_gather", policy="fcfs",
                            tracing=True, model_parallel=2, n_instances=3)
    sim = SimConfig.from_serving_config(serving, [make_app("QA", "G+M")])
    assert sim.kv_capacity_tokens == 96 * 16      # derived: blocks * size
    assert sim.block_size == 16 and sim.max_batch == 24
    assert sim.prefill_chunk_tokens == 64 and sim.prefix_caching
    assert not sim.fused_iteration and not sim.donate_pool
    assert sim.ragged_native is False             # flat lowering priced
    assert sim.policy == "w/o-priority"           # fcfs spelled sim-side
    assert sim.tracing and sim.tp_degree == 2 and sim.n_instances == 3
    # overrides win over the mapped values
    sim2 = SimConfig.from_serving_config(serving, [make_app("QA", "G+M")],
                                         n_instances=1, duration=5.0)
    assert sim2.n_instances == 1 and sim2.duration == 5.0


def test_derived_properties():
    assert ServingConfig().ragged_native is True
    assert ServingConfig(ragged_backend="native").ragged_native is True
    assert ServingConfig(ragged_backend="flat_gather").ragged_native is False
    assert ServingConfig(policy="kairos").sim_policy == "kairos"
    assert ServingConfig(policy="parrot").sim_policy == "parrot"
    assert ServingConfig(policy="fcfs").sim_policy == "w/o-priority"
    assert ServingConfig(num_blocks=8, block_size=4).kv_capacity_tokens == 32


# =============================================================================
# from_config builders consume the config faithfully
# =============================================================================


def test_runner_and_engine_from_config(model_and_params):
    from repro.serving import LLMEngine, PagedModelRunner
    model, params = model_and_params
    cfg = ServingConfig(num_blocks=24, block_size=8, max_batch=3,
                        prefix_caching=True, prefill_chunk_tokens=16)
    r = PagedModelRunner.from_config(model, params, cfg)
    assert r.num_blocks == 24 and r.block_size == 8
    e = LLMEngine.from_config(r, cfg, instance_id=7)
    assert e.instance_id == 7 and e.max_batch == 3
    assert e.prefix_cache is not None
    assert e.sched.prefill_chunk_tokens == 16


def test_cluster_from_config_and_public_contract(model_and_params):
    from repro.core import Orchestrator
    from repro.core.orchestrator import HardwareProfile
    from repro.serving import Request, ServingCluster, reset_request_ids
    model, params = model_and_params
    reset_request_ids()
    cfg = ServingConfig(num_blocks=32, block_size=8, max_batch=2,
                        n_instances=2, policy="kairos")
    orch = Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0, kv_capacity_tokens=cfg.kv_capacity_tokens))
    cluster = ServingCluster.from_config(model, params, orch, cfg)
    assert cluster.config is cfg and cluster.n_instances == 2
    assert cluster._engine_factory is not None, \
        "from_config clusters must be elastic-capable"
    r0, r1 = (e.runner for e in cluster.engines)
    assert r0._fused_fn is r1._fused_fn and r0.pool is not r1.pool
    # the whole public contract, nothing else: submit -> drain -> metrics
    rng = np.random.default_rng(0)
    for i in range(4):
        cluster.submit(Request(
            agent_name="a", msg_id=f"m{i}", prompt_len=10,
            prompt_tokens=rng.integers(0, 500, 10).astype(np.int32),
            max_new_tokens=3, arrival_time=float(i)))
    done = cluster.drain()
    cluster.close()
    assert sorted(r.msg_id for r in done) == [f"m{i}" for i in range(4)]
    snap = cluster.metrics_snapshot()
    for key in ("queue_depth", "n_instances", "n_migrations",
                "migrated_bytes"):
        assert key in snap and isinstance(snap[key], float)
    assert snap["n_instances"] == 2.0
    assert sum(v for k, v in snap.items()
               if k.endswith(".n_finished")) == 4.0


# =============================================================================
# Workflow legacy kwargs: removed after the one-release deprecation window
# =============================================================================


def test_workflow_accepts_config():
    from repro.agents import Workflow
    cfg = ServingConfig(num_blocks=48, block_size=8, max_batch=2,
                        prefix_caching=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # config path warns nothing
        wf = Workflow(app_name="t", config=cfg)
    assert wf.config is cfg


def test_workflow_legacy_kwargs_raise_pointing_at_config():
    from repro.agents import Workflow
    with pytest.raises(TypeError, match="ServingConfig"):
        Workflow(app_name="t", n_instances=2, num_blocks=48,
                 block_size=8, prefix_caching=True)


def test_workflow_rejects_unknown_kwargs():
    from repro.agents import Workflow
    with pytest.raises(TypeError, match="unexpected keyword"):
        Workflow(app_name="t", not_a_knob=1)


def test_workflow_default_matches_legacy_default():
    from repro.agents import Workflow
    wf = Workflow(app_name="t")
    assert wf.config == ServingConfig(max_batch=4), \
        "bare Workflow() must keep its historical engine shape"
