"""Multi-pod dry-run regression: a representative subset of (arch × shape
× mesh) combinations must lower + compile with 512 placeholder devices.

Runs in a subprocess because the dry-run forces
XLA_FLAGS=--xla_force_host_platform_device_count=512 before jax init,
while the rest of the suite must see 1 device.
"""
import json
import subprocess
import sys
import tempfile
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

CASES = [
    ("qwen3-1.7b", "decode_32k"),       # GQA split-KV decode
    ("rwkv6-3b", "long_500k"),          # attention-free 524k context
    ("jamba-v0.1-52b", "decode_32k"),   # hybrid + MoE + FSDP serving
]


@pytest.mark.slow
def test_dryrun_subset_compiles_both_meshes():
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        out = f.name
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--both-meshes", "--out", out]
    for arch, shape in CASES:
        cmd += ["--arch", arch, "--shape", shape]
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"}
    proc = subprocess.run(cmd, capture_output=True, text=True, cwd=ROOT,
                          env=env, timeout=1200)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    records = json.loads(Path(out).read_text())
    ok = [r for r in records if r["status"] == "ok"]
    skipped = [r for r in records if r["status"].startswith("skipped")]
    # CLI runs the cartesian product: 3 archs x 3 shapes x 2 meshes = 18,
    # minus the sanctioned qwen3 x long_500k skips (full attention)
    assert len(records) == 18
    assert len(skipped) == 2
    assert len(ok) == 16
    for r in ok:
        assert r["memory"].get("peak_bytes"), r
        assert sum(r["collectives"].values()) > 0
        # fits a 16 GB v5e
        assert r["memory"]["peak_bytes"] < 16 * 2 ** 30, (
            r["arch"], r["shape"], r["mesh"], r["memory"]["peak_bytes"] / 2 ** 30)
