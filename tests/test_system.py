"""End-to-end behaviour tests for the paper's system: the full Kairos
pipeline (orchestrator -> priority scheduler -> time-slot dispatcher ->
continuous-batching instances) on a single-application workload, checking
the paper's qualitative claims hold on the production code path."""
import numpy as np

from repro.core import wasserstein_1d
from repro.sim import SimConfig, Simulation, make_app


def test_end_to_end_kairos_pipeline():
    cfg = SimConfig(apps=[make_app("QA", "G+M")], policy="kairos",
                    rate=6.0, duration=90.0, seed=7)
    sim = Simulation(cfg)
    res = sim.run()

    # workflows complete and produce tokens
    assert len(res.workflows) > 100
    assert all(w.total_tokens > 0 for w in res.workflows)

    # §4.2: the dynamic-branching workflow was reconstructed online
    g = sim.orch.analyzer.graphs["QA[G+M]"]
    assert ("Router", "MathAgent") in g.edges
    assert ("Router", "HumanitiesAgent") in g.edges

    # §4.3: per-agent latency distributions are distinct (Fig. 4)
    prof = sim.orch.profiler
    r = prof.latency["Router"].samples
    h = prof.latency["HumanitiesAgent"].samples
    assert wasserstein_1d(r, h) > np.mean(r)  # clearly separated

    # §5.1: priorities: Router (full workflow remaining) is scheduled
    # after the leaf experts
    sc = sim.orch.priorities.scores
    assert sc[("QA[G+M]", "MathAgent")] < sc[("QA[G+M]", "Router")]

    # §6: memory conservation at every instance after drain
    for inst in sim.instances.values():
        assert inst.bm.free_blocks == inst.bm.num_blocks


def test_convergence_detection_fires():
    cfg = SimConfig(apps=[make_app("RG", "TQ")], policy="kairos",
                    rate=3.0, duration=200.0, seed=8)
    sim = Simulation(cfg)
    sim.run()
    conv = [a for a in sim.orch.profiler.agents() if sim.orch.profiler.converged(a)]
    assert conv, "at least one agent's latency distribution should converge"
