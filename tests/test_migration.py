"""Live request migration (serving/migration.py).

The contract under test: a running request serialized off one engine and
rebuilt on another continues its token stream BIT-IDENTICALLY to a run
that never migrated — mid-decode, mid-prefill (chunked), across
mid-block boundaries, with COW-shared cached prefixes, onto warm and
cold target caches — and the donated-pool address witness holds on both
sides of every transfer.  Also: a refused migration (full target) is
lossless, and the property sweep drives randomized workloads through
repeated forced migrations (hypothesis when available, a seeded sweep
fallback otherwise).
"""
import jax
import numpy as np
import pytest

from repro.serving import (
    LLMEngine,
    MigrationError,
    PagedModelRunner,
    Request,
    migrate,
    reset_request_ids,
    restore_request,
    snapshot_request,
)


@pytest.fixture(scope="module")
def runner0():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return PagedModelRunner(model, params, num_blocks=64, block_size=8,
                            max_batch=4)


def _engine(runner0, iid, *, cache=True, chunk=None, num_blocks=None):
    if num_blocks is not None:
        # tiny pool for capacity-refusal tests
        r = PagedModelRunner(runner0.model, runner0.params,
                             num_blocks=num_blocks, block_size=8,
                             max_batch=4)
    else:
        r = runner0.clone()
    return LLMEngine(r, instance_id=iid, max_batch=4,
                     enable_prefix_cache=cache, prefill_chunk_tokens=chunk)


def _reqs(n=4, max_new=12, sys_len=16, uniq=9, seed=5):
    rng = np.random.default_rng(seed)
    sys_toks = rng.integers(0, 500, sys_len).astype(np.int32)
    out = []
    for i in range(n):
        toks = np.concatenate(
            [sys_toks, rng.integers(0, 500, uniq + i).astype(np.int32)])
        out.append(Request(agent_name="a", msg_id=f"m{i}",
                           prompt_len=len(toks), prompt_tokens=toks,
                           max_new_tokens=max_new))
    return out


def _drain(*engines, max_steps=4000):
    done = []
    for _ in range(max_steps):
        for e in engines:
            done.extend(e.step())
        if not any(e.sched.has_work for e in engines):
            return done
    raise AssertionError("drain did not converge")


def _tokens(done):
    return {q.msg_id: list(q.output_tokens) for q in done}


def _baseline(runner0, req_kw=None, *, cache=True, chunk=None):
    reset_request_ids()
    e = _engine(runner0, 0, cache=cache, chunk=chunk)
    for q in _reqs(**(req_kw or {})):
        e.submit(q)
    return _tokens(_drain(e))


# ---------------------------------------------------------------------------
# deterministic round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("steps_before", [1, 2, 4, 7])
def test_mid_decode_migration_token_identical(runner0, steps_before):
    base = _baseline(runner0)
    reset_request_ids()
    e0, e1 = _engine(runner0, 0), _engine(runner0, 1)
    for q in _reqs():
        e0.submit(q)
    done = []
    for _ in range(steps_before):
        done.extend(e0.step())
    moved = list(e0.sched.running)
    assert moved, "workload must still be running at the migration point"
    for q in moved:
        migrate(e0, e1, q)
        assert q.instance_id == 1
    done.extend(_drain(e0, e1))
    assert _tokens(done) == base


def test_mid_prefill_and_mid_block_migration(runner0):
    """Chunked prefill: migrate while prefilled_len is mid-prompt and not
    block-aligned (chunk budget 6 on block size 8 guarantees the cut
    lands inside a block); the pending-token slot is empty mid-prefill."""
    req_kw = dict(n=3, uniq=21, max_new=8)   # prompts 37..39 tokens
    base = _baseline(runner0, req_kw, chunk=6)
    reset_request_ids()
    e0, e1 = _engine(runner0, 0, chunk=6), _engine(runner0, 1, chunk=6)
    for q in _reqs(**req_kw):
        e0.submit(q)
    done = list(e0.step())
    mid = [q for q in e0.sched.running if q.prefilled_len < q.prompt_len]
    assert mid, "chunked prefill should leave requests mid-prompt"
    assert any(q.prefilled_len % 8 for q in mid), "want a mid-block cut"
    for q in list(e0.sched.running):
        migrate(e0, e1, q)
    done.extend(_drain(e0, e1))
    assert _tokens(done) == base


def test_cow_shared_blocks_migrate(runner0):
    """Two requests sharing a cached prefix (COW-shared blocks) both
    migrate; streams stay identical and the source pool fully drains."""
    base = _baseline(runner0)
    reset_request_ids()
    e0, e1 = _engine(runner0, 0), _engine(runner0, 1)
    for q in _reqs():
        e0.submit(q)
    done = list(e0.step())
    done.extend(e0.step())
    shared = [b for b in range(e0.bm.num_blocks) if e0.bm.is_shared(b)]
    assert shared, "shared-prefix workload should COW-share blocks"
    for q in list(e0.sched.running):
        migrate(e0, e1, q)
    assert not e0.bm.owned_seqs(), "source must not leak sequences"
    done.extend(_drain(e0, e1))
    assert _tokens(done) == base


def test_warm_target_prefix_cache_adopts_blocks(runner0):
    """A target that already caches the prompt's prefix serves those
    blocks from its own cache: restore reports cached blocks > 0 and the
    continued stream still matches."""
    base = _baseline(runner0)
    reset_request_ids()
    e0, e1 = _engine(runner0, 0), _engine(runner0, 1)
    reqs = _reqs()
    # warm e1's prefix cache with the shared system prompt
    warm = Request(agent_name="w", msg_id="warm",
                   prompt_len=reqs[0].prompt_len,
                   prompt_tokens=np.array(reqs[0].prompt_tokens),
                   max_new_tokens=2)
    e1.submit(warm)
    _drain(e1)
    for q in reqs:
        e0.submit(q)
    done = [q for q in _drain_steps(e0, 3)]
    victim = e0.sched.running[0]
    snap = snapshot_request(e0, victim)
    n_cached = restore_request(e1, snap)
    assert n_cached > 0, "warm target should adopt cached prefix blocks"
    done.extend(_drain(e0, e1))
    # warm finished in its own earlier drain, so it is not in `done`
    assert _tokens(done) == base


def _drain_steps(e, n):
    done = []
    for _ in range(n):
        done.extend(e.step())
    return done


def test_pool_addresses_stable_across_migration(runner0):
    reset_request_ids()
    e0, e1 = _engine(runner0, 0), _engine(runner0, 1)
    for q in _reqs():
        e0.submit(q)
    e0.step()
    a0, a1 = e0.runner.pool_address(), e1.runner.pool_address()
    for q in list(e0.sched.running):
        migrate(e0, e1, q)
    if a0 is not None:
        assert e0.runner.pool_address() == a0
        assert e1.runner.pool_address() == a1


def test_refused_migration_is_lossless(runner0):
    """A target without capacity raises MigrationError BEFORE any source
    state is released; the request finishes on the source untouched."""
    base = _baseline(runner0)
    reset_request_ids()
    e0 = _engine(runner0, 0)
    e1 = _engine(runner0, 1, num_blocks=2)   # too small to adopt anything
    for q in _reqs():
        e0.submit(q)
    e0.step()
    victim = e0.sched.running[0]
    with pytest.raises(MigrationError):
        migrate(e0, e1, victim)
    assert victim in e0.sched.running, "refusal must leave the request"
    with pytest.raises(MigrationError):
        migrate(e0, e0, victim)           # self-migration is refused too
    assert _tokens(_drain(e0)) == base


def test_snapshot_carries_progress_and_pending_token(runner0):
    reset_request_ids()
    e0 = _engine(runner0, 0)
    for q in _reqs(n=2):
        e0.submit(q)
    e0.step()
    e0.step()
    victim = next(q for q in e0.sched.running if q.output_len > 0)
    out_before = list(victim.output_tokens)
    pend = e0.pending_token(victim.req_id)
    snap = snapshot_request(e0, victim)
    assert snap.pending_token == pend is not None
    assert snap.n_resident_tokens == victim.prefilled_len + victim.output_len
    assert snap.n_blocks == snap.kv.shape[2] > 0
    assert victim.output_tokens == out_before, "snapshot must not reset"
    assert victim not in e0.sched.running


# ---------------------------------------------------------------------------
# property sweep: randomized workloads through repeated forced migrations
# ---------------------------------------------------------------------------


def _roundtrip_property(seed: int, migrate_every: int, chunk, runner0):
    req_kw = dict(n=3, max_new=8, uniq=5 + seed % 13, seed=seed)
    base = _baseline(runner0, req_kw, chunk=chunk)
    reset_request_ids()
    engines = [_engine(runner0, 0, chunk=chunk),
               _engine(runner0, 1, chunk=chunk)]
    pending = _reqs(**req_kw)
    done, it = [], 0
    for _ in range(4000):
        if pending:
            engines[it % 2].submit(pending.pop(0))
        for e in engines:
            done.extend(e.step())
        it += 1
        if it % migrate_every == 0:
            src = max(engines, key=lambda e: len(e.sched.running))
            dst = engines[1 - engines.index(src)]
            for q in list(src.sched.running):
                if dst.sched.can_adopt(q):
                    migrate(src, dst, q)
        if not pending and not any(e.sched.has_work for e in engines):
            break
    assert _tokens(done) == base


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10 ** 6), migrate_every=st.integers(1, 4),
           chunk=st.sampled_from([None, 8]))
    def test_migration_roundtrip_property(seed, migrate_every, chunk,
                                          runner0):
        _roundtrip_property(seed, migrate_every, chunk, runner0)

except ImportError:   # pragma: no cover - hypothesis ships in test extras

    @pytest.mark.parametrize("seed,migrate_every,chunk",
                             [(3, 1, None), (11, 2, 8), (27, 3, None),
                              (40, 2, 8)])
    def test_migration_roundtrip_property(seed, migrate_every, chunk,
                                          runner0):
        _roundtrip_property(seed, migrate_every, chunk, runner0)


# ---------------------------------------------------------------------------
# partial-failure hardening: transfer faults roll back losslessly
# ---------------------------------------------------------------------------


def _accounting(bm):
    return (bm.free_blocks, bm.cached_blocks, bm.hard_used_blocks,
            sorted(bm.owned_seqs()))


def test_migrate_many_transfer_fault_rolls_back_without_leaks(runner0):
    """A gathered transfer that fails AFTER target allocation (the worst
    point: every request already adopted, blocks allocated, pending
    tokens planted) must leave both BlockManagers balanced, every
    request RUNNING on the source with identical progress, and the
    subsequent drain bit-identical — the leak-witness regression for the
    lossless-refusal contract."""
    from repro.serving import (FaultInjector, FaultPlan, FaultSpec,
                               MigrationError, migrate_many)
    base = _baseline(runner0)
    reset_request_ids()
    e0, e1 = _engine(runner0, 0), _engine(runner0, 1)
    for q in _reqs():
        e0.submit(q)
    done = []
    for _ in range(3):
        done.extend(e0.step())
    moved = list(e0.sched.running)
    assert moved, "need live work to make the rollback real"
    progress = {q.req_id: (q.prefilled_len, list(q.output_tokens))
                for q in moved}
    acc0, acc1 = _accounting(e0.bm), _accounting(e1.bm)
    # plan one transfer fault at engine 0's first outbound transfer
    inj = FaultInjector(FaultPlan(
        (FaultSpec(kind="transfer", instance_id=0, step=0),)))
    with pytest.raises(MigrationError):
        migrate_many(e0, e1, moved, now=0.0, faults=inj)
    assert inj.n_fired == 1
    # both managers balance; the target kept nothing
    assert _accounting(e0.bm) == acc0, "source accounting must round-trip"
    assert _accounting(e1.bm) == acc1, "target leaked blocks on rollback"
    assert not e1.sched.running and not e1.has_pending
    for q in moved:
        assert q.instance_id == 0 and q in e0.sched.running
        assert (q.prefilled_len, list(q.output_tokens)) == \
            progress[q.req_id], "rollback must not lose progress"
    # the planned fault fired once; the retry goes through cleanly
    snaps, skipped = migrate_many(e0, e1, moved, now=1.0, faults=inj)
    assert len(snaps) == len(moved) and not skipped
    done.extend(_drain(e0, e1))
    assert _tokens(done) == base


def test_migrate_transfer_fault_single_request_rolls_back(runner0):
    """Single-request :func:`migrate` under a planned transfer fault:
    same lossless rollback, then the fault-free retry continues the
    stream bit-identically."""
    from repro.serving import (FaultInjector, FaultPlan, FaultSpec,
                               MigrationError)
    base = _baseline(runner0, dict(n=2, max_new=10))
    reset_request_ids()
    e0, e1 = _engine(runner0, 0), _engine(runner0, 1)
    for q in _reqs(n=2, max_new=10):
        e0.submit(q)
    done = list(e0.step())
    victim = e0.sched.running[0]
    acc1 = _accounting(e1.bm)
    inj = FaultInjector(FaultPlan(
        (FaultSpec(kind="transfer", instance_id=0, step=0),)))
    with pytest.raises(MigrationError):
        migrate(e0, e1, victim, now=0.0, faults=inj)
    assert victim in e0.sched.running and victim.instance_id == 0
    assert _accounting(e1.bm) == acc1
    migrate(e0, e1, victim, now=1.0, faults=inj)   # plan exhausted: clean
    assert victim.instance_id == 1
    done.extend(_drain(e0, e1))
    assert _tokens(done) == base
