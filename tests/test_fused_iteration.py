"""Single-dispatch fused iteration execution (engine.run_iteration).

The fused path flattens a whole :class:`IterationPlan` — every prefill
chunk (arbitrary mid-block start/end, cached-prefix resident KV) plus
every decode token — into one ragged :class:`IterationBatch` executed by
ONE jitted device dispatch.  Covered here:

* token identity vs the legacy per-chunk path (with and without a cached
  shared prefix, mid-block chunk boundaries, mixed prefill+decode
  iterations, staggered arrivals under memory pressure);
* the donated in-place KV pool: buffer-address stability across fused
  AND legacy iterations (donation actually happened), the probe's
  ability to detect copies with donation off, token identity donated vs
  non-donated under preemption pressure, jitted prefill-scatter /
  copy-block helpers, and clone() pool ownership;
* the native ragged kernel vs the flatten-and-repeat lowering: token
  identity end to end (ref and Pallas-interpret backends);
* exactly one device dispatch per iteration (vs K+1 on the legacy path);
* a recompile-count guard: the bucketed static shapes bound `jax.jit`
  cache growth across a varied workload;
* the ragged segment-mask attention lowerings vs the ref oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels.ref import ragged_segment_attention_ref
from repro.serving import (
    LLMEngine,
    PagedModelRunner,
    Request,
    flatten_plan,
    pad_bucket,
    reset_request_ids,
)


@pytest.fixture(scope="module")
def model_and_params():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


def _mixed_reqs(seed=11, sys_len=16, n=4, uniq=6, max_new=4):
    """Shared-prefix requests (full-block cached prefix when caching on)."""
    rng = np.random.default_rng(seed)
    sys_toks = rng.integers(0, 500, sys_len).astype(np.int32)
    reqs = []
    for i in range(n):
        toks = np.concatenate(
            [sys_toks, rng.integers(0, 500, uniq + i).astype(np.int32)])
        reqs.append(Request(agent_name="a", msg_id=f"m{i}", prompt_len=len(toks),
                            prompt_tokens=toks, max_new_tokens=max_new,
                            arrival_time=float(i)))
    return reqs


def _serve(model_and_params, *, fused, chunk, cache, reqs=None,
           staggered=False, num_blocks=64, **runner_kw):
    model, params = model_and_params
    reset_request_ids()
    runner = PagedModelRunner(model, params, num_blocks=num_blocks,
                              block_size=8, max_batch=4, **runner_kw)
    eng = LLMEngine(runner, max_batch=4, enable_prefix_cache=cache,
                    prefill_chunk_tokens=chunk, fused_iteration=fused)
    reqs = reqs if reqs is not None else _mixed_reqs()
    if staggered:
        # trickle arrivals so iterations genuinely mix chunks and decodes
        pending = list(reqs)
        done = []
        for _ in range(4000):
            if pending:
                eng.submit(pending.pop(0))
            done.extend(eng.step())
            if not pending and not eng.running and not eng.waiting:
                break
    else:
        for r in reqs:
            eng.submit(r)
        done = eng.run_until_drained(max_steps=4000)
    assert len(done) == len(reqs)
    assert eng.bm.free_blocks + eng.bm.cached_blocks == eng.bm.num_blocks
    return eng, sorted((d.msg_id, tuple(d.output_tokens)) for d in done)


# =============================================================================
# token identity vs the per-chunk path
# =============================================================================


@pytest.mark.parametrize("cache", [False, True])
@pytest.mark.parametrize("chunk", [None, 5, 8, 16])
def test_fused_token_identical(model_and_params, cache, chunk):
    """Fused execution generates exactly the legacy tokens at every chunk
    budget — including 5, which splits blocks mid-way — with and without
    a cached shared prefix."""
    _, legacy = _serve(model_and_params, fused=False, chunk=chunk, cache=cache)
    _, fused = _serve(model_and_params, fused=True, chunk=chunk, cache=cache)
    assert fused == legacy, f"chunk={chunk} cache={cache} diverged"


def test_fused_token_identical_staggered_mixed_iterations(model_and_params):
    """Staggered arrivals force iterations that mix mid-prompt chunks with
    running decodes; outputs must still match the legacy path."""
    reqs = lambda: _mixed_reqs(seed=7, sys_len=24, n=6, uniq=11, max_new=6)
    _, legacy = _serve(model_and_params, fused=False, chunk=16, cache=True,
                       reqs=reqs(), staggered=True)
    _, fused = _serve(model_and_params, fused=True, chunk=16, cache=True,
                      reqs=reqs(), staggered=True)
    assert fused == legacy


def test_fused_survives_preemption_pressure(model_and_params):
    """Tight pool: preemption-by-recompute still drains and matches the
    legacy path's generated tokens."""
    reqs = lambda: _mixed_reqs(seed=3, sys_len=8, n=5, uniq=19, max_new=10)
    _, legacy = _serve(model_and_params, fused=False, chunk=8, cache=False,
                       reqs=reqs(), num_blocks=24)
    eng, fused = _serve(model_and_params, fused=True, chunk=8, cache=False,
                        reqs=reqs(), num_blocks=24)
    assert fused == legacy


# =============================================================================
# donated in-place pool (zero-copy hot path)
# =============================================================================


def _drain_tracking_pool(model_and_params, *, donate, fused=True,
                         num_blocks=24, chunk=8):
    """Drain a preemption-pressure workload recording the pool's device
    buffer address after every iteration; returns (addresses, outputs)."""
    model, params = model_and_params
    reset_request_ids()
    runner = PagedModelRunner(model, params, num_blocks=num_blocks,
                              block_size=8, max_batch=4, donate_pool=donate)
    eng = LLMEngine(runner, max_batch=4, enable_prefix_cache=True,
                    prefill_chunk_tokens=chunk, fused_iteration=fused)
    for r in _mixed_reqs(seed=3, sys_len=8, n=5, uniq=19, max_new=6):
        eng.submit(r)
    addrs, done = [], []
    for _ in range(4000):
        done.extend(eng.step())
        addrs.append(runner.pool_address())
        if not eng.running and not eng.waiting:
            break
    assert len(done) == 5
    return addrs, sorted((d.msg_id, tuple(d.output_tokens)) for d in done)


@pytest.mark.parametrize("fused", [True, False])
def test_pool_buffer_address_stable_under_donation(model_and_params, fused):
    """Donation actually happened: every dispatch of a drain — fused
    iterations, and the legacy path's prefill-scatter / copy-block /
    suffix / decode helpers — updates the ONE pool buffer in place,
    including across preemption-by-recompute.  Skips cleanly where the
    runtime exposes no buffer address."""
    addrs, _ = _drain_tracking_pool(model_and_params, donate=True, fused=fused)
    if addrs[0] is None:
        pytest.skip("runtime exposes no unsafe_buffer_pointer")
    assert len(set(addrs)) == 1, \
        f"donated pool buffer moved: {len(set(addrs))} distinct addresses"


def test_pool_address_probe_detects_copies(model_and_params):
    """The guard above is meaningful: with donation off, the same drain
    materializes fresh pool buffers (the address moves) — if this ever
    stops detecting copies, the stability assertion proves nothing."""
    addrs, _ = _drain_tracking_pool(model_and_params, donate=False)
    if addrs[0] is None:
        pytest.skip("runtime exposes no unsafe_buffer_pointer")
    assert len(set(addrs)) > 1


def test_donated_vs_nondonated_token_identical(model_and_params):
    """Donation changes buffer traffic only: token streams are identical
    under prefix-cache + chunked-prefill + preemption pressure."""
    _, donated = _drain_tracking_pool(model_and_params, donate=True)
    _, plain = _drain_tracking_pool(model_and_params, donate=False)
    assert donated == plain


def test_prefill_and_copy_block_are_jitted_dispatches(model_and_params):
    """The legacy out-of-jit full-pool ``at[].set`` writes are gone:
    ``prefill`` is exactly two counted dispatches (model + donated
    scatter), ``copy_block`` exactly one, and neither moves the pool
    buffer."""
    model, params = model_and_params
    reset_request_ids()
    runner = PagedModelRunner(model, params, num_blocks=16, block_size=8,
                              max_batch=2)
    a0 = runner.pool_address()
    rng = np.random.default_rng(0)
    d0 = runner.n_dispatches
    runner.prefill(jnp.asarray(rng.integers(0, 500, 12), jnp.int32), [3, 4])
    assert runner.n_dispatches - d0 == 2
    d0 = runner.n_dispatches
    runner.copy_block(3, 7)
    assert runner.n_dispatches - d0 == 1
    np.testing.assert_array_equal(np.asarray(runner.pool[:, :, 7]),
                                  np.asarray(runner.pool[:, :, 3]))
    if a0 is not None:
        assert runner.pool_address() == a0
    # copy_block shares ONE compiled specialization across block ids
    d0 = runner.n_dispatches
    cache0 = runner.jit_cache_size()
    runner.copy_block(4, 8)
    runner.copy_block(7, 9)
    assert runner.n_dispatches - d0 == 2
    assert runner.jit_cache_size() == cache0


def test_clone_owns_pool_under_donation(model_and_params):
    """Clones share compiled (donating) step fns but never a pool
    buffer: dispatching one instance leaves the other's pool untouched
    and at its own stable address."""
    model, params = model_and_params
    reset_request_ids()
    r0 = PagedModelRunner(model, params, num_blocks=16, block_size=8,
                          max_batch=2)
    r1 = r0.clone()
    assert r0._fused_fn is r1._fused_fn
    a0, a1 = r0.pool_address(), r1.pool_address()
    rng = np.random.default_rng(1)
    r0.prefill(jnp.asarray(rng.integers(0, 500, 8), jnp.int32), [0])
    assert not np.asarray(r0.pool[:, :, 0] == 0).all()
    assert np.asarray(r1.pool == 0).all()
    if a1 is not None:
        assert r1.pool_address() == a1 and r0.pool_address() == a0
        assert a0 != a1


# =============================================================================
# native ragged kernel vs flatten-and-repeat, end to end
# =============================================================================


def test_native_vs_flat_ragged_token_identical_under_pressure(model_and_params):
    """The native segment-bounded ragged lowering generates exactly the
    flatten-and-repeat lowering's tokens under prefix-cache +
    chunked-prefill + preemption pressure (tight pool)."""
    reqs = lambda: _mixed_reqs(seed=9, sys_len=16, n=5, uniq=13, max_new=6)
    _, native = _serve(model_and_params, fused=True, chunk=8, cache=True,
                       reqs=reqs(), num_blocks=24, ragged_backend="ref")
    _, flat = _serve(model_and_params, fused=True, chunk=8, cache=True,
                     reqs=reqs(), num_blocks=24, ragged_backend="flat_ref")
    assert native == flat


def test_native_pallas_kernel_token_identical_in_engine(model_and_params):
    """The real Pallas kernel (interpret mode) inside the fused engine
    step produces the ref backend's exact tokens — small workload, the
    interpreted grid is slow."""
    reqs = lambda: _mixed_reqs(seed=5, sys_len=8, n=2, uniq=5, max_new=3)
    _, ref = _serve(model_and_params, fused=True, chunk=8, cache=True,
                    reqs=reqs(), num_blocks=32, ragged_backend="ref")
    _, native = _serve(model_and_params, fused=True, chunk=8, cache=True,
                       reqs=reqs(), num_blocks=32,
                       ragged_backend="interpret")
    assert native == ref


# =============================================================================
# dispatch counting
# =============================================================================


def test_fused_is_single_dispatch_per_iteration(model_and_params):
    """Every fused iteration — mixed, prefill-only, or decode-only —
    issues exactly one device dispatch; the legacy path issues K+1 plus
    an argmax round-trip per completed chunk."""
    model, params = model_and_params
    totals = {}
    for fused in (True, False):
        reset_request_ids()
        runner = PagedModelRunner(model, params, num_blocks=64, block_size=8,
                                  max_batch=4)
        eng = LLMEngine(runner, max_batch=4, prefill_chunk_tokens=8,
                        fused_iteration=fused)
        for r in _mixed_reqs(seed=5, sys_len=16, n=3, uniq=7, max_new=3):
            eng.submit(r)
        iters = 0
        for _ in range(4000):
            before = runner.n_dispatches
            eng.step()
            issued = runner.n_dispatches - before
            if issued == 0:
                break                      # idle: drained
            iters += 1
            if fused:
                assert issued == 1, f"fused iteration issued {issued} dispatches"
        assert iters > 0
        totals[fused] = (runner.n_dispatches, iters)
    n_fused, it_fused = totals[True]
    n_legacy, it_legacy = totals[False]
    assert n_fused == it_fused, "fused: exactly one dispatch per iteration"
    # legacy pays K+1 per mixed iteration plus argmax round-trips: strictly
    # more dispatches than iterations over any run that decodes
    assert n_legacy > it_legacy


# =============================================================================
# recompile guard
# =============================================================================


def test_bucketing_bounds_recompiles(model_and_params):
    """The IterationBatch's padded bucket shapes keep the fused jit cache
    logarithmic: a workload sweeping many prompt lengths, budgets, and
    batch mixes must compile at most one entry per distinct bucket tuple."""
    model, params = model_and_params
    runner = PagedModelRunner(model, params, num_blocks=64, block_size=8,
                              max_batch=4)
    shape_keys = set()
    rng = np.random.default_rng(0)
    for trial, chunk in enumerate((None, 8, 16, 5)):
        reset_request_ids()
        eng = LLMEngine(runner, max_batch=4, prefill_chunk_tokens=chunk,
                        fused_iteration=True)
        # shim: record every flattened shape the engine executes
        orig = runner.run_iteration

        def run(batch, _orig=orig):
            shape_keys.add(batch.shape_key)
            return _orig(batch)

        runner.run_iteration = run
        n = int(rng.integers(2, 5))
        for i in range(n):
            plen = int(rng.integers(3, 60))
            toks = rng.integers(0, 500, plen).astype(np.int32)
            eng.submit(Request(agent_name="a", msg_id=f"t{trial}-{i}",
                               prompt_len=plen, prompt_tokens=toks,
                               max_new_tokens=int(rng.integers(1, 6)),
                               arrival_time=float(i)))
        done = eng.run_until_drained(max_steps=4000)
        runner.run_iteration = orig
        assert len(done) == n
    compiled = runner.jit_cache_size()   # only the fused fn ran
    if compiled == 0:
        pytest.skip("jax private _cache_size API unavailable")
    assert compiled <= len(shape_keys), \
        "fused jit must compile at most once per bucket shape"
    # and the bucket set itself stays small: every dim is floor * 2^k, so
    # this sweep (4 budgets x 14 requests x prompt lengths 3..59) lands on
    # a couple dozen tuples — unbucketed shapes would be in the hundreds
    assert compiled <= 24, f"bucket set exploded: {sorted(shape_keys)}"


def test_pad_bucket():
    assert pad_bucket(0, 4) == 0     # absent part: compiled away
    assert pad_bucket(1, 4) == 4
    assert pad_bucket(4, 4) == 4
    assert pad_bucket(5, 4) == 8
    assert pad_bucket(129, 4) == 256


# =============================================================================
# flatten_plan semantics
# =============================================================================


def test_flatten_defers_first_decode_of_completed_prefill():
    """A request whose final chunk is in this plan must NOT get a decode
    row this iteration — its first decode token is this dispatch's own
    argmax (data dependency) — while already-decoding requests do."""
    from repro.serving import BatchScheduler, BlockManager
    reset_request_ids()
    bm = BlockManager(num_blocks=32, block_size=8)
    sched = BatchScheduler(bm, max_running=4)
    rng = np.random.default_rng(0)
    a = Request(agent_name="a", msg_id="a", prompt_len=8,
                prompt_tokens=rng.integers(0, 500, 8).astype(np.int32))
    sched.submit(a)
    plan = sched.plan(0.0)
    batch = flatten_plan(plan, bm, {})
    kinds = [(s.kind, s.emits_token) for s in batch.segments]
    assert kinds == [("prefill", True)]
    # next iteration: the pending token decodes
    plan2 = sched.plan(1.0)
    batch2 = flatten_plan(plan2, bm, {a.req_id: 123})
    assert [(s.kind, s.emits_token) for s in batch2.segments] == [("decode", True)]
    assert batch2.tokens_d[0] == 123 and batch2.positions_d[0] == a.total_len
    # its sample row points at the decode part of the device layout,
    # and the absent prefill part compiles away (zero-sized)
    assert batch2.tokens_p.size == 0
    assert batch2.sample_rows[0] == batch2.tokens_p.size


def test_flatten_write_slots_and_padding():
    """Write slots address exact token positions through the block table;
    padding rows carry the out-of-range slot so scatters drop them."""
    from repro.serving import BatchScheduler, BlockManager
    reset_request_ids()
    bm = BlockManager(num_blocks=32, block_size=8)
    sched = BatchScheduler(bm, max_running=4, prefill_chunk_tokens=8)
    rng = np.random.default_rng(1)
    r = Request(agent_name="a", msg_id="m", prompt_len=20,
                prompt_tokens=rng.integers(0, 500, 20).astype(np.int32))
    sched.submit(r)
    plan = sched.plan(0.0)
    batch = flatten_plan(plan, bm, {})
    table = bm.block_table(r.req_id)
    n = batch.n_tokens
    assert n == 8 and plan.chunks[0].start == 0
    expect = [table[p // 8] * 8 + p % 8 for p in range(8)]
    assert batch.write_slots[:n].tolist() == expect
    assert (batch.write_slots[n:] == bm.num_blocks * 8).all()
    assert (np.asarray(batch.tokens_p[0, :n]) ==
            np.asarray(r.prompt_tokens[:8], np.int32)).all()
    # chunk tables are trimmed to the chunk's own extent (1 block here),
    # padded to the table bucket floor — decode tables never widen them
    assert batch.tables_p.shape[1] == 4 and batch.tables_p[0, 0] == table[0]


# =============================================================================
# ragged segment-mask attention helper
# =============================================================================


def _ragged_case(key, seg_specs, kv=2, g=4, hd=64, bs=8, nb=3, n_pool=32):
    """Build a (S, L) chunk tile: segments of the given (length, context)
    at staggered offsets, with KV already resident in the pool."""
    ks = jax.random.split(key, 4)
    k_pool = jax.random.normal(ks[0], (n_pool, bs, kv, hd), jnp.float32)
    v_pool = jax.random.normal(ks[1], (n_pool, bs, kv, hd), jnp.float32)
    perm = np.asarray(jax.random.permutation(ks[2], n_pool))
    s, lmax = len(seg_specs), max(n for n, _ in seg_specs)
    tables = np.stack([perm[i * nb:(i + 1) * nb] for i in range(s)])
    positions = np.zeros((s, lmax), np.int32)
    for i, (seg_len, ctx) in enumerate(seg_specs):
        positions[i, :seg_len] = np.arange(ctx, ctx + seg_len)
    q = jax.random.normal(ks[3], (s, lmax, kv, g, hd), jnp.float32)
    return (q, k_pool, v_pool, jnp.asarray(tables, jnp.int32),
            jnp.asarray(positions, jnp.int32))


@pytest.mark.parametrize("backend", ["interpret", "flat_interpret", "flat_ref"])
@pytest.mark.parametrize("seg_specs", [
    [(1, 9), (1, 4), (1, 17)],            # single-token segments
    [(6, 0), (5, 8), (1, 12), (1, 3)],    # ragged mix, padded tile rows
    [(8, 13)],                            # mid-block chunk start
])
def test_ragged_segment_attention_matches_oracle(seg_specs, backend):
    """Every lowering — the native segment-tiled Pallas kernel
    ("interpret") and the legacy flatten-and-repeat lowering onto the
    decode path ("flat_*") — agrees with the ref oracle on the
    segment-blocked causal mask."""
    args = _ragged_case(jax.random.PRNGKey(0), seg_specs)
    ref = kops.ragged_segment_attention(*args, backend="ref")
    ker = kops.ragged_segment_attention(*args, backend=backend)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=2e-5, atol=2e-5)


def test_ragged_segment_attention_is_causal_within_segment():
    """Poisoning pool rows *after* a token's position never changes its
    output; poisoning a row at or before it does."""
    q, kp, vp, bt, pos = _ragged_case(jax.random.PRNGKey(1), [(4, 8)])
    out = ragged_segment_attention_ref(q, kp, vp, bt, pos)
    # token (0, 0) sits at position 8; rows 9.. of its table are future
    blk, off = int(bt[0, 9 // 8]), 9 % 8
    poisoned = ragged_segment_attention_ref(
        q, kp.at[blk, off].set(1e3), vp.at[blk, off].set(-1e3), bt, pos)
    np.testing.assert_allclose(np.asarray(out[0, 0]),
                               np.asarray(poisoned[0, 0]), rtol=1e-6)
    assert not np.allclose(np.asarray(out[0, 1]), np.asarray(poisoned[0, 1])), \
        "token at position 9 must see row 9"


def test_ragged_segment_attention_never_crosses_segments():
    """A segment's output is invariant to everything in other segments'
    pages (disjoint tables here)."""
    q, kp, vp, bt, pos = _ragged_case(jax.random.PRNGKey(2), [(4, 3), (4, 11)])
    out = ragged_segment_attention_ref(q, kp, vp, bt, pos)
    poisoned = ragged_segment_attention_ref(
        q, kp.at[np.asarray(bt[1])].set(1e3),
        vp.at[np.asarray(bt[1])].set(-1e3), bt, pos)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(poisoned[0]),
                               rtol=1e-6)
