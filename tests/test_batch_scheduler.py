"""Unified instance-level batch scheduler (serving/batch_scheduler.py).

Covers the two capabilities the refactor adds on top of the shared
admission/preemption core:

* chunked prefill is **token-identical** to monolithic prefill on the
  real paged JAX engine, at several budgets, with and without a cached
  shared prefix;
* instance waiting queues admit **strictly in policy order** under memory
  pressure (property-based): every admission wave is a prefix of the
  policy-ordered waiting queue.
"""
import jax
import numpy as np
import pytest

from repro.core.scheduler import FCFSScheduler, SchedulerPolicy
from repro.serving import (
    BatchScheduler,
    BlockManager,
    LLMEngine,
    PagedModelRunner,
    Request,
    reset_request_ids,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property still checked via seeded sampling below
    HAVE_HYPOTHESIS = False


# =============================================================================
# pure-scheduler properties (no model execution needed)
# =============================================================================


class ScorePolicy(SchedulerPolicy):
    """Priority = externally assigned score (lower = more urgent)."""
    name = "score"

    def __init__(self, scores):
        self._scores = scores

    def sort_key(self, req: Request):
        return (self._scores[req.req_id], req.req_id)


def _drive(sched, cost_per_req, waves):
    """Step the scheduler like the simulator does, recording each
    admission wave (set of requests admitted by one plan() call)."""
    before = list(sched.waiting)
    order = sched.policy.order(before)
    plan = sched.plan(0.0)
    if plan is None:
        return False
    admitted = [r for r in order if r not in sched.waiting and r in sched.running]
    if admitted:
        waves.append((order, admitted))
    for r in plan.decode:
        r.output_len += 1
        if r.output_len >= cost_per_req[r.req_id]:
            sched.finish(r, 0.0)
    return True


def _check_strict_policy_admission(prompts, outs, prios, chunk=None):
    """Core property: under memory pressure, every admission wave is a
    prefix of the policy-ordered waiting queue, and strict order does
    not cost liveness (all requests drain, all memory returns)."""
    reset_request_ids()
    n = len(prompts)
    # tight memory so admission stalls and preemption can trigger
    bm = BlockManager(num_blocks=24, block_size=8)
    scores, cost = {}, {}
    reqs = []
    for i in range(n):
        r = Request(agent_name=f"a{i}", msg_id=f"m{i}", prompt_len=prompts[i],
                    arrival_time=float(i))
        scores[r.req_id] = prios[i]
        cost[r.req_id] = outs[i]
        reqs.append(r)
    policy = ScorePolicy(scores)
    sched = BatchScheduler(bm, policy=policy, max_running=6,
                           prefill_chunk_tokens=chunk)
    for r in reqs:
        sched.submit(r)

    waves = []
    for _ in range(10_000):
        if not sched.has_work:
            break
        if not _drive(sched, cost, waves):
            break
    # nothing ever jumps a higher-priority request
    assert waves, "at least one admission must happen"
    for order, admitted in waves:
        assert admitted == order[: len(admitted)], (
            f"admitted {[r.req_id for r in admitted]} is not a policy-order "
            f"prefix of {[r.req_id for r in order]}")
    assert not sched.has_work, "scheduler must drain under pressure"
    assert all(r.finish_time >= 0 for r in reqs)
    assert bm.free_blocks == bm.num_blocks


def test_priority_admission_strict_order_sampled():
    """Seeded-random exploration of the admission-order property (runs
    everywhere; the hypothesis variant below digs deeper when available)."""
    rng = np.random.default_rng(0)
    for case in range(40):
        n = int(rng.integers(3, 13))
        _check_strict_policy_admission(
            prompts=[int(p) for p in rng.integers(1, 121, n)],
            outs=[int(o) for o in rng.integers(1, 41, n)],
            prios=[int(s) for s in rng.integers(0, 6, n)],
            chunk=None if case % 2 else 16)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_priority_admission_strict_order_hypothesis(data):
        n = data.draw(st.integers(3, 12))
        _check_strict_policy_admission(
            prompts=data.draw(st.lists(st.integers(1, 120),
                                       min_size=n, max_size=n)),
            outs=data.draw(st.lists(st.integers(1, 40),
                                    min_size=n, max_size=n)),
            prios=data.draw(st.lists(st.integers(0, 5),
                                     min_size=n, max_size=n)),
            chunk=data.draw(st.sampled_from([None, 8, 32])))


def test_fcfs_victim_is_latest_arrival():
    """Default policy preserves the classic vLLM recompute victim."""
    reset_request_ids()
    bm = BlockManager(num_blocks=8, block_size=8)
    sched = BatchScheduler(bm, policy=FCFSScheduler(), max_running=4)
    reqs = [Request(agent_name="a", msg_id=f"m{i}", prompt_len=8,
                    arrival_time=float(i)) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    plan = sched.plan(0.0)
    assert plan is not None and len(sched.running) == 3
    # force growth pressure: all three will need a second block
    for r in list(sched.running):
        r.output_len = 8
    sched._ensure_growable()
    assert sched.stats.n_preempted >= 1
    assert reqs[-1] not in sched.running, "victim must be the latest arrival"


def test_chunk_budget_is_run_to_completion():
    """Per-iteration prefill compute is handed out FIFO over the running
    set (run-to-completion): an in-flight prefill finishes before a
    later-admitted prompt starts, which minimizes every prefill's
    completion time — priority is enforced at admission, not by
    processor-sharing the budget (see plan() comment and the
    chunked_prefill benchmark).  Stats count only executed chunk
    tokens, so a preemption mid-prefill never inflates them."""
    reset_request_ids()
    bm = BlockManager(num_blocks=64, block_size=8)
    scores = {}
    policy = ScorePolicy(scores)
    sched = BatchScheduler(bm, policy=policy, max_running=4,
                           prefill_chunk_tokens=8)
    first = Request(agent_name="lo", msg_id="first", prompt_len=24,
                    arrival_time=0.0)
    scores[first.req_id] = 5
    sched.submit(first)
    sched.plan(0.0)                      # admitted, first 8 tokens
    assert first.prefilled_len == 8
    hi = Request(agent_name="hi", msg_id="hi", prompt_len=24, arrival_time=1.0)
    scores[hi.req_id] = 0
    sched.submit(hi)
    p2 = sched.plan(1.0)
    assert [c.req.msg_id for c in p2.chunks] == ["first"], \
        "in-flight prefill keeps the budget until it completes"
    assert first.prefilled_len == 16 and hi.prefilled_len == 0
    assert sched.stats.prefill_tokens == 16   # only executed chunk tokens


def test_idle_instance_admits_near_capacity_prompt():
    """The admission watermark must not starve a prompt that needs more
    than watermark blocks: an idle instance commits the whole pool."""
    reset_request_ids()
    bm = BlockManager(num_blocks=64, block_size=8)
    sched = BatchScheduler(bm, max_running=4)
    r = Request(agent_name="a", msg_id="m", prompt_len=499)  # 63 > 0.95*64
    sched.submit(r)
    plan = sched.plan(0.0)
    assert plan is not None and r in sched.running
    for _ in range(50):
        for d in sched.plan(0.0).decode:
            d.output_len += 1
            if d.output_len >= 3:
                sched.finish(d, 0.0)
        if not sched.has_work:
            break
    assert r.finish_time >= 0
    assert bm.free_blocks == bm.num_blocks


def test_preempted_before_prefill_retracts_cache_entries():
    """A request preempted in the same plan that admitted it (before its
    prefill could execute) must not leave its admission-time cache
    inserts behind: later requests would match blocks whose KV was never
    written and silently attend garbage."""
    from repro.serving import PrefixCache, TokenPrefixMatcher
    reset_request_ids()
    bm = BlockManager(num_blocks=20, block_size=4)
    cache = PrefixCache(4)
    sched = BatchScheduler(bm, prefix_cache=cache,
                           matcher=TokenPrefixMatcher(), max_running=8)
    # five decoders parked one token before a block boundary
    rng = np.random.default_rng(0)
    for i in range(5):
        a = Request(agent_name="a", msg_id=f"a{i}", prompt_len=8,
                    prompt_tokens=rng.integers(0, 500, 8).astype(np.int32),
                    arrival_time=float(i))
        sched.submit(a)
    assert sched.plan(0.0) is not None and len(sched.running) == 5
    for a in sched.running:
        a.output_len = 4            # total 12 = allocation edge; next grows
    # B: shared-prefix prompt, latest arrival -> preemption victim
    btoks = rng.integers(0, 500, 12).astype(np.int32)
    b = Request(agent_name="b", msg_id="b", prompt_len=12,
                prompt_tokens=btoks, arrival_time=10.0)
    sched.submit(b)
    plan = sched.plan(1.0)
    assert plan is not None
    assert b.state.value == "preempted", "setup must preempt B at admission"
    assert all(c.req is not b for c in plan.chunks), \
        "B's prefill never made it into a plan"
    # B's poisoned entries are gone (only the five A requests' executed
    # blocks remain indexed) and none of B's blocks stayed parked
    assert len(cache) == 10 and bm.cached_blocks == 0
    c = Request(agent_name="c", msg_id="c", prompt_len=12,
                prompt_tokens=btoks.copy(), arrival_time=11.0)
    hashes, cached = TokenPrefixMatcher()(c, cache, bm)
    assert cached == [], "no request may match never-written blocks"


def test_reset_request_ids():
    reset_request_ids()
    a = Request(agent_name="a", msg_id="m")
    reset_request_ids()
    b = Request(agent_name="a", msg_id="m")
    assert a.req_id == b.req_id == 0


# =============================================================================
# chunked-prefill equivalence on the real paged engine
# =============================================================================


@pytest.fixture(scope="module")
def model_and_params():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


def _serve(model_and_params, chunk, cache):
    model, params = model_and_params
    runner = PagedModelRunner(model, params, num_blocks=64, block_size=8,
                              max_batch=4)
    eng = LLMEngine(runner, instance_id=0, max_batch=4,
                    enable_prefix_cache=cache, prefill_chunk_tokens=chunk)
    reqs = _shared_prefix_reqs()
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained(max_steps=4000)
    assert len(done) == len(reqs)
    assert eng.bm.free_blocks + eng.bm.cached_blocks == eng.bm.num_blocks
    return eng, sorted((d.msg_id, tuple(d.output_tokens)) for d in done)


def _shared_prefix_reqs(sys_len=16, uniq=6, n=4, max_new=4):
    rng = np.random.default_rng(11)
    sys_toks = rng.integers(0, 500, sys_len).astype(np.int32)
    reqs = []
    for i in range(n):
        toks = np.concatenate([sys_toks,
                               rng.integers(0, 500, uniq).astype(np.int32)])
        reqs.append(Request(agent_name="a", msg_id=f"m{i}", prompt_len=len(toks),
                            prompt_tokens=toks, max_new_tokens=max_new,
                            arrival_time=float(i)))
    return reqs


@pytest.mark.parametrize("cache", [False, True])
def test_chunked_prefill_token_identical(model_and_params, cache):
    """Chunked prefill at several budgets — including ones that split
    blocks mid-way — must generate exactly the monolithic tokens."""
    _, base = _serve(model_and_params, None, cache)
    for chunk in (5, 8, 16):
        eng, out = _serve(model_and_params, chunk, cache)
        assert out == base, f"chunk={chunk} cache={cache} diverged"
        assert eng.stats.n_finished == 4


def test_chunked_prefill_interleaves_decode(model_and_params):
    """With a small budget, a long prompt must not monopolize an
    iteration: decode of an earlier request proceeds while the long
    prompt is still prefilling."""
    model, params = model_and_params
    runner = PagedModelRunner(model, params, num_blocks=64, block_size=8,
                              max_batch=4)
    eng = LLMEngine(runner, instance_id=0, max_batch=4,
                    prefill_chunk_tokens=8)
    rng = np.random.default_rng(3)
    short = Request(agent_name="s", msg_id="short", prompt_len=8,
                    prompt_tokens=rng.integers(0, 500, 8).astype(np.int32),
                    max_new_tokens=8, arrival_time=0.0)
    long_ = Request(agent_name="l", msg_id="long", prompt_len=40,
                    prompt_tokens=rng.integers(0, 500, 40).astype(np.int32),
                    max_new_tokens=2, arrival_time=0.1)
    eng.submit(short)
    eng.step()                      # short admitted + prefilled (token pending)
    eng.submit(long_)
    eng.step()                      # long starts chunking; short decodes
    eng.step()                      # chunking continues; short keeps decoding
    assert 0 < long_.prefilled_len < long_.prompt_len
    assert short.output_len >= 2, "decode must progress during chunked prefill"
    done = eng.run_until_drained()
    assert {r.msg_id for r in [short, long_] if r.finish_time >= 0} \
        == {"short", "long"}
    assert eng.bm.free_blocks == eng.bm.num_blocks
