"""Prefill/decode disaggregation (serving/handoff.py + role-typed stack).

The contract under test: a role-typed drain — prefill instances running
chunked prefill only, decode instances admitting work exclusively
through block-granular KV handoff — produces token streams
BIT-IDENTICAL to a colocated drain of the same workload, while each
(source, target) handoff batch costs at most one gathered donated
``write_blocks`` dispatch and neither pool buffer ever moves.  Around
that: mid-block prefill cuts, COW/warm-cache adoption on the decode
side, lossless colocated-decode fallback when the decode pool is full,
role-aware admission at the scheduler and every dispatcher, and the
batched ``migrate_many`` single-dispatch invariant.
"""
import jax
import numpy as np
import pytest

from repro.core.dispatcher import (
    InstanceModel,
    RoundRobinDispatcher,
    TimeSlotDispatcher,
    role_accepts,
)
from repro.core.memory_model import MemoryRamp
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving import (
    LLMEngine,
    PagedModelRunner,
    Request,
    RequestPhase,
    drive_handoffs,
    handoff,
    migrate_many,
    reset_request_ids,
)
from repro.serving.handoff import HandoffError


@pytest.fixture(scope="module")
def runner0():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return PagedModelRunner(model, params, num_blocks=64, block_size=8,
                            max_batch=4)


def _engine(runner0, iid, *, role="general", cache=True, chunk=None,
            num_blocks=None):
    if num_blocks is not None:
        r = PagedModelRunner(runner0.model, runner0.params,
                             num_blocks=num_blocks, block_size=8,
                             max_batch=4)
    else:
        r = runner0.clone()
    return LLMEngine(r, instance_id=iid, max_batch=4, role=role,
                     enable_prefix_cache=cache, prefill_chunk_tokens=chunk)


def _reqs(n=4, max_new=12, sys_len=16, uniq=9, seed=5, tag="m"):
    rng = np.random.default_rng(seed)
    sys_toks = rng.integers(0, 500, sys_len).astype(np.int32)
    out = []
    for i in range(n):
        toks = np.concatenate(
            [sys_toks, rng.integers(0, 500, uniq + i).astype(np.int32)])
        out.append(Request(agent_name="a", msg_id=f"{tag}{i}",
                           prompt_len=len(toks), prompt_tokens=toks,
                           max_new_tokens=max_new))
    return out


def _tokens(done):
    return {q.msg_id: list(q.output_tokens) for q in done}


def _baseline(runner0, req_kw=None, *, cache=True, chunk=None):
    reset_request_ids()
    e = _engine(runner0, 0, cache=cache, chunk=chunk)
    for q in _reqs(**(req_kw or {})):
        e.submit(q)
    done = []
    for _ in range(4000):
        done.extend(e.step())
        if not e.sched.has_work:
            return _tokens(done)
    raise AssertionError("baseline drain did not converge")


class _MiniCluster:
    """Just enough cluster surface for drive_handoffs: the engine list,
    a tracer, and an is_fenced probe (never fenced here)."""

    class _Dispatcher:
        @staticmethod
        def is_fenced(instance_id, now):
            return False

    def __init__(self, engines, tracer=NULL_TRACER):
        self.engines = list(engines)
        self.tracer = tracer
        self.dispatcher = self._Dispatcher()


def _disagg_drain(cluster, max_steps=4000):
    """Step every engine then sweep handoffs, until drained.  Returns
    (finished requests, accumulated sweep stats)."""
    done = []
    totals = {"n_handoffs": 0, "handoff_bytes": 0,
              "handoff_dispatches": 0, "n_stranded": 0}
    for it in range(max_steps):
        for e in cluster.engines:
            done.extend(e.step())
        hs = drive_handoffs(cluster, now=float(it))
        assert hs["handoff_dispatches"] <= hs["n_handoffs"], \
            "batching must never spend more dispatches than handoffs"
        for k in totals:
            totals[k] += hs[k]
        if not any(e.sched.has_work for e in cluster.engines):
            return done, totals
    raise AssertionError("disaggregated drain did not converge")


# ---------------------------------------------------------------------------
# the tentpole oracle: disaggregated == colocated, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [None, 6])
def test_disagg_drain_token_identical(runner0, chunk):
    base = _baseline(runner0, chunk=chunk)
    reset_request_ids()
    e0 = _engine(runner0, 0, role="prefill", chunk=chunk)
    e1 = _engine(runner0, 1, role="decode", chunk=chunk)
    a0, a1 = e0.runner.pool_address(), e1.runner.pool_address()
    cluster = _MiniCluster([e0, e1])
    for q in _reqs():
        e0.submit(q)
    done, totals = _disagg_drain(cluster)
    assert _tokens(done) == base, "disaggregation must not change tokens"
    assert totals["n_handoffs"] == 4 and totals["n_stranded"] == 0
    assert all(q.instance_id == 1 for q in done), \
        "every request must finish on the decode instance"
    if a0 is not None:
        assert e0.runner.pool_address() == a0
        assert e1.runner.pool_address() == a1


def test_mid_block_prefill_cut_handoff(runner0):
    """Chunk budget 6 on block size 8: prefill cuts land inside blocks
    and the prompts (37..39 tokens) end mid-block, so every handoff
    moves a partially-filled final block.  Streams must still match."""
    req_kw = dict(n=3, uniq=21, max_new=8)
    base = _baseline(runner0, req_kw, chunk=6)
    reset_request_ids()
    e0 = _engine(runner0, 0, role="prefill", chunk=6)
    e1 = _engine(runner0, 1, role="decode", chunk=6)
    cluster = _MiniCluster([e0, e1])
    reqs = _reqs(**req_kw)
    assert all(q.prompt_len % 8 for q in reqs), "want mid-block prompt ends"
    for q in reqs:
        e0.submit(q)
    # requests still mid-prefill never appear in handoff_ready
    e0.step()
    mid = [q for q in e0.sched.running if q.prefilled_len < q.prompt_len]
    assert mid and all(q.phase is RequestPhase.PREFILL for q in mid)
    assert not ({q.req_id for q in e0.sched.handoff_ready()}
                & {q.req_id for q in mid})
    done, totals = _disagg_drain(cluster)
    assert _tokens(done) == base
    assert totals["n_handoffs"] == 3


def test_cow_shared_prefix_adopted_on_decode_side(runner0):
    """Wave 1's handoffs re-register the shared prefix in the decode
    instance's cache; wave 2's handoffs then adopt those blocks instead
    of re-sending them over the wire (trace: ``cached > 0``)."""
    reset_request_ids()
    e0 = _engine(runner0, 0, role="prefill")
    e1 = _engine(runner0, 1, role="decode")
    tracer = Tracer(clock=lambda: 0.0)
    cluster = _MiniCluster([e0, e1], tracer=tracer)
    for q in _reqs(n=2, tag="w1-"):
        e0.submit(q)
    done, _ = _disagg_drain(cluster)
    for q in _reqs(n=2, tag="w2-"):
        e0.submit(q)
    done2, totals2 = _disagg_drain(cluster)
    assert totals2["n_handoffs"] == 2
    evts = [e for e in tracer.events() if e.kind == "handoff-complete"]
    starts = [e for e in tracer.events() if e.kind == "handoff-start"]
    assert len(evts) == len(starts) == 4
    assert {e.req_id for e in evts} == {e.req_id for e in starts}
    assert all(e.instance_id == 1 and e.data["src"] == 0 for e in evts)
    assert all(s.data["to"] == 1 and s.data["n_blocks"] > 0
               and s.data["n_bytes"] > 0 for s in starts)
    wave2 = [e for e in evts if e.msg_id.startswith("w2-")]
    assert any(e.data["cached"] > 0 for e in wave2), \
        "wave 2 should adopt the prefix wave 1 registered on the target"
    # identical to running both waves colocated on one cached engine
    reset_request_ids()
    eb = _engine(runner0, 0)
    base = {}
    for tag in ("w1-", "w2-"):
        for q in _reqs(n=2, tag=tag):
            eb.submit(q)
        acc = []
        for _ in range(4000):
            acc.extend(eb.step())
            if not eb.sched.has_work:
                break
        base.update(_tokens(acc))
    assert {**_tokens(done), **_tokens(done2)} == base


def test_handoff_refused_full_decode_pool_decodes_colocated(runner0):
    """A decode pool too small to adopt anything strands every request:
    the prefill instance decodes them itself, losslessly, and the driver
    does not re-count already-stranded requests."""
    base = _baseline(runner0)
    reset_request_ids()
    e0 = _engine(runner0, 0, role="prefill")
    e1 = _engine(runner0, 1, role="decode", num_blocks=2)
    cluster = _MiniCluster([e0, e1])
    for q in _reqs():
        e0.submit(q)
    done, totals = _disagg_drain(cluster)
    assert totals["n_handoffs"] == 0
    assert totals["n_stranded"] == 4, "each request stranded exactly once"
    assert _tokens(done) == base, "colocated fallback must be lossless"
    assert all(q.instance_id == 0 for q in done)


def test_stranded_request_hands_off_once_capacity_frees(runner0):
    """Stranded requests stay re-offerable (with backoff): when the
    decode pool frees up mid-decode, the next offer migrates them
    (mid-decode transfers are bit-identical, inherited from the
    migration layer)."""
    base = _baseline(runner0, dict(n=2, max_new=10))
    reset_request_ids()
    e0 = _engine(runner0, 0, role="prefill")
    e1 = _engine(runner0, 1, role="decode", num_blocks=2)
    cluster = _MiniCluster([e0, e1])
    for q in _reqs(n=2, max_new=10):
        e0.submit(q)
    done = []
    # strand both, decode a few colocated iterations
    for it in range(4):
        for e in cluster.engines:
            done.extend(e.step())
        drive_handoffs(cluster, now=float(it))
    assert e0.sched.stranded and all(q.output_len > 0
                                     for q in e0.sched.running)
    # capacity appears: swap in a decode instance with a real pool.
    # The stranded pair is mid-backoff, so sweep until their next offer
    # comes due (bounded by the exponential backoff window).
    e2 = _engine(runner0, 2, role="decode")
    cluster.engines[1] = e2
    moved = 0
    for it in range(64):
        moved += drive_handoffs(cluster, now=100.0 + it)["n_handoffs"]
        if moved:
            break
    assert moved == 2, "re-offer must move the stranded requests"
    assert not e0.sched.stranded, "handoff clears the stranded set"
    for it in range(4000):
        for e in cluster.engines:
            done.extend(e.step())
        if not any(e.sched.has_work for e in cluster.engines):
            break
    assert _tokens(done) == base


def test_strand_retry_cap_stops_reprobing_full_pool(runner0):
    """A permanently full decode pool must stop costing a probe per
    stranded request per sweep: offers back off exponentially and stop
    for good past the retry cap (the strand becomes permanent), with one
    ``handoff-strand`` event per failed offer and the final one flagged
    ``permanent``.  The drain stays lossless throughout."""
    # long decodes: offers back off at sweeps ~1,3,7,15,31, so the cap
    # (4) trips while the requests are still running
    base = _baseline(runner0, dict(n=2, max_new=40))
    reset_request_ids()
    e0 = _engine(runner0, 0, role="prefill")
    e1 = _engine(runner0, 1, role="decode", num_blocks=2)  # never adopts
    tracer = Tracer(clock=lambda: 0.0)
    cluster = _MiniCluster([e0, e1], tracer=tracer)
    for q in _reqs(n=2, max_new=40):
        e0.submit(q)
    done = []
    for it in range(4000):
        for e in cluster.engines:
            done.extend(e.step())
        drive_handoffs(cluster, now=float(it))
        if not any(e.sched.has_work for e in cluster.engines):
            break
    assert _tokens(done) == base, "capped strands must stay lossless"
    strands = [e for e in tracer.events() if e.kind == "handoff-strand"]
    per_req = {}
    for e in strands:
        per_req.setdefault(e.req_id, []).append(e)
    cap = 4   # _MiniCluster has no config -> drive_handoffs default
    assert set(per_req) == {q.req_id for q in done}
    for req_id, evts in per_req.items():
        # one event per failed offer, never more than cap+1 (the offer
        # that trips the cap is the last one ever made)
        assert len(evts) <= cap + 1, \
            f"req {req_id} probed {len(evts)} times, cap is {cap}"
        assert evts[-1].data["permanent"], \
            "the last offer must mark the strand permanent"
        assert [e.data["attempts"] for e in evts] == \
            list(range(1, len(evts) + 1))
    # well past the cap the ready set is non-empty only while decoding;
    # offers stop regardless: no strand event after the permanent one
    n_after = sum(1 for e in strands if e.data["attempts"] > cap + 1)
    assert n_after == 0


def _run_cluster(runner0, roles, *, num_blocks=28, n=6, max_new=8):
    """Full ServingCluster drain under prefix-cache + chunked-prefill +
    preemption pressure (pool sized to force evictions)."""
    from repro.core import Orchestrator
    from repro.core.orchestrator import HardwareProfile
    from repro.serving import ServingCluster, ServingConfig
    reset_request_ids()
    cfg = ServingConfig(num_blocks=num_blocks, block_size=8, max_batch=3,
                        n_instances=2, prefix_caching=True,
                        prefill_chunk_tokens=16, policy="kairos",
                        roles=roles)
    orch = Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0, kv_capacity_tokens=cfg.kv_capacity_tokens))
    cluster = ServingCluster.from_config(runner0.model, runner0.params,
                                         orch, cfg)
    rng = np.random.default_rng(3)
    sys_toks = rng.integers(0, 500, 16).astype(np.int32)
    for i in range(n):
        toks = np.concatenate(
            [sys_toks, rng.integers(0, 500, 8 + 3 * i).astype(np.int32)])
        cluster.submit(Request(agent_name="a", msg_id=f"c{i}",
                               prompt_len=len(toks), prompt_tokens=toks,
                               max_new_tokens=max_new,
                               arrival_time=0.01 * i))
    done = cluster.drain()
    snap = cluster.metrics_snapshot()
    cluster.close()
    assert len(done) == n, "drain must finish every request"
    return _tokens(done), snap


def test_serving_cluster_disagg_drain_matches_colocated(runner0):
    """The tentpole acceptance oracle at cluster level: 1 prefill + 1
    decode fully drains bit-identically to the colocated 2-instance
    baseline, with the handoff/migration counters visible in the
    snapshot under role-prefixed labels."""
    base, base_snap = _run_cluster(runner0, None)
    disagg, snap = _run_cluster(runner0, ("prefill", "decode"))
    assert disagg == base, "disaggregated drain must be token-identical"
    assert snap["n_handoffs"] >= 6.0
    assert snap["handoff_dispatches"] <= snap["n_handoffs"]
    assert snap["handoff_bytes"] > 0.0
    assert any(k.startswith("prefill0.") for k in snap)
    assert any(k.startswith("decode1.") for k in snap)
    assert any(k.startswith("engine0.") for k in base_snap), \
        "flat clusters keep the engine<i> prefix baselines rely on"
    # per-role attribution: admissions land on the prefill pool, every
    # finish on the decode pool (flat snapshots roll up as "general")
    from repro.obs import rollup_by_role
    roles = rollup_by_role(snap)
    assert {"prefill", "decode"} <= set(roles)
    assert roles["prefill"]["n_admitted"] >= 6.0, \
        "every admission (re-admissions included) is prefill-pool work"
    assert roles["prefill"].get("n_finished", 0.0) \
        + roles["decode"].get("n_finished", 0.0) == 6.0
    assert roles["decode"].get("n_finished", 0.0) > 0.0
    assert set(rollup_by_role(base_snap)) == {"general"}


# ---------------------------------------------------------------------------
# role-aware admission: scheduler + dispatchers
# ---------------------------------------------------------------------------


def test_role_accepts_phase_matrix():
    fresh = Request(agent_name="a", msg_id="p", prompt_len=8,
                    max_new_tokens=4)
    assert fresh.phase is RequestPhase.PREFILL
    assert role_accepts("general", fresh)
    assert role_accepts("prefill", fresh)
    assert not role_accepts("decode", fresh)
    fresh.phase = RequestPhase.DECODE
    assert role_accepts("general", fresh)
    assert not role_accepts("prefill", fresh)
    assert role_accepts("decode", fresh)


def test_decode_engine_never_admits_balancer_traffic(runner0):
    e = _engine(runner0, 0, role="decode")
    q = _reqs(n=1)[0]
    assert not e.sched.can_admit(q), \
        "decode instances admit only through adopt()"


def test_prefill_engine_never_grows_decode_batches(runner0):
    reset_request_ids()
    e = _engine(runner0, 0, role="prefill")
    for q in _reqs(n=2):
        e.submit(q)
    for _ in range(6):
        e.step()
    ready = e.sched.handoff_ready()
    assert len(ready) == 2, "prefill must complete"
    assert all(q.output_len == 0 for q in e.sched.running), \
        "prefill instances must not decode un-stranded requests"
    for q in ready:
        e.sched.allow_colocated_decode(q)
    e.step()
    assert all(q.output_len > 0 for q in e.sched.running), \
        "stranded requests decode colocated"


def _ramp(now):
    return MemoryRamp(p_tokens=16.0, slope=2.0, t_start=now, t_end=now + 1.0)


@pytest.mark.parametrize("force", [False, True])
def test_timeslot_dispatcher_routes_by_role(force):
    insts = [InstanceModel(0, 512.0, role="prefill"),
             InstanceModel(1, 512.0, role="decode")]
    d = TimeSlotDispatcher(insts)
    q = Request(agent_name="a", msg_id="x", prompt_len=8, max_new_tokens=4)
    assert d.dispatch(q, _ramp(0.0), 0.0, force=force) == 0, \
        "prefill-phase work lands on the prefill instance, force included"
    q2 = Request(agent_name="a", msg_id="y", prompt_len=8, max_new_tokens=4)
    q2.phase = RequestPhase.DECODE
    assert d.dispatch(q2, _ramp(0.0), 0.0, force=force) == 1


def test_round_robin_dispatcher_respects_roles():
    insts = [InstanceModel(0, 512.0, role="decode"),
             InstanceModel(1, 512.0, role="prefill")]
    d = RoundRobinDispatcher(insts)
    for i in range(3):   # rotation never lands prefill work on decode
        q = Request(agent_name="a", msg_id=f"r{i}", prompt_len=8,
                    max_new_tokens=4)
        assert d.dispatch(q, _ramp(0.0), 0.0) == 1


def test_handoff_rejects_mid_prefill_request(runner0):
    reset_request_ids()
    e0 = _engine(runner0, 0, role="prefill", chunk=6)
    e1 = _engine(runner0, 1, role="decode", chunk=6)
    for q in _reqs(n=2, uniq=21):
        e0.submit(q)
    e0.step()
    mid = next(q for q in e0.sched.running
               if q.prefilled_len < q.prompt_len)
    with pytest.raises(HandoffError):
        handoff(e0, e1, mid)
    assert mid in e0.sched.running, "refusal must leave the request"


# ---------------------------------------------------------------------------
# migration batching: one gathered donated dispatch per batch
# ---------------------------------------------------------------------------


def test_migrate_many_single_dispatch(runner0):
    base = _baseline(runner0)
    reset_request_ids()
    e0, e1 = _engine(runner0, 0), _engine(runner0, 1)
    for q in _reqs():
        e0.submit(q)
    done = []
    for _ in range(3):
        done.extend(e0.step())
    batch = list(e0.sched.running)
    assert len(batch) >= 2, "want a real batch"
    d0 = e1.runner.n_dispatches
    snaps, skipped = migrate_many(e0, e1, batch)
    assert len(snaps) == len(batch) and not skipped
    assert e1.runner.n_dispatches - d0 == 1, \
        "N requests to one target must cost exactly one write dispatch"
    assert sum(s.n_bytes for s in snaps) > 0
    for _ in range(4000):
        done.extend(e0.step())
        done.extend(e1.step())
        if not (e0.sched.has_work or e1.sched.has_work):
            break
    assert _tokens(done) == base


def test_migrate_many_skips_infeasible_without_dispatch(runner0):
    reset_request_ids()
    e0 = _engine(runner0, 0)
    e1 = _engine(runner0, 1, num_blocks=2)   # cannot adopt anything
    for q in _reqs(n=2):
        e0.submit(q)
    e0.step()
    d0 = e1.runner.n_dispatches
    snaps, skipped = migrate_many(e0, e1, list(e0.sched.running))
    assert not snaps and len(skipped) == 2
    assert e1.runner.n_dispatches == d0, "a fully-skipped batch is free"
    assert all(q in e0.sched.running for q in skipped)
