"""Integration: real JAX paged engine serves batched requests end-to-end,
with continuous batching and preemption under memory pressure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import LLMEngine, PagedModelRunner, Request


@pytest.fixture(scope="module")
def runner():
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return PagedModelRunner(model, params, num_blocks=64, block_size=8, max_batch=4)


def _req(key, prompt_len, max_new, t=0.0, agent="a"):
    toks = jax.random.randint(key, (prompt_len,), 0, 500)
    return Request(agent_name=agent, msg_id=f"m{int(key[0])}-{prompt_len}",
                   prompt_len=prompt_len, prompt_tokens=np.asarray(toks),
                   max_new_tokens=max_new, arrival_time=t, app_start_time=t)


def test_engine_serves_batched_requests(runner):
    eng = LLMEngine(runner, instance_id=0)
    reqs = [_req(jax.random.PRNGKey(i), 12 + i, 6) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == 5
    for r in done:
        assert r.output_len == 6
        assert len(r.output_tokens) == 6
        assert r.finish_time > r.exec_start_time >= 0
    # all memory returned
    assert eng.bm.free_blocks == eng.bm.num_blocks


def test_engine_preempts_under_memory_pressure(runner):
    eng = LLMEngine(runner, instance_id=1)
    # 4 concurrent x (24 prompt + 120 new + 1) tokens > 64*8=512 token capacity
    reqs = [_req(jax.random.PRNGKey(10 + i), 24, 120, t=float(i)) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained(max_steps=4000)
    assert len(done) == 6
    assert eng.stats.n_preempted > 0, "memory pressure should force preemption"
    assert eng.bm.free_blocks == eng.bm.num_blocks


def test_paged_decode_matches_contiguous_decode(runner):
    """The paged runner's decode must equal the model's contiguous decode."""
    cfg = runner.cfg
    model = runner.model
    params = runner.params
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 10), 0, 500)

    logits_ref, _ = model.prefill(params, toks)   # next-token logits after 10

    eng = LLMEngine(runner, instance_id=2)
    r = _req(jax.random.PRNGKey(99), 10, 2)
    r.prompt_tokens = np.asarray(toks[0])
    eng.submit(r)
    eng.step()  # fused prefill iteration: first token pending
    eng.step()  # first decode iteration commits it
    # first generated token was argmax of prefill logits
    assert r.output_tokens[0] == int(jnp.argmax(logits_ref))
