"""Focused unit tests on model internals: sliding-window masks, chunked
attention equivalence, MoE dispatch invariants, RWKV/Mamba chunked-vs-step
equivalence, optimizers, data pipeline, sharding rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import ModelConfig, MoEConfig


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #
def _mini_cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97)
    base.update(kw)
    return ModelConfig(**base)


def test_chunked_attention_equals_unchunked():
    from repro.models.attention import causal_attention, init_attention
    cfg = _mini_cfg()
    p = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64))
    o1 = causal_attention(p, x, cfg, q_chunk=16)   # 4 chunks
    o2 = causal_attention(p, x, cfg, q_chunk=512)  # single pass
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5, atol=2e-5)


def test_sliding_window_restricts_receptive_field():
    from repro.models.attention import causal_attention, init_attention
    cfg = _mini_cfg(sliding_window=8)
    p = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 64))
    o1 = causal_attention(p, x, cfg)
    # perturbing a token far outside the window must not change the output
    x2 = x.at[0, 0].set(100.0)
    o2 = causal_attention(p, x2, cfg)
    np.testing.assert_allclose(np.asarray(o1[0, 20:]), np.asarray(o2[0, 20:]),
                               rtol=1e-4, atol=1e-4)
    # ...but it must change positions inside the window of token 0
    assert not np.allclose(np.asarray(o1[0, 2]), np.asarray(o2[0, 2]))


def test_gemma_global_layers_see_past_window():
    from repro.models.attention import causal_attention, init_attention
    cfg = _mini_cfg(sliding_window=8, global_attn_every=2)
    p = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 64))
    x2 = x.at[0, 0].set(100.0)
    og1 = causal_attention(p, x, cfg, is_global=jnp.asarray(True))
    og2 = causal_attention(p, x2, cfg, is_global=jnp.asarray(True))
    assert not np.allclose(np.asarray(og1[0, 31]), np.asarray(og2[0, 31]))


# --------------------------------------------------------------------------- #
# MoE
# --------------------------------------------------------------------------- #
def test_moe_capacity_and_combine_weights():
    from repro.models.moe import init_moe, moe_ffn
    cfg = _mini_cfg(moe=MoEConfig(num_experts=4, top_k=2, d_expert=32))
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    out, aux = moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) >= 0
    # permutation equivariance over tokens (dispatch must not mix tokens):
    perm = jax.random.permutation(jax.random.PRNGKey(2), 16)
    out_p, _ = moe_ffn(p, x[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(out[:, perm]), np.asarray(out_p),
                               rtol=5e-3, atol=5e-3)


def test_moe_aux_loss_penalizes_imbalance():
    from repro.models.moe import init_moe, moe_ffn
    cfg = _mini_cfg(moe=MoEConfig(num_experts=4, top_k=1, d_expert=32))
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64))
    # force all tokens to expert 0
    p_bad = dict(p, router=jnp.zeros_like(p["router"]).at[:, 0].set(10.0))
    _, aux_bal = moe_ffn(p, x, cfg)
    _, aux_bad = moe_ffn(p_bad, x, cfg)
    assert float(aux_bad) > float(aux_bal)


# --------------------------------------------------------------------------- #
# SSM: chunked forward == sequential single steps
# --------------------------------------------------------------------------- #
def test_rwkv_chunked_matches_stepwise():
    from repro.models import ssm
    cfg = _mini_cfg(family="ssm", num_heads=0, num_kv_heads=0, rwkv_head_dim=16)
    p = ssm.init_rwkv_time_mix(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s, d = 1, 10, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    h = d // 16
    st = jnp.zeros((b, h, 16, 16))
    sh = jnp.zeros((b, d))
    out_chunk, st_c, _ = ssm.rwkv_time_mix(p, x, st, sh, cfg, chunk=4)
    outs = []
    st_s, sh_s = st, sh
    for t in range(s):
        o, st_s, sh_s = ssm.rwkv_time_mix_step(p, x[:, t:t+1], st_s, sh_s, cfg)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_step),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_s), rtol=1e-3, atol=1e-3)


def test_mamba_chunked_matches_stepwise():
    from repro.models import ssm
    cfg = _mini_cfg(family="hybrid", ssm_state_dim=8, ssm_expand=2, ssm_conv_dim=4)
    p = ssm.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s, d = 1, 9, 64
    di = 2 * d
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    h0 = jnp.zeros((b, di, 8))
    c0 = jnp.zeros((b, 3, di))
    out_chunk, h_c, conv_c = ssm.mamba_forward(p, x, h0, c0, cfg, chunk=4)
    outs, h_s, c_s = [], h0, c0
    for t in range(s):
        o, h_s, c_s = ssm.mamba_step(p, x[:, t:t+1], h_s, c_s, cfg)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_step),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s), rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------------- #
# optimizers + data
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("kind", ["adam", "adafactor"])
def test_optimizer_reduces_quadratic(kind):
    from repro.training.optimizer import make_optimizer
    _, init, update = make_optimizer(kind)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = init(params)
    start = float(jnp.sum(params["w"] ** 2))
    for step in range(800):
        grads = {"w": 2 * params["w"]}       # d/dw ||w||^2
        params, state = update(params, grads, state, jnp.asarray(step))
    end = float(jnp.sum(params["w"] ** 2))
    assert np.isfinite(end) and end < start * 0.95, (start, end)


def test_data_pipeline_deterministic_and_sharded():
    from repro.training.data import DataConfig, PackedStream
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8, seed=3)
    ds = PackedStream(cfg)
    b1 = ds.batch(5)
    b2 = ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards partition the global batch
    s0 = ds.batch(5, shard=0, num_shards=2)
    s1 = ds.batch(5, shard=1, num_shards=2)
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].max() < 100


# --------------------------------------------------------------------------- #
# sharding rules
# --------------------------------------------------------------------------- #
def test_param_pspecs_divide_all_archs():
    """Every rule-produced spec must evenly divide the dim it shards."""
    from repro.models import build_model
    from repro.models.sharding import param_pspec

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    for arch in ("qwen2-moe-a2.7b", "jamba-v0.1-52b", "kimi-k2-1t-a32b",
                 "gemma3-27b", "rwkv6-3b"):
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))

        def check(path, leaf):
            keys = tuple(str(getattr(k, "key", "")) for k in path)
            spec = param_pspec(keys, leaf, cfg, FakeMesh(), fsdp=True)
            for ax, dim in zip(tuple(spec) + (None,) * leaf.ndim, leaf.shape):
                if ax is None:
                    continue
                size = int(np.prod([FakeMesh.shape[a] for a in
                                    (ax if isinstance(ax, tuple) else (ax,))]))
                assert dim % size == 0, (arch, keys, spec, leaf.shape)

        jax.tree_util.tree_map_with_path(check, shapes)


def test_shape_applicability_matrix():
    from repro.configs import ARCH_IDS, shape_applicable
    long = INPUT_SHAPES["long_500k"]
    runnable = {a for a in ARCH_IDS if shape_applicable(get_config(a), long)}
    assert runnable == {"rwkv6-3b", "jamba-v0.1-52b", "gemma3-27b"}
    for a in ARCH_IDS:  # all other shapes always run
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), INPUT_SHAPES[s])
