"""Elastic autoscaling (serving/autoscaler.py + cluster elasticity).

Three layers:

* the PURE decision core — hysteresis (patience streaks, cooldown
  freeze), fleet bounds, and fenced-first victim selection, exercised on
  hand-built :class:`ClusterSignals` with no model in sight;
* the REAL cluster — ``scale_up`` mints a routable engine from the
  config factory, the closed autoscaler loop grows under queue pressure
  and shrinks back when calm, and the scale-down-of-a-fenced-instance
  regression: retiring an OOM-fenced instance must clear its dispatcher
  fence and requeue its in-flight work WITHOUT dropping the completions
  of the iteration it had in flight;
* the SIMULATOR — an elastic run on a seeded bursty trace is
  deterministic (same seed twice => identical scale history and
  summary) and actually scales.
"""
import jax
import numpy as np
import pytest

from repro.core import Orchestrator
from repro.core.orchestrator import HardwareProfile
from repro.serving import (
    Autoscaler,
    AutoscalerConfig,
    ClusterSignals,
    InstanceSignal,
    LLMEngine,
    Request,
    ServingCluster,
    ServingConfig,
    reset_request_ids,
)


@pytest.fixture(scope="module")
def model_and_params():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


# =============================================================================
# pure decision core
# =============================================================================


def _sig(now, queue=0, kv=(0.1,), fenced=(), load=None):
    inst = [InstanceSignal(instance_id=i, kv_used_frac=f,
                           fenced=(i in fenced),
                           load=(load[i] if load else 0.0))
            for i, f in enumerate(kv)]
    return ClusterSignals(now=now, queue_depth=queue, instances=inst)


def test_scale_up_needs_patience():
    a = Autoscaler(AutoscalerConfig(max_instances=4, queue_high=2.0,
                                    up_patience=3))
    assert a.decide(_sig(0.0, queue=9)) is None      # streak 1
    assert a.decide(_sig(1.0, queue=9)) is None      # streak 2
    assert a.decide(_sig(2.0, queue=9)) == ("up", -1)
    # a single calm window resets the streak
    a2 = Autoscaler(AutoscalerConfig(queue_high=2.0, up_patience=2))
    a2.decide(_sig(0.0, queue=9))
    a2.decide(_sig(1.0, queue=0))                    # calm: reset
    assert a2.decide(_sig(2.0, queue=9)) is None     # back to streak 1


def test_kv_pressure_alone_scales_up():
    a = Autoscaler(AutoscalerConfig(kv_high=0.85, up_patience=1))
    assert a.decide(_sig(0.0, queue=0, kv=(0.2, 0.9))) == ("up", -1)


def test_bounds_are_respected():
    a = Autoscaler(AutoscalerConfig(min_instances=1, max_instances=2,
                                    up_patience=1, down_patience=1))
    assert a.decide(_sig(0.0, queue=99, kv=(0.1, 0.1))) is None, \
        "already at max_instances"
    assert a.decide(_sig(1.0, queue=0, kv=(0.1,))) is None, \
        "already at min_instances"


def test_scale_down_needs_sustained_calm_and_cooldown_freezes():
    cfg = AutoscalerConfig(min_instances=1, queue_high=2.0, up_patience=1,
                           down_patience=2, cooldown_s=5.0)
    a = Autoscaler(cfg)
    assert a.decide(_sig(0.0, queue=9, kv=(0.1, 0.1))) == ("up", -1)
    a.note_action(0.0, "up", 2, 3)                   # starts the freeze
    # frozen: even sustained calm decides nothing...
    assert a.decide(_sig(1.0, kv=(0.1,) * 3)) is None
    assert a.decide(_sig(2.0, kv=(0.1,) * 3)) is None
    # ...but the streak kept counting through the freeze, so the first
    # unfrozen window can act immediately
    assert a.decide(_sig(6.0, kv=(0.1,) * 3)) == ("down", 0)


def test_pick_victim_prefers_fenced_then_least_loaded():
    sig = _sig(0.0, kv=(0.3, 0.6, 0.2), fenced=(1,), load=[5.0, 9.0, 1.0])
    assert Autoscaler.pick_victim(sig) == 1, \
        "an OOM-fenced instance is the cheapest capacity to give back"
    sig = _sig(0.0, kv=(0.3, 0.6, 0.2), load=[5.0, 9.0, 1.0])
    assert Autoscaler.pick_victim(sig) == 2, "else least loaded wins"


# =============================================================================
# real cluster elasticity
# =============================================================================


_CFG = ServingConfig(num_blocks=32, block_size=8, max_batch=2,
                     n_instances=1, policy="fcfs")


def _orch(num_blocks=32):
    return Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0, kv_capacity_tokens=num_blocks * 8))


def _reqs(n, max_new=4, plen=12, seed=2):
    rng = np.random.default_rng(seed)
    return [Request(agent_name="a", msg_id=f"m{i}", prompt_len=plen,
                    prompt_tokens=rng.integers(0, 500, plen).astype(np.int32),
                    max_new_tokens=max_new, arrival_time=float(i))
            for i in range(n)]


def _drain(cluster, max_steps=4000):
    done = []
    for _ in range(max_steps):
        done.extend(cluster.step())
        if not cluster.has_work:
            break
    cluster.close()
    assert not cluster.has_work
    return done


def test_scale_up_mints_routable_engine(model_and_params):
    """The config factory's engine is a first-class instance: fresh id,
    shared compiled fns, private pool, and the dispatcher places work on
    it."""
    model, params = model_and_params
    reset_request_ids()
    cluster = ServingCluster.from_config(model, params, _orch(), _CFG)
    iid = cluster.scale_up()
    assert iid == 1 and cluster.n_instances == 2
    r0, r1 = (e.runner for e in cluster.engines)
    assert r0._fused_fn is r1._fused_fn and r0.pool is not r1.pool
    for q in _reqs(8):
        cluster.submit(q)
    done = _drain(cluster)
    assert len(done) == 8
    admitted = [e.stats.n_admitted for e in cluster.engines]
    assert all(a > 0 for a in admitted), \
        f"dispatcher must route to the new instance too: {admitted}"
    assert cluster.metrics_snapshot()["n_instances"] == 2.0


def test_scale_down_mid_flight_is_lossless(model_and_params):
    """Retiring an instance mid-decode finishes every submitted request
    (migrated or requeued, nothing dropped) and counts the migrations."""
    model, params = model_and_params
    reset_request_ids()
    cluster = ServingCluster.from_config(
        model, params, _orch(64),
        ServingConfig(num_blocks=64, block_size=8, max_batch=4,
                      n_instances=2, policy="fcfs"))
    # 4 requests across 2 engines (max_batch=4): the survivor always has
    # batch slots left, so retirement drains via real migration
    reqs = _reqs(4, max_new=12)
    for q in reqs:
        cluster.submit(q)
    done = [r for _ in range(3) for r in cluster.step()]
    busy = max(cluster.engines, key=lambda e: len(e.sched.running))
    assert busy.sched.running, "need in-flight work to make the test real"
    done += cluster.scale_down(busy.instance_id)
    assert cluster.n_instances == 1
    done += _drain(cluster)
    assert sorted(r.msg_id for r in done) == sorted(r.msg_id for r in reqs)
    snap = cluster.metrics_snapshot()
    assert snap["n_migrations"] >= 1 and snap["migrated_bytes"] > 0


def test_scale_down_clears_oom_fence_and_keeps_completions(model_and_params):
    """REGRESSION: retiring an OOM-fenced instance must (a) surface the
    completions of its in-flight iteration, (b) requeue/migrate the rest
    losslessly, (c) kill the fence — a later scale_up reusing the id
    starts unfenced and receives placements."""
    model, params = model_and_params
    reset_request_ids()
    cluster = ServingCluster.from_config(
        model, params, _orch(64),
        ServingConfig(num_blocks=64, block_size=8, max_batch=4,
                      n_instances=2, policy="fcfs"))
    victim = cluster._by_id[1]
    # plant work directly on the victim: one request about to finish
    # (its pending collect must surface from scale_down), one mid-decode
    finisher, runner_up = _reqs(2, max_new=1)[0], _reqs(2, max_new=16)[1]
    victim.submit(finisher)
    victim.submit(runner_up)
    victim.step()                        # prefill + sample first tokens
    victim.dispatch_iteration()          # in-flight: this one FINISHES
    assert victim.has_pending            # finisher (max_new=1)
    now = cluster.clock()
    cluster.dispatcher.on_oom(1, now)    # fence it, like a real OOM would
    assert cluster.dispatcher.is_fenced(1, now)
    finished = cluster.scale_down(1, now)
    assert finisher in finished, \
        "the in-flight iteration's completion was dropped"
    assert not cluster.dispatcher.is_fenced(1, now), \
        "the OOM fence must die with the instance"
    # the mid-decode request survived somewhere (migrated or requeued)
    survivor = cluster.engines[0]
    assert (runner_up in survivor.sched.running
            or runner_up in survivor.sched.waiting
            or runner_up in cluster.balancer.queue)
    # reuse the retired id: the new instance starts unfenced + routable
    fresh = LLMEngine(survivor.runner.clone(), instance_id=1, max_batch=4)
    assert cluster.scale_up(fresh, now) == 1
    assert not cluster.dispatcher.is_fenced(1, cluster.clock())
    done = _drain(cluster)
    assert runner_up in done, "requeued work must still complete"


def test_autoscaler_closed_loop_grows_and_shrinks(model_and_params):
    """End-to-end on real engines with a fake clock: queue pressure grows
    the fleet, the post-burst calm shrinks it back to min_instances, and
    nothing is lost along the way."""
    model, params = model_and_params
    t = {"now": 0.0}
    reset_request_ids()
    cluster = ServingCluster.from_config(
        model, params, _orch(), _CFG, clock=lambda: t["now"])
    cluster.attach_autoscaler(Autoscaler(AutoscalerConfig(
        min_instances=1, max_instances=3, queue_high=2.0, queue_low=0.5,
        up_patience=2, down_patience=3, decision_period_s=0.2,
        cooldown_s=0.2)))
    reqs = _reqs(10, max_new=6)
    for q in reqs:
        cluster.submit(q)
    done = []
    for _ in range(4000):
        t["now"] += 0.25                 # every step is a decision window
        done.extend(cluster.step())
        if not cluster.has_work:
            break
    hist = cluster.autoscaler.history
    assert any(k == "up" for _, k, _, _ in hist), \
        f"queue pressure never scaled up: {hist}"
    assert max(n for _, _, _, n in hist) >= 2
    # drain the calm tail until the fleet shrinks back
    for _ in range(200):
        t["now"] += 0.25
        done.extend(cluster.step())
        if cluster.n_instances == 1 and not cluster.has_work:
            break
    cluster.close()
    assert cluster.n_instances == 1, "calm must shrink back to min"
    assert any(k == "down" for _, k, _, _ in hist)
    assert sorted(r.msg_id for r in done) == sorted(r.msg_id for r in reqs)


# =============================================================================
# simulator elasticity
# =============================================================================


def _elastic_sim(seed=3):
    from repro.sim.simulator import Simulation
    from repro.workloads.traces import bursty_trace
    trace = bursty_trace(seed=seed, duration=24.0, base_rate=2.0,
                         burst_mult=6.0)
    cfg = trace.sim_config(
        ServingConfig(num_blocks=512, block_size=16, max_batch=32,
                      policy="kairos", n_instances=2),
        autoscale=AutoscalerConfig(min_instances=2, max_instances=5,
                                   queue_high=3.0, queue_low=0.5,
                                   up_patience=2, down_patience=6,
                                   decision_period_s=0.25, cooldown_s=1.0))
    return Simulation(cfg).run()


def test_sim_elastic_run_is_deterministic_and_scales():
    a, b = _elastic_sim(), _elastic_sim()
    assert a.scale_history, "the burst must trigger scaling"
    assert any(k == "up" for _, k, _, _ in a.scale_history)
    assert a.scale_history == b.scale_history
    assert a.summary() == b.summary()
    assert a.instance_seconds > 0
    s = a.summary()
    assert s["n_workflows"] > 0 and s["n_migrated"] >= 0
