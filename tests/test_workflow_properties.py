"""Property-based tests on workflow analysis and the priority embedding."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import assume, given, settings

from repro.core.priority import agent_priorities, classical_mds_1d
from repro.core.workflow import _sweepline_parallel


@settings(max_examples=80, deadline=None)
@given(spans=st.lists(
    st.tuples(st.floats(0, 100), st.floats(0.01, 20)), min_size=2, max_size=8))
def test_sweepline_matches_bruteforce(spans):
    spans = [(f"a{i}", s, s + d) for i, (s, d) in enumerate(spans)]
    got = _sweepline_parallel(spans)
    expect = set()
    for i, (ni, si, ei) in enumerate(spans):
        for j, (nj, sj, ej) in enumerate(spans):
            if i != j and si < ej and sj < ei:
                expect.add(ni)
                expect.add(nj)
    assert got == expect


@settings(max_examples=30, deadline=None)
@given(means=st.lists(st.floats(0.1, 100.0), min_size=2, max_size=8, unique=True))
def test_priority_order_matches_mean_remaining(means):
    """For well-separated unimodal distributions, the MDS priority order
    must equal the order of mean remaining latency."""
    ms = sorted(means)
    assume(all(b / a >= 1.2 for a, b in zip(ms, ms[1:])))  # well-separated
    rng = np.random.default_rng(0)
    samples = {("app", f"a{i}"): (rng.normal(m, 0.01 * m, 128)).tolist()
               for i, m in enumerate(means)}
    pr = agent_priorities(samples)
    order_by_priority = sorted(range(len(means)), key=lambda i: pr[("app", f"a{i}")])
    order_by_mean = sorted(range(len(means)), key=lambda i: means[i])
    assert order_by_priority == order_by_mean


@settings(max_examples=30, deadline=None)
@given(pts=st.lists(st.floats(-50, 50), min_size=2, max_size=10))
def test_mds_preserves_line_distances(pts):
    pts = np.asarray(pts)
    d = np.abs(pts[:, None] - pts[None, :])
    c = classical_mds_1d(d)
    np.testing.assert_allclose(np.abs(c[:, None] - c[None, :]), d, atol=1e-6)
