"""Fault plane: deterministic injection, crash recovery with
bit-identical replay, and SLO-aware load shedding.

Four layers:

* the PURE pieces — :class:`FaultPlan` generation is a pure function of
  (seed, shape); :class:`FaultInjector` replays a plan identically from
  per-instance dispatch/transfer ordinals; :class:`RecoveryManager`'s
  retry budget, backoff timing, and replay bookkeeping run against stub
  clusters with no model in sight; the :class:`LoadShedder` valve opens
  only under sustained overload and picks deadline-hopeless victims
  first;
* the REAL cluster — a planned mid-drain crash storm loses zero
  requests and every recovered stream is bit-identical to the
  fault-free drain (argmax replay via prompt+emitted re-prefill);
* the PROPERTY — for *any* seeded fault plan that spares one instance,
  the recovered drain equals the fault-free drain exactly: no request
  lost, none duplicated, no token differs (hypothesis when available,
  seeded parametrization otherwise);
* the SIMULATOR — the same plan classes drive the discrete-event sim
  (shared recovery/shedding code), faulted runs are deterministic, and
  shedding under overload keeps goodput-under-SLO strictly above the
  no-shedding baseline.
"""
import jax
import numpy as np
import pytest

from repro.serving import (
    DispatchEffects,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    LoadShedder,
    RecoveryManager,
    Request,
    RequestState,
    ServingCluster,
    ServingConfig,
    reset_request_ids,
)
from repro.sim.cost_model import CostModel

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is a tier-1 dep
    HAVE_HYPOTHESIS = False


# =============================================================================
# pure: plans and injectors
# =============================================================================


def test_fault_plan_generation_is_deterministic():
    kw = dict(horizon=16, n_crashes=2, n_straggles=3, n_ooms=2,
              n_transfer_faults=1, spare=(0,))
    a = FaultPlan.generate(17, [0, 1, 2, 3], **kw)
    b = FaultPlan.generate(17, [0, 1, 2, 3], **kw)
    assert a.specs == b.specs and len(a) == 8
    # spared instances never crash; nobody crashes twice
    crash_ids = [s.instance_id for s in a.crashes()]
    assert 0 not in crash_ids
    assert len(crash_ids) == len(set(crash_ids))
    # a different seed names different chaos
    c = FaultPlan.generate(18, [0, 1, 2, 3], **kw)
    assert c.specs != a.specs


def test_fault_plan_crashes_capped_by_crashable_instances():
    plan = FaultPlan.generate(3, [0, 1], n_crashes=5, spare=(0,))
    assert len(plan.crashes()) == 1
    assert plan.crashes()[0].instance_id == 1


def test_fault_injector_fires_at_planned_ordinals_and_replays():
    plan = FaultPlan((
        FaultSpec("crash", instance_id=1, step=2),
        FaultSpec("straggle", instance_id=0, step=1, delay_s=0.2, factor=3.0),
        FaultSpec("straggle", instance_id=0, step=1, delay_s=0.1, factor=2.0),
        FaultSpec("oom", instance_id=0, step=1),
        FaultSpec("transfer", instance_id=0, step=1),
    ))

    def run():
        inj = FaultInjector(plan)
        effects = []
        for step in range(4):
            for iid in (0, 1):
                effects.append((iid, step, inj.on_dispatch(iid)))
        transfers = [inj.transfer_fault(0) for _ in range(3)]
        return inj, effects, transfers

    inj, effects, transfers = run()
    by_point = {(iid, step): eff for iid, step, eff in effects}
    # steps without a planned fault are no-ops
    assert by_point[(0, 0)] == DispatchEffects()
    # both straggles and the oom land on (0, 1), combined
    eff = by_point[(0, 1)]
    assert eff.oom and eff.crash is None
    assert eff.delay_s == pytest.approx(0.3)
    assert eff.factor == pytest.approx(6.0)
    # the crash fires exactly at (1, 2)
    assert by_point[(1, 2)].crash is not None
    assert by_point[(1, 3)].crash is None
    # transfer ordinal 1 (the second outbound transfer) faults, once
    assert transfers[0] is None and transfers[2] is None
    assert transfers[1] is not None and transfers[1].kind == "transfer"
    assert inj.n_fired == len(plan)
    # a fresh injector over the same plan replays identically
    _, effects2, transfers2 = run()
    assert effects2 == effects
    assert transfers2 == transfers


# =============================================================================
# pure: recovery manager on a stub cluster
# =============================================================================


class _StubModel:
    def __init__(self, iid):
        self.instance_id = iid
        self.fenced_until = 0.0


class _StubDispatcher:
    def __init__(self):
        self.fenced = []
        self.removed = []
        self._models = {}

    def on_oom(self, iid, now):
        self.fenced.append((iid, now))

    def remove_instance(self, iid):
        self.removed.append(iid)
        return self._models.setdefault(iid, _StubModel(iid))


class _StubBalancer:
    def __init__(self):
        self.queue = []

    def enqueue(self, req):
        self.queue.append(req)


class _StubSched:
    def __init__(self, waiting=(), running=()):
        self.waiting = list(waiting)
        self.running = list(running)


class _StubEngine:
    def __init__(self, iid, running):
        self.instance_id = iid
        self.sched = _StubSched(running=running)


class _StubCluster:
    def __init__(self):
        self.dispatcher = _StubDispatcher()
        self.balancer = _StubBalancer()
        self.discarded = []

    def discard_engine(self, engine):
        self.discarded.append(engine.instance_id)


def _req(msg_id, prompt, emitted=(), max_new=8, arrival=0.0):
    r = Request(agent_name="a", msg_id=msg_id, prompt_len=len(prompt),
                prompt_tokens=np.asarray(prompt, dtype=np.int32),
                max_new_tokens=max_new, arrival_time=arrival)
    r.output_tokens.extend(int(t) for t in emitted)
    r.output_len = len(r.output_tokens)
    r.prefilled_len = r.prompt_len
    return r


def test_recovery_reconstructs_with_extended_prompt_and_unwinds():
    rm = RecoveryManager(max_retries=3)
    cluster = _StubCluster()
    req = _req("m0", [1, 2, 3], emitted=[7, 8], max_new=8)
    failed = rm.on_crash(cluster, _StubEngine(0, [req]), now=1.0)
    assert failed == [] and rm.n_crashes == 1 and rm.n_reconstructed == 1
    # fenced + removed + discarded, re-queued on the balancer
    assert cluster.dispatcher.fenced == [(0, 1.0)]
    assert cluster.dispatcher.removed == [0] and cluster.discarded == [0]
    assert cluster.dispatcher._models[0].fenced_until == float("inf")
    assert cluster.balancer.queue == [req]
    # the request re-prefills prompt + emitted, budget shrunk to match
    assert req.state is RequestState.QUEUED
    assert list(req.prompt_tokens) == [1, 2, 3, 7, 8]
    assert req.prompt_len == 5 and req.max_new_tokens == 6
    assert req.output_len == 0 and not req.output_tokens
    assert rm.n_replayed_tokens == 2
    # finish: replay re-emitted verbatim, original identity restored
    req.output_tokens.extend([9, 10])
    rm.on_finish(req)
    assert list(req.output_tokens) == [7, 8, 9, 10]
    assert req.prompt_len == 3 and req.max_new_tokens == 8
    assert list(req.prompt_tokens) == [1, 2, 3]


def test_recovery_retry_budget_exhausts_to_failed():
    rm = RecoveryManager(max_retries=1)
    cluster = _StubCluster()
    req = _req("m0", [1, 2, 3])
    assert rm.on_crash(cluster, _StubEngine(0, [req]), now=0.0) == []
    failed = rm.on_crash(cluster, _StubEngine(1, [req]), now=1.0)
    assert failed == [req] and req.state is RequestState.FAILED
    assert req.finish_time == 1.0 and rm.n_failed == 1
    assert len(cluster.balancer.queue) == 1  # only the first crash re-queued


def test_recovery_backoff_delays_requeue_exponentially():
    rm = RecoveryManager(max_retries=4, backoff_s=0.5)
    cluster = _StubCluster()
    req = _req("m0", [1, 2, 3])
    rm.on_crash(cluster, _StubEngine(0, [req]), now=10.0)
    assert cluster.balancer.queue == [] and rm.pending == 1
    assert rm.backoff_deadlines == [10.5]
    rm.tick(cluster, now=10.4)
    assert cluster.balancer.queue == [] and rm.pending == 1
    rm.tick(cluster, now=10.5)
    assert cluster.balancer.queue == [req] and rm.pending == 0
    # second crash: delay doubles
    rm.on_crash(cluster, _StubEngine(1, [req]), now=20.0)
    assert rm.backoff_deadlines == [21.0]


def test_step_deadline_fences_stragglers():
    rm = RecoveryManager(step_deadline_s=0.25)
    cluster = _StubCluster()
    eng = _StubEngine(2, [])
    assert not rm.check_step_deadline(cluster, eng, elapsed_s=0.2, now=1.0)
    assert rm.check_step_deadline(cluster, eng, elapsed_s=0.9, now=2.0)
    assert cluster.dispatcher.fenced == [(2, 2.0)]
    assert rm.n_straggler_fences == 1
    # no deadline configured -> never fences
    assert not RecoveryManager().check_step_deadline(
        cluster, eng, elapsed_s=99.0, now=3.0)


# =============================================================================
# pure: the shedding valve
# =============================================================================


def test_shedder_opens_only_under_sustained_overload():
    sh = LoadShedder(slo_e2e_s=10.0, cost=CostModel(), queue_high=4.0,
                     patience=3)
    assert not sh.observe(99, n_instances=2, max_kv_frac=0.1)   # streak 1
    assert not sh.observe(99, n_instances=2, max_kv_frac=0.1)   # streak 2
    assert not sh.observe(0, n_instances=2, max_kv_frac=0.1)    # calm: reset
    assert not sh.observe(99, n_instances=2, max_kv_frac=0.1)
    assert not sh.observe(99, n_instances=2, max_kv_frac=0.1)
    assert sh.observe(99, n_instances=2, max_kv_frac=0.1)       # open
    # KV pressure with a non-empty queue counts as overload too
    sh2 = LoadShedder(slo_e2e_s=10.0, cost=CostModel(), patience=1)
    assert sh2.observe(1, n_instances=4, max_kv_frac=0.99)
    # ... but an empty queue never does (nothing to shed)
    sh3 = LoadShedder(slo_e2e_s=10.0, cost=CostModel(), patience=1)
    assert not sh3.observe(0, n_instances=4, max_kv_frac=0.99)


def test_shedder_picks_hopeless_then_lowest_slack():
    cost = CostModel()
    sh = LoadShedder(slo_e2e_s=5.0, cost=cost, queue_high=2.0, patience=1)
    now = 100.0
    hopeless = _req("old", [1] * 8, max_new=16, arrival=now - 60.0)
    fresh = [_req(f"f{i}", [1] * 8, max_new=16, arrival=now - 0.1 * i)
             for i in range(4)]
    queue = [hopeless] + fresh
    assert sh.select(queue, now, n_instances=1) == []  # valve still closed
    sh.observe(len(queue), n_instances=1, max_kv_frac=0.5)
    victims = sh.select(queue, now, n_instances=1)
    # the deadline-hopeless request goes first; then the overflow past
    # the valve line (2 * 1 instance), lowest slack (= oldest) first
    assert victims[0] is hopeless
    assert len(victims) == 1 + (len(fresh) - 2)
    victim_ids = {v.msg_id for v in victims}
    assert victim_ids == {"old", "f3", "f2"}
    sh.shed(hopeless, now, queue_depth=len(queue))
    assert hopeless.state is RequestState.SHED and sh.n_shed == 1


# =============================================================================
# real cluster: crash storms recover bit-identically
# =============================================================================


@pytest.fixture(scope="module")
def model_and_params():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


def _orch(num_blocks=64, block_size=8):
    from repro.core import Orchestrator
    from repro.core.orchestrator import HardwareProfile
    return Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0, kv_capacity_tokens=num_blocks * block_size))


_CHAOS_CFG = ServingConfig(num_blocks=64, block_size=8, max_batch=4,
                           n_instances=3, policy="fcfs",
                           prefix_caching=True, recovery_retries=3)


def _chaos_reqs(n=8, max_new=10):
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, 500, 16).astype(np.int32)
    out = []
    for i in range(n):
        toks = np.concatenate(
            [prefix, rng.integers(0, 500, 5 + (i % 9)).astype(np.int32)])
        out.append(Request(agent_name=f"a{i % 3}", msg_id=f"m{i}",
                           prompt_len=len(toks), prompt_tokens=toks,
                           max_new_tokens=max_new, arrival_time=float(i)))
    return out


def _drain(cluster):
    done = []
    for _ in range(100_000):
        done.extend(cluster.step())
        if not cluster.has_work:
            break
    cluster.close()
    return done


def _fault_free_streams(model, params):
    reset_request_ids()
    cluster = ServingCluster.from_config(model, params, _orch(), _CHAOS_CFG)
    for q in _chaos_reqs():
        cluster.submit(q)
    return {r.msg_id: list(r.output_tokens) for r in _drain(cluster)}


@pytest.fixture(scope="module")
def chaos_baseline(model_and_params):
    model, params = model_and_params
    base = _fault_free_streams(model, params)
    assert len(base) == 8
    return base


def _assert_plan_recovers(model, params, base, plan):
    """The chaos oracle: under ``plan``, the drain loses no request,
    duplicates none, and every stream matches the fault-free drain."""
    reset_request_ids()
    cluster = ServingCluster.from_config(model, params, _orch(), _CHAOS_CFG,
                                         faults=plan)
    for q in _chaos_reqs():
        cluster.submit(q)
    done = _drain(cluster)
    failed = [r.msg_id for r in done if r.state is RequestState.FAILED]
    assert not failed, f"requests failed under plan {plan.specs}: {failed}"
    streams = {}
    for r in done:
        assert r.msg_id not in streams, f"request {r.msg_id} duplicated"
        streams[r.msg_id] = list(r.output_tokens)
    assert set(streams) == set(base), \
        f"lost/extra requests: {set(base) ^ set(streams)}"
    mismatched = [m for m in base if streams[m] != base[m]]
    assert not mismatched, \
        f"recovered streams diverged for {mismatched} under {plan.specs}"
    return cluster.metrics_snapshot()


def test_cluster_crash_storm_recovers_bit_identically(model_and_params,
                                                      chaos_baseline):
    model, params = model_and_params
    plan = FaultPlan.generate(5, [0, 1, 2], horizon=10, n_crashes=2,
                              spare=(0,))
    snap = _assert_plan_recovers(model, params, chaos_baseline, plan)
    assert snap["n_crashes"] == 2
    assert snap["n_instances"] == 1          # both victims stay removed
    assert snap["n_reconstructed"] >= snap["n_crashes"]
    assert snap["n_recovery_failed"] == 0


if HAVE_HYPOTHESIS:

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 9999))
    def test_any_fault_plan_recovers_bit_identically(model_and_params,
                                                     chaos_baseline, seed):
        model, params = model_and_params
        plan = FaultPlan.generate(seed, [0, 1, 2], horizon=12, n_crashes=2,
                                  n_straggles=1, n_ooms=1, spare=(0,),
                                  straggle_delay_s=0.01)
        _assert_plan_recovers(model, params, chaos_baseline, plan)

else:  # pragma: no cover - hypothesis is a tier-1 dep

    @pytest.mark.parametrize("seed", [0, 7, 123, 2024])
    def test_any_fault_plan_recovers_bit_identically(model_and_params,
                                                     chaos_baseline, seed):
        model, params = model_and_params
        plan = FaultPlan.generate(seed, [0, 1, 2], horizon=12, n_crashes=2,
                                  n_straggles=1, n_ooms=1, spare=(0,),
                                  straggle_delay_s=0.01)
        _assert_plan_recovers(model, params, chaos_baseline, plan)


# =============================================================================
# simulator: shared fault plane, deterministic chaos, shedding goodput
# =============================================================================


def _sim_kw(**over):
    from repro.sim.workload import make_app
    kw = dict(apps=[make_app("QA", "G+M")], policy="kairos", rate=4.0,
              duration=10.0, n_instances=3, kv_capacity_tokens=4096,
              block_size=16, max_batch=8, seed=1)
    kw.update(over)
    return kw


def test_sim_faulted_run_loses_nothing_and_is_deterministic():
    from repro.sim.simulator import SimConfig, Simulation
    plan = FaultPlan.generate(3, [0, 1, 2], horizon=12, n_crashes=1,
                              n_straggles=1, n_ooms=1, spare=(0,))
    kw = _sim_kw()
    res = Simulation(SimConfig(faults=plan, recovery_backoff_s=0.1,
                               **kw)).run()
    assert res.n_crashes == 1 and res.n_lost == 0
    assert res.n_reconstructed >= 1
    # every workflow the fault-free run completes, the faulted run does too
    res0 = Simulation(SimConfig(**kw)).run()
    assert len(res.workflows) == len(res0.workflows)
    # same plan, fresh sim -> identical summary (replayable chaos)
    res2 = Simulation(SimConfig(faults=plan, recovery_backoff_s=0.1,
                                **kw)).run()
    assert res2.summary() == res.summary()


def test_sim_shedding_beats_no_shedding_goodput_under_overload():
    from repro.sim.simulator import SimConfig, Simulation
    kw = _sim_kw(rate=12.0, duration=20.0, n_instances=2,
                 kv_capacity_tokens=3072, seed=3)
    slo = 12.0
    res_off = Simulation(SimConfig(**kw)).run()
    res_on = Simulation(SimConfig(slo_e2e_s=slo, shed_queue_high=4.0,
                                  **kw)).run()
    assert res_on.n_shed > 0, "valve never opened under overload"
    assert res_off.n_shed == 0
    # the acceptance oracle: goodput-under-SLO strictly above baseline
    assert res_on.goodput(slo) > res_off.goodput(slo), \
        (res_on.goodput(slo), res_off.goodput(slo))
