"""Property tests for the serving sharding rules + sim cost parity.

* ``param_pspec`` swept over EVERY registered architecture (reduced
  shapes) x model-parallel degrees {1, 2, 4}: every returned spec must
  address exactly the leaf's rank (or be fully replicated), and every
  sharded dim must divide by the mesh axis size — ``param_pspec``
  prefers explicit replication over GSPMD padding, so a non-dividing
  spec is a rule bug, not a runtime choice.
* ``serving_param_specs`` replicates everything outside the layer stack
  (argmax-only serving head; see models/sharding.py).
* ``validate_serving_tp`` rejects configs a megatron shard_map step
  cannot split exactly (the silent-replication double-psum hazard).
* Sim parity (satellite of the sharding PR): ``SimConfig.tp_degree``
  defaults to 1 and ``CostModel.iteration_time`` at ``tp_degree=1`` is
  numerically IDENTICAL to the pre-sharding formula, so every committed
  BENCH baseline and fig trajectory is unchanged.

These run on any device count: meshes are stand-ins exposing only the
``shape`` / ``axis_names`` surface ``param_pspec`` consults.
"""
import dataclasses
from types import SimpleNamespace

import jax
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.sharding import (param_pspec, serving_param_specs,
                                   validate_serving_tp)


def _fake_mesh(mp: int, data: int = 1):
    """Duck-typed mesh: param_pspec reads mesh.shape[name] and
    mesh.axis_names only, so spec rules are testable on a 1-device
    host at any model-parallel degree."""
    return SimpleNamespace(shape={"data": data, "model": mp},
                           axis_names=("data", "model"))


def _abstract_params(arch: str):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    return cfg, jax.eval_shape(model.init_params, jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mp", [1, 2, 4])
def test_param_pspec_valid_rank_and_divisibility(arch, mp):
    cfg, params = _abstract_params(arch)
    mesh = _fake_mesh(mp)

    def check(path, leaf):
        keys = tuple(str(getattr(k, "key", getattr(k, "idx", None)))
                     for k in path)
        spec = param_pspec(keys, leaf, cfg, mesh)
        assert len(spec) in (0, leaf.ndim), \
            f"{arch} mp={mp} {keys}: spec {spec} vs rank {leaf.ndim}"
        for ax, dim in zip(spec, leaf.shape):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, \
                f"{arch} mp={mp} {keys}: dim {dim} not divisible by {ax}={size}"

    jax.tree_util.tree_map_with_path(check, params)


@pytest.mark.parametrize("mp", [2, 4])
def test_serving_specs_replicate_outside_layer_stack(mp):
    cfg, params = _abstract_params("qwen3-1.7b")
    specs = serving_param_specs(params, cfg, _fake_mesh(mp))

    def check(path, spec):
        keys = tuple(str(getattr(k, "key", getattr(k, "idx", None)))
                     for k in path)
        if "layers" not in keys:
            assert spec == jax.sharding.PartitionSpec(), \
                f"non-layer param {keys} must be replicated, got {spec}"

    jax.tree_util.tree_map_with_path(check, specs)
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert any("model" in (spec or ()) for spec in flat), \
        "layer stack must actually shard something over 'model'"


def test_validate_serving_tp_rejects_non_dividing_and_moe():
    cfg = get_config("qwen3-1.7b").reduced()     # 4 heads / 2 kv heads
    validate_serving_tp(cfg, 1)
    validate_serving_tp(cfg, 2)
    with pytest.raises(ValueError, match="num_kv_heads|num_heads"):
        validate_serving_tp(cfg, 4)
    wide = dataclasses.replace(cfg, num_heads=8, num_kv_heads=4, head_dim=64)
    validate_serving_tp(wide, 4)
    with pytest.raises(ValueError, match="d_ff"):
        validate_serving_tp(dataclasses.replace(wide, d_ff=510), 4)
    moe = get_config("qwen2-moe-a2.7b").reduced()
    with pytest.raises(ValueError, match="MoE"):
        validate_serving_tp(moe, 2)


# =============================================================================
# sim cost parity at tp_degree=1 (committed baselines must not move)
# =============================================================================


def test_cost_model_tp1_numerically_unchanged():
    from repro.sim.cost_model import COST_MODELS
    for m in COST_MODELS.values():
        for args in [(8, 0, 0, 0, False, 0), (3, 120, 64, 2, True, 0),
                     (0, 256, 0, 4, False, 10 ** 9)]:
            n, p, c, s, fused, hbm = args
            legacy = (m.t_base + m.beta * n + m.gamma * p
                      + m.gamma_cached * c
                      + (m.beta_seg_fused if fused else m.beta_prefill) * s
                      + hbm / (m.hbm_gbps * 1e9))
            got = m.iteration_time(n, p, c, n_prefill_seqs=s, fused=fused,
                                   hbm_bytes=hbm)
            assert got == legacy, (m.name, args)
            assert got == m.iteration_time(
                n, p, c, n_prefill_seqs=s, fused=fused, hbm_bytes=hbm,
                tp_degree=1)


def test_cost_model_tp2_faster_but_collective_bounded():
    from repro.sim.cost_model import LLAMA3_8B as m
    t1 = m.iteration_time(8, 64)
    t2 = m.iteration_time(8, 64, tp_degree=2)
    # compute halves, t_base and the all-reduce term don't: strictly
    # between the full cost and a naive t/2
    assert t1 / 2 < t2 < t1
    # collective term grows with the ring factor 2(tp-1)/tp
    t4 = m.iteration_time(8, 64, tp_degree=4)
    assert t4 < t2


def test_sim_config_tp_default_and_threading():
    from repro.sim import SimConfig, Simulation, make_app
    assert SimConfig(apps=[]).tp_degree == 1
    base = Simulation(SimConfig(apps=[make_app("QA", "G+M")], rate=3.0,
                                duration=12.0, n_instances=2,
                                seed=0)).run().summary()
    tp2 = Simulation(SimConfig(apps=[make_app("QA", "G+M")], rate=3.0,
                               duration=12.0, n_instances=2, seed=0,
                               tp_degree=2)).run().summary()
    assert tp2["n_workflows"] > 0
    # sharded instances iterate faster -> mean latency must not regress
    assert tp2["avg"] <= base["avg"]
