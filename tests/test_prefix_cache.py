"""Shared-prefix KV reuse: block-manager sharing unit tests, the
hash-indexed prefix cache, engine-level cache-hit correctness (identical
tokens vs a cold run), eviction under pressure, and the sim integration."""
import jax
import numpy as np
import pytest

from repro.serving import BlockManager, LLMEngine, PagedModelRunner, Request
from repro.serving.prefix_cache import PrefixCache


# =============================================================================
# BlockManager sharing
# =============================================================================


def test_ref_acquire_release_lifecycle():
    bm = BlockManager(8, 4)
    table = bm.allocate(1, 8)                  # 2 blocks, ref 1 each
    assert [bm.ref_count(b) for b in table] == [1, 1]
    bm.ref_acquire(table[0])                   # share with someone else
    assert bm.ref_count(table[0]) == 2 and bm.is_shared(table[0])
    bm.free(1)                                 # seq gone; shared block survives
    assert bm.ref_count(table[0]) == 1
    assert bm.free_blocks == 7                 # only the private block returned
    bm.ref_release(table[0])
    assert bm.free_blocks == 8


def test_cacheable_blocks_park_instead_of_freeing():
    bm = BlockManager(4, 2)
    table = bm.allocate(1, 4)
    bm.mark_cacheable(table[0])
    bm.free(1)
    assert bm.cached_blocks == 1 and bm.free_blocks == 3
    # parked KV can be re-acquired (a cache hit) ...
    bm.ref_acquire(table[0])
    assert bm.cached_blocks == 0 and bm.ref_count(table[0]) == 1
    bm.ref_release(table[0])
    # ... or reclaimed (eviction)
    bm.reclaim(table[0])
    assert bm.free_blocks == 4 and bm.cached_blocks == 0


def test_copy_on_write_duplicates_shared_block():
    bm = BlockManager(8, 4)
    t1 = bm.allocate(1, 8)
    bm.ref_acquire(t1[0])
    bm._owned[2] = [t1[0]]                     # second table shares block 0
    res = bm.copy_on_write(2, 0)
    assert res is not None and res[0] == t1[0]
    assert bm.block_table(2)[0] != t1[0]
    assert bm.ref_count(t1[0]) == 1            # original owner keeps it
    # private block: COW is a no-op
    assert bm.copy_on_write(1, 1) is None


def test_allocate_shared_seeds_table():
    bm = BlockManager(8, 4)
    t1 = bm.allocate(1, 8)
    bm.mark_cacheable(t1[0])
    bm.ref_acquire(t1[0])
    t2 = bm.allocate_shared(2, [t1[0]], 12)    # 1 shared + 2 fresh
    assert t2[0] == t1[0] and len(t2) == 3
    assert bm.ref_count(t1[0]) == 2
    bm.free(1)
    bm.free(2)
    assert bm.free_blocks + bm.cached_blocks == 8


# =============================================================================
# PrefixCache
# =============================================================================


def test_match_returns_longest_cached_prefix():
    bm = BlockManager(16, 4)
    cache = PrefixCache(4)
    toks = np.arange(13)
    hashes = cache.hash_tokens(toks, 4)        # 3 full blocks
    table = bm.allocate(1, 13)
    cache.insert(hashes[:2], table[:2], bm)    # only first two cached
    got = cache.match(hashes, bm)
    assert got == table[:2]
    for b in got:
        bm.ref_release(b)
    # diverging tokens match only the common prefix
    other = np.concatenate([np.arange(8), np.arange(50, 55)])
    got2 = cache.match(cache.hash_tokens(other, 4), bm)
    assert got2 == table[:2]
    for b in got2:
        bm.ref_release(b)


def test_eviction_is_lru_and_skips_referenced():
    bm = BlockManager(8, 2)
    cache = PrefixCache(2)
    ta = bm.allocate(1, 4)
    tb = bm.allocate(2, 4)
    ha = cache.key_chain("a", 2)
    hb = cache.key_chain("b", 2)
    cache.insert(ha, ta, bm)
    cache.insert(hb, tb, bm)
    bm.free(1)                                 # a's blocks park
    cache.match(ha, bm)                        # touch a -> b becomes coldest
    for b in ta:
        bm.ref_release(b)
    # b's blocks are still referenced by seq 2 -> not evictable
    assert cache.evict(bm, 4) == 2             # only a's two parked blocks
    assert bm.free_blocks == 4 + 2
    bm.free(2)
    assert cache.evict(bm, 4) == 2


def test_usable_prefix_caps_below_prompt_len():
    cache = PrefixCache(8)
    assert cache.usable_prefix_blocks(1) == 0
    assert cache.usable_prefix_blocks(8) == 0      # would cover whole prompt
    assert cache.usable_prefix_blocks(9) == 1
    assert cache.usable_prefix_blocks(17) == 2


def test_key_chain_is_prefix_consistent():
    a, b = PrefixCache.key_chain("app|agent", 4), PrefixCache.key_chain("app|agent", 2)
    assert a[:2] == b
    assert PrefixCache.key_chain("other", 2) != b


# =============================================================================
# Engine integration (real paged JAX engine, reduced model)
# =============================================================================


@pytest.fixture(scope="module")
def model_and_params():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


def _mk_engine(model_and_params, cache: bool, num_blocks: int = 64):
    model, params = model_and_params
    runner = PagedModelRunner(model, params, num_blocks=num_blocks,
                              block_size=8, max_batch=4)
    return LLMEngine(runner, instance_id=0, max_batch=4,
                     enable_prefix_cache=cache)


def _shared_prefix_reqs(n: int = 4, sys_len: int = 16, uniq: int = 6,
                        max_new: int = 4):
    rng = np.random.default_rng(11)
    sys_toks = rng.integers(0, 500, sys_len).astype(np.int32)
    reqs = []
    for i in range(n):
        toks = np.concatenate([sys_toks,
                               rng.integers(0, 500, uniq).astype(np.int32)])
        reqs.append(Request(agent_name="a", msg_id=f"m{i}", prompt_len=len(toks),
                            prompt_tokens=toks, max_new_tokens=max_new,
                            arrival_time=float(i)))
    return reqs


def test_cache_hit_generates_identical_tokens(model_and_params):
    outs = {}
    for cache in (False, True):
        eng = _mk_engine(model_and_params, cache)
        for r in _shared_prefix_reqs():
            eng.submit(r.__class__(agent_name=r.agent_name, msg_id=r.msg_id,
                                   prompt_len=r.prompt_len,
                                   prompt_tokens=r.prompt_tokens,
                                   max_new_tokens=r.max_new_tokens,
                                   arrival_time=r.arrival_time))
        done = eng.run_until_drained()
        assert len(done) == 4
        outs[cache] = sorted((d.msg_id, tuple(d.output_tokens)) for d in done)
        if cache:
            assert eng.stats.prefill_tokens_saved > 0
            assert eng.prefix_cache.stats.hits >= 3
        # all private memory returned; only parked cache blocks remain
        assert eng.bm.free_blocks + eng.bm.cached_blocks == eng.bm.num_blocks
    assert outs[False] == outs[True]


def test_cache_eviction_under_memory_pressure(model_and_params):
    # tiny pool: long decodes force eviction of parked prefix blocks
    eng = _mk_engine(model_and_params, True, num_blocks=16)
    for r in _shared_prefix_reqs(n=5, sys_len=16, uniq=4, max_new=24):
        eng.submit(r)
    done = eng.run_until_drained(max_steps=4000)
    assert len(done) == 5
    assert eng.prefix_cache.stats.n_evicted > 0 or eng.stats.n_preempted > 0
    assert eng.bm.free_blocks + eng.bm.cached_blocks == eng.bm.num_blocks


# =============================================================================
# Simulator integration
# =============================================================================


def test_sim_prefix_caching_saves_prefill_and_matches_workload():
    from repro.sim import SimConfig, Simulation, make_app, with_shared_prefixes
    apps = [with_shared_prefixes(make_app("QA", "G+M"), 96)]
    done = {}
    for pc in (False, True):
        cfg = SimConfig(apps=apps, policy="kairos", rate=3.0, duration=20.0,
                        n_instances=2, prefix_caching=pc, seed=5)
        res = Simulation(cfg).run()
        done[pc] = res
        assert res.summary()["n_workflows"] > 0
    assert done[False].prefill_tokens_saved == 0
    assert done[True].prefill_tokens_saved > 0
    assert done[True].prefill_savings > 0.2
    # same sampled workload either way (deterministic per-request RNG)
    assert len(done[False].workflows) == len(done[True].workflows)


def test_memory_ramp_discounts_shared_prefix():
    from repro.core.memory_model import make_ramp
    full = make_ramp(256, 2.0, 30.0, 0.0)
    disc = make_ramp(256, 2.0, 30.0, 0.0, shared_prefix_tokens=128)
    assert disc.p_tokens == full.p_tokens - 128
    assert disc.slope == full.slope


def test_orchestrator_ramp_uses_declared_prefix():
    from repro.core.orchestrator import Orchestrator
    req = Request(agent_name="a", msg_id="m", prompt_len=200,
                  shared_prefix_len=100)
    on = Orchestrator(prefix_caching=True).memory_ramp(req, 0.0)
    off = Orchestrator(prefix_caching=False).memory_ramp(req, 0.0)
    assert on.p_tokens < off.p_tokens
