"""End-to-end behaviour tests for the discrete-event reproduction harness:
the paper's headline orderings must hold (Kairos < Ayo < Parrot; priority
ablation is the dominant factor; preemption drops under packing)."""
import pytest

from repro.sim import colocated_apps, make_app, run_policy

RATE, DUR, SEED = 2.6, 100.0, 3
KW = dict(rate=RATE, duration=DUR, seed=SEED, max_batch=48)


@pytest.fixture(scope="module")
def results():
    apps = colocated_apps()
    return {p: run_policy(apps, p, **KW)
            for p in ["parrot", "ayo", "kairos", "w/o-priority"]}


def test_all_workflows_complete(results):
    ns = {p: len(r.workflows) for p, r in results.items()}
    assert len(set(ns.values())) == 1, f"workflow counts differ: {ns}"
    assert ns["kairos"] > 50


def test_kairos_beats_parrot(results):
    k = results["kairos"].summary()
    p = results["parrot"].summary()
    assert k["avg"] < p["avg"] * 0.85, (k["avg"], p["avg"])
    assert k["p99"] < p["p99"]


def test_kairos_beats_or_matches_ayo(results):
    k = results["kairos"].summary()
    a = results["ayo"].summary()
    assert k["avg"] < a["avg"] * 1.03, (k["avg"], a["avg"])


def test_priority_is_the_dominant_mechanism(results):
    """§7.6: removing priority scheduling costs far more than removing
    packing — w/o-priority should be much worse than full Kairos."""
    k = results["kairos"].summary()
    nop = results["w/o-priority"].summary()
    assert nop["avg"] > k["avg"] * 1.2


def test_kairos_reduces_preemption(results):
    assert results["kairos"].n_preempted < results["parrot"].n_preempted


def test_workload_identical_across_policies(results):
    """Deterministic per-request sampling: same total token work."""
    tok = {p: sum(w.total_tokens for w in r.workflows) for p, r in results.items()}
    assert len(set(tok.values())) == 1, tok


def test_single_app_qa():
    apps = [make_app("QA", "G+M")]
    k = run_policy(apps, "kairos", rate=6.0, duration=80.0, seed=5, max_batch=48)
    p = run_policy(apps, "parrot", rate=6.0, duration=80.0, seed=5, max_batch=48)
    assert k.summary()["avg"] < p.summary()["avg"]


def test_latency_distributions_learned():
    from repro.sim import SimConfig, Simulation
    cfg = SimConfig(apps=colocated_apps(), policy="kairos", **KW)
    sim = Simulation(cfg)
    sim.run()
    prof = sim.orch.profiler
    agents = prof.agents()
    assert "Router" in agents and "Engineer" in agents
    # Fig 3/4: Router's outputs/latency are far smaller than Engineer's
    assert prof.expected_output_len("Router") * 5 < prof.expected_output_len("Engineer")
    # priorities reflect remaining latency: entry agents have lower priority
    scores = sim.orch.priorities.scores
    assert scores[("CG[HE]", "QAEngineer")] < scores[("CG[HE]", "ProductManager")]
