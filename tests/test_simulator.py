"""End-to-end behaviour tests for the discrete-event reproduction harness:
the paper's headline orderings must hold (Kairos < Ayo < Parrot; priority
ablation is the dominant factor; preemption drops under packing)."""
import pytest

from repro.sim import colocated_apps, make_app, run_policy

RATE, DUR, SEED = 2.6, 100.0, 3
KW = dict(rate=RATE, duration=DUR, seed=SEED, max_batch=48)


@pytest.fixture(scope="module")
def results():
    apps = colocated_apps()
    return {p: run_policy(apps, p, **KW)
            for p in ["parrot", "ayo", "kairos", "w/o-priority"]}


def test_all_workflows_complete(results):
    ns = {p: len(r.workflows) for p, r in results.items()}
    assert len(set(ns.values())) == 1, f"workflow counts differ: {ns}"
    assert ns["kairos"] > 50


def test_kairos_beats_parrot(results):
    k = results["kairos"].summary()
    p = results["parrot"].summary()
    assert k["avg"] < p["avg"] * 0.85, (k["avg"], p["avg"])
    assert k["p99"] < p["p99"]


def test_kairos_beats_or_matches_ayo(results):
    k = results["kairos"].summary()
    a = results["ayo"].summary()
    assert k["avg"] < a["avg"] * 1.03, (k["avg"], a["avg"])


def test_priority_is_the_dominant_mechanism(results):
    """§7.6: removing priority scheduling costs far more than removing
    packing — w/o-priority should be much worse than full Kairos."""
    k = results["kairos"].summary()
    nop = results["w/o-priority"].summary()
    assert nop["avg"] > k["avg"] * 1.2


def test_kairos_reduces_preemption(results):
    assert results["kairos"].n_preempted < results["parrot"].n_preempted


def test_workload_identical_across_policies(results):
    """Deterministic per-request sampling: same total token work."""
    tok = {p: sum(w.total_tokens for w in r.workflows) for p, r in results.items()}
    assert len(set(tok.values())) == 1, tok


def test_single_app_qa():
    apps = [make_app("QA", "G+M")]
    k = run_policy(apps, "kairos", rate=6.0, duration=80.0, seed=5, max_batch=48)
    p = run_policy(apps, "parrot", rate=6.0, duration=80.0, seed=5, max_batch=48)
    assert k.summary()["avg"] < p.summary()["avg"]


def test_zero_copy_pricing_knobs():
    """The cost model prices the zero-copy engine hot path: donated
    pools copy 0 bytes (no change to the default trajectory), while
    ``donate_pool=False`` pays a full pool read+write per dispatch and
    ``ragged_native=False`` re-reads the batch-padded table width per
    chunk — both strictly slower, with identical scheduling decisions."""
    from repro.sim import SimConfig, Simulation
    from repro.sim.cost_model import LLAMA3_8B

    # CostModel arithmetic: donation zeroes the traffic term exactly
    t0 = LLAMA3_8B.iteration_time(4, 64, 128, n_prefill_seqs=2, fused=True)
    copy = 2 * LLAMA3_8B.pool_bytes(12288)          # one full read + write
    tc = LLAMA3_8B.iteration_time(4, 64, 128, n_prefill_seqs=2, fused=True,
                                  hbm_bytes=copy)
    assert tc > t0
    assert tc - t0 == pytest.approx(copy / (LLAMA3_8B.hbm_gbps * 1e9))

    kw = dict(apps=[make_app("QA", "G+M")], policy="kairos", rate=4.0,
              duration=40.0, seed=7, prefill_chunk_tokens=512)
    base = Simulation(SimConfig(**kw)).run()
    copying = Simulation(SimConfig(**kw, donate_pool=False)).run()
    # same workload, strictly worse latency when every dispatch pays a
    # full pool read+write
    assert len(base.workflows) == len(copying.workflows)
    assert copying.summary()["avg"] > base.summary()["avg"]

    # ragged_native=False: a chunk re-reads the batch-padded table width
    # instead of its own context — strictly slower per iteration
    from repro.serving.request import Request
    from repro.sim.simulator import SimInstance

    def one_iter_dt(native):
        inst = SimInstance(0, LLAMA3_8B, kv_capacity_tokens=4096,
                           prefill_chunk_tokens=32, ragged_native=native)
        inst.submit(Request(agent_name="a", msg_id="m", prompt_len=100,
                            true_output_len=4, max_new_tokens=4))
        _, dt = inst.step(0.0)
        return dt

    assert one_iter_dt(native=False) > one_iter_dt(native=True)


def test_latency_distributions_learned():
    from repro.sim import SimConfig, Simulation
    cfg = SimConfig(apps=colocated_apps(), policy="kairos", **KW)
    sim = Simulation(cfg)
    sim.run()
    prof = sim.orch.profiler
    agents = prof.agents()
    assert "Router" in agents and "Engineer" in agents
    # Fig 3/4: Router's outputs/latency are far smaller than Engineer's
    assert prof.expected_output_len("Router") * 5 < prof.expected_output_len("Engineer")
    # priorities reflect remaining latency: entry agents have lower priority
    scores = sim.orch.priorities.scores
    assert scores[("CG[HE]", "QAEngineer")] < scores[("CG[HE]", "ProductManager")]
