"""Observability stack (src/repro/obs): tracing, metrics, SLO, export.

Covered here:

* ring-buffer ``Tracer`` semantics: per-instance streams, merged
  time-ordering, overwrite-oldest + ``dropped()``, ``NullTracer`` no-op;
* a traced real-engine cluster drain emits a well-ordered lifecycle per
  request (submit <= dispatch <= admit <= first-token <= finish) and is
  **token-identical** to the untraced drain;
* critical-path extraction on a hand-built workflow DAG (chain with a
  fan-out branch) — picks the gating chain, decomposes queue / prefill /
  decode / orch exactly;
* SLO math: per-request clauses, NaN fails closed, workflow goodput and
  good-token fraction on hand-built samples;
* Chrome/Perfetto export validates and the plain-dict round-trip is
  loss-free;
* metrics registry snapshots + engine counter consolidation
  (``runner.n_dispatches`` is registry-backed);
* orchestrator EMA: ``expected_exec_time`` feeds from measured spans
  when traced, static profiler fallback otherwise.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import Orchestrator
from repro.core.orchestrator import HardwareProfile
from repro.obs import (
    NULL_TRACER,
    SLO,
    CriticalPath,
    Event,
    MetricsRegistry,
    RequestSample,
    StageSpan,
    Tracer,
    critical_path,
    events_from_dicts,
    events_to_dicts,
    merge_snapshots,
    slo_report,
    spans_from_events,
    stage_breakdown,
    to_chrome_trace,
    validate_chrome_trace,
)
from repro.serving import (
    LLMEngine,
    PagedModelRunner,
    Request,
    ServingCluster,
    reset_request_ids,
)
from repro.serving.request import CompletionRecord


# =============================================================================
# tracer
# =============================================================================


def test_tracer_orders_and_merges_per_instance_streams():
    tr = Tracer()
    tr.emit("submit", req_id=1, instance_id=-1, ts=1.0)
    tr.emit("admit", req_id=1, instance_id=0, ts=2.0)
    tr.emit("decode", req_id=1, instance_id=0, ts=4.0)
    tr.emit("finish", req_id=1, instance_id=0, ts=5.0)
    tr.emit("dispatch", req_id=1, instance_id=-1, ts=1.5)
    evs = tr.events()
    assert [e.kind for e in evs] == \
        ["submit", "dispatch", "admit", "decode", "finish"]
    assert [e.ts for e in evs] == sorted(e.ts for e in evs)
    assert sorted(tr.instance_ids()) == [-1, 0]
    assert len(tr.events(instance_id=0)) == 3
    assert len(tr) == 5


def test_tracer_ring_overwrites_oldest_and_counts_drops():
    tr = Tracer(capacity_per_instance=4)
    for i in range(10):
        tr.emit("decode", req_id=i, instance_id=0, ts=float(i))
    evs = tr.events()
    assert len(evs) == 4 and tr.dropped() == 6
    assert [e.req_id for e in evs] == [6, 7, 8, 9]   # oldest overwritten
    tr.clear()
    assert len(tr) == 0 and tr.dropped() == 0


def test_null_tracer_is_inert():
    NULL_TRACER.emit("submit", req_id=1, ts=0.0)
    assert not NULL_TRACER.enabled
    assert NULL_TRACER.events() == [] and len(NULL_TRACER) == 0


def test_unknown_event_kind_rejected():
    with pytest.raises(AssertionError):
        Tracer().emit("no-such-kind", ts=0.0)


# =============================================================================
# traced real drain: ordering + token identity
# =============================================================================


@pytest.fixture(scope="module")
def model_and_params():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


def _reqs(n=5, max_new=4, seed=3):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(10, 30))
        reqs.append(Request(
            agent_name="a", msg_id=f"m{i}", prompt_len=plen,
            prompt_tokens=rng.integers(0, 500, plen).astype(np.int32),
            max_new_tokens=max_new, arrival_time=float(i) * 1e-3))
    return reqs


def _drain(model_and_params, tracer, n_instances=2):
    model, params = model_and_params
    reset_request_ids()
    runner0 = PagedModelRunner(model, params, num_blocks=64, block_size=8,
                               max_batch=4)
    engines = [
        LLMEngine(runner0 if i == 0 else runner0.clone(), instance_id=i,
                  max_batch=4, prefill_chunk_tokens=16, tracer=tracer)
        for i in range(n_instances)]
    orch = Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0, kv_capacity_tokens=64 * 8))
    cluster = ServingCluster(engines, orch, tracer=tracer)
    pending = _reqs()
    done = []
    for _ in range(4000):
        while pending:
            cluster.submit(pending.pop(0))
        done.extend(cluster.step())
        if not cluster.has_work:
            break
    cluster.close()
    assert len(done) == 5
    return sorted((r.msg_id, tuple(r.output_tokens)) for r in done), cluster


def test_traced_drain_lifecycle_order_and_token_identity(model_and_params):
    tr = Tracer()
    out_traced, _ = _drain(model_and_params, tr)
    out_plain, _ = _drain(model_and_params, NULL_TRACER)
    assert out_traced == out_plain, \
        "enabling tracing must not change a single generated token"

    evs = tr.events()
    by_req = {}
    for e in evs:
        if e.req_id >= 0:
            by_req.setdefault(e.req_id, []).append(e)
    assert len(by_req) == 5
    order = {"submit": 0, "dispatch": 1, "admit": 2, "first-token": 3,
             "finish": 5}
    for req_id, res in by_req.items():
        kinds = [e.kind for e in res]
        for needed in ("submit", "dispatch", "admit", "first-token", "finish"):
            assert kinds.count(needed) == 1, (req_id, kinds)
        anchors = [e for e in res if e.kind in order]
        anchors.sort(key=lambda e: order[e.kind])
        ts = [e.ts for e in anchors]
        assert ts == sorted(ts), f"req {req_id} lifecycle out of order: {ts}"
        # control plane writes ring -1; engine events carry their instance
        sub = next(e for e in res if e.kind == "submit")
        adm = next(e for e in res if e.kind == "admit")
        assert sub.instance_id == -1 and adm.instance_id >= 0

    # spans rebuild losslessly from the stream and fully timed
    spans = spans_from_events(evs)
    assert len(spans) == 5
    assert all(s.exec_start >= 0 and s.first_token >= 0 and s.finish >= 0
               for s in spans)
    bd = stage_breakdown(spans)
    assert bd["total"]["mean"] > 0

    # export path: valid Chrome trace with both engine tracks
    trace = to_chrome_trace(evs, dropped=tr.dropped())
    assert validate_chrome_trace(trace) == []
    pnames = {e["args"]["name"] for e in trace["traceEvents"]
              if e.get("ph") == "M" and e["name"] == "process_name"}
    assert {"control-plane", "engine0", "engine1"} <= pnames
    assert any(e["ph"] == "X" and e["name"] == "decode"
               for e in trace["traceEvents"])
    json.dumps(trace)   # must serialize


def test_engine_metrics_snapshot_consolidates_counters(model_and_params):
    tr = Tracer()
    _, cluster = _drain(model_and_params, tr)
    snap = cluster.metrics_snapshot()
    for i in (0, 1):
        assert snap[f"engine{i}.n_dispatches"] >= 1
        assert snap[f"engine{i}.n_finished"] >= 1
        assert snap[f"engine{i}.iteration_tokens.count"] >= 1
    # the legacy attribute and the registry are the same counter
    e0 = cluster.engines[0]
    assert e0.runner.n_dispatches == snap["engine0.n_dispatches"]
    e0.runner.n_dispatches += 1
    assert e0.runner.metrics.counter("n_dispatches").value \
        == snap["engine0.n_dispatches"] + 1


# =============================================================================
# critical path on a hand-built DAG
# =============================================================================


def _span(name, upstream, arrival, exec_start, first_token, finish,
          msg_id="wf", req_id=0):
    return StageSpan(name=name, msg_id=msg_id, upstream=upstream,
                     arrival=arrival, exec_start=exec_start,
                     first_token=first_token, finish=finish, req_id=req_id)


def test_critical_path_hand_built_dag():
    # entry A fans out to B (slow) and C (fast); D starts after B gated it.
    spans = [
        _span("A", None, 0.0, 1.0, 2.0, 4.0, req_id=1),
        _span("B", "A", 4.5, 5.0, 6.0, 10.0, req_id=2),     # gating branch
        _span("C", "A", 4.2, 4.3, 4.5, 5.0, req_id=3),      # fast branch
        _span("D", "B", 10.5, 11.0, 12.0, 15.0, req_id=4),  # last finisher
    ]
    cp = critical_path(spans)
    assert isinstance(cp, CriticalPath)
    assert [s.name for s in cp.stages] == ["A", "B", "D"]   # C not on path
    bd = cp.breakdown()
    # queue: (1-0) + (5-4.5) + (11-10.5); prefill: 1+1+1; decode: 2+4+3
    assert bd["queue"] == pytest.approx(2.0)
    assert bd["prefill"] == pytest.approx(3.0)
    assert bd["decode"] == pytest.approx(9.0)
    assert bd["orch"] == pytest.approx(0.5 + 0.5)           # A->B, B->D gaps
    assert cp.total == pytest.approx(15.0)
    assert bd["queue"] + bd["prefill"] + bd["decode"] + bd["orch"] \
        == pytest.approx(cp.total)
    rows = cp.stage_rows()
    assert [r["agent"] for r in rows] == ["A", "B", "D"]


def test_critical_path_fan_in_picks_latest_gating_upstream():
    # two A-stage calls feed B; the later finisher is the gate
    spans = [
        _span("A", None, 0.0, 0.0, 0.5, 1.0, req_id=1),
        _span("A", None, 0.0, 0.0, 0.5, 3.0, req_id=2),
        _span("B", "A", 3.5, 3.5, 4.0, 5.0, req_id=3),
    ]
    cp = critical_path(spans)
    assert [s.req_id for s in cp.stages] == [2, 3]
    assert cp.gaps == pytest.approx([0.0, 0.5])


def test_critical_path_dangling_upstream_truncates():
    spans = [_span("B", "ghost", 1.0, 1.0, 1.5, 2.0, req_id=1)]
    cp = critical_path(spans)
    assert [s.name for s in cp.stages] == ["B"]
    assert cp.total == pytest.approx(1.0)


# =============================================================================
# SLO / goodput math
# =============================================================================


def test_slo_per_request_clauses_and_nan_fail_closed():
    slo = SLO(ttft_s=1.0, tpot_s=0.5, e2e_s=10.0)
    ok = RequestSample(msg_id="w", arrival=0.0, finish=2.0, output_len=3,
                       exec_start=0.1, first_token=0.8)   # tpot 0.6 fails
    assert not ok.meets(slo)
    ok2 = RequestSample(msg_id="w", arrival=0.0, finish=1.6, output_len=3,
                        exec_start=0.1, first_token=0.8)  # tpot 0.4
    assert ok2.meets(slo)
    # no first-token timing recorded: TTFT/TPOT are NaN -> fail closed
    missing = RequestSample(msg_id="w", arrival=0.0, finish=1.0, output_len=2)
    assert not missing.meets(slo)
    assert missing.meets(SLO(e2e_s=10.0))   # disabled clauses don't fail


def test_slo_report_workflow_goodput():
    slo = SLO(e2e_s=5.0, workflow_deadline_s=8.0)
    mk = lambda wf, a, f, n: RequestSample(
        msg_id=wf, arrival=a, finish=f, output_len=n,
        exec_start=a, first_token=a)
    samples = [
        mk("w1", 0.0, 3.0, 10), mk("w1", 3.0, 7.0, 10),   # attained, span 7
        mk("w2", 0.0, 3.0, 10), mk("w2", 4.0, 13.0, 10),  # e2e 9 > 5: miss
        mk("w3", 0.0, 2.0, 10), mk("w3", 5.0, 9.5, 10),   # span 9.5 > 8: miss
    ]
    rep = slo_report(samples, slo, duration_s=10.0)
    assert rep["n_workflows"] == 3
    assert rep["request_attainment"] == pytest.approx(5 / 6)
    assert rep["goodput_slo"] == pytest.approx(1 / 3)
    assert rep["workflow_attainment"] == rep["goodput_slo"]
    assert rep["good_token_frac"] == pytest.approx(20 / 60)
    assert rep["goodput_wf_per_s"] == pytest.approx(0.1)
    empty = slo_report([], slo)
    assert empty["goodput_slo"] == 0.0 and empty["n_requests"] == 0.0


# =============================================================================
# export round-trip
# =============================================================================


def test_event_dict_round_trip_is_loss_free():
    tr = Tracer()
    tr.emit("submit", req_id=7, instance_id=-1, agent="qa", msg_id="w1",
            ts=1.0, upstream=None)
    tr.emit("prefill-chunk", req_id=7, instance_id=0, ts=2.0,
            start=0, end=16, last=True)
    evs = tr.events()
    back = events_from_dicts(json.loads(json.dumps(events_to_dicts(evs))))
    assert [tuple(e) for e in back] == [tuple(e) for e in evs]
    assert all(isinstance(e, Event) for e in back)
    with pytest.raises(AssertionError):
        events_from_dicts([{**evs[0]._asdict(), "kind": "bogus"}])


def test_chrome_trace_collapses_missing_first_token_to_exec_span():
    tr = Tracer()
    tr.emit("submit", req_id=1, instance_id=-1, msg_id="w", ts=0.0)
    tr.emit("admit", req_id=1, instance_id=0, ts=1.0)
    tr.emit("finish", req_id=1, instance_id=0, ts=3.0)
    trace = to_chrome_trace(tr.events())
    assert validate_chrome_trace(trace) == []
    names = [e["name"] for e in trace["traceEvents"] if e["ph"] == "X"]
    assert "exec" in names and "prefill" not in names
    # in-flight request (no finish) fabricates no span
    tr2 = Tracer()
    tr2.emit("submit", req_id=2, instance_id=-1, msg_id="w", ts=0.0)
    tr2.emit("admit", req_id=2, instance_id=0, ts=1.0)
    assert [e for e in to_chrome_trace(tr2.events())["traceEvents"]
            if e["ph"] == "X"] == []


# =============================================================================
# metrics registry
# =============================================================================


def test_metrics_registry_snapshot_and_merge():
    m = MetricsRegistry()
    m.inc("reqs")
    m.inc("reqs", 2)
    m.set("depth", 7)
    for v in (1.0, 2.0, 3.0, 4.0):
        m.observe("lat", v)
    snap = m.snapshot()
    assert snap["reqs"] == 3.0 and snap["depth"] == 7.0
    assert snap["lat.count"] == 4.0
    assert snap["lat.mean"] == pytest.approx(2.5)
    assert snap["lat.max"] == 4.0
    merged = merge_snapshots({"e0": snap, "e1": {"reqs": 1.0}})
    assert merged["e0.reqs"] == 3.0 and merged["e1.reqs"] == 1.0
    with pytest.raises(AssertionError):
        m.counter("depth")   # name already registered as a gauge


# =============================================================================
# orchestrator EMA feed
# =============================================================================


def _rec(agent, exec_start, first_token, end, out_len):
    return CompletionRecord(
        agent_name=agent, msg_id="w", upstream_name=None, app_name="app",
        start_time=0.0, end_time=end, prompt_len=8, output_len=out_len,
        exec_start_time=exec_start, first_token_time=first_token)


def test_orchestrator_expected_exec_time_feeds_from_measured_spans():
    tr = Tracer()
    orch = Orchestrator(tracer=tr)
    static = Orchestrator()   # NULL_TRACER: static profiler path
    for o in (orch, static):
        o.on_completion(_rec("qa", 0.0, 2.0, 6.0, 5))
    # traced: TTFT 2.0 + TPOT 1.0 * (E[out]-1) — differs from the static
    # mode-of-distribution estimate fed the same single completion
    t_traced = orch.expected_exec_time("qa")
    exp_out = orch.profiler.expected_output_len("qa")
    assert t_traced == pytest.approx(2.0 + 1.0 * max(exp_out - 1, 1))
    # unseen agent falls back to the static path even when traced
    assert orch.expected_exec_time("ghost") \
        == static.expected_exec_time("ghost")
    # EMA moves toward a faster second sample
    orch.on_completion(_rec("qa", 0.0, 1.0, 3.0, 5))
    assert orch.expected_exec_time("qa") < t_traced
