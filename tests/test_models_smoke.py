"""Per-architecture smoke tests: reduced config (2 layers, d_model<=512,
<=4 experts), one forward/train step + one prefill/decode step on CPU.
Asserts output shapes and finiteness (no NaNs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

B, S = 2, 24


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(jax.random.fold_in(key, 3), (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # one SGD step: grads exist and are finite
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(g)
    assert leaves
    assert all(np.all(np.isfinite(np.asarray(leaf, dtype=np.float32))) for leaf in leaves), \
        f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
        logits, cache = jax.jit(model.prefill)(params, tokens, frames)
    else:
        logits, cache = jax.jit(model.prefill)(params, tokens)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), f"{arch}: prefill NaN"

    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    # grow kv cache to allow one more token
    if "kv" in cache:
        kv = cache["kv"]
        pad = [(0, 0)] * kv.ndim
        pad[3] = (0, 4)
        cache = dict(cache, kv=jnp.pad(kv, pad))
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, nxt)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), f"{arch}: decode NaN"
    assert int(cache2["pos"]) == S + 1


def test_decode_matches_prefill_dense():
    """Decoding token-by-token must agree with a longer prefill (llama-family)."""
    cfg = get_config("llama3.2-3b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0, cfg.vocab_size)

    logits_full, _ = model.prefill(params, toks)           # logits after 10 tokens
    logits_pre, cache = model.prefill(params, toks[:, :9])
    kv = jnp.pad(cache["kv"], [(0, 0), (0, 0), (0, 0), (0, 2), (0, 0), (0, 0)])
    cache = dict(cache, kv=kv)
    logits_dec, _ = model.decode_step(params, cache, toks[:, 9:10])
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_full, np.float32), rtol=2e-2, atol=2e-2)
