"""Observability: tracing, metrics, critical-path and SLO analysis."""
from repro.obs.trace import (
    EVENT_KINDS,
    NULL_TRACER,
    Event,
    NullTracer,
    TraceContext,
    Tracer,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    merge_snapshots,
    rollup_by_role,
)
from repro.obs.critical_path import (
    CriticalPath,
    StageSpan,
    critical_path,
    spans_from_events,
    spans_from_requests,
    stage_breakdown,
)
from repro.obs.slo import (
    SLO,
    RequestSample,
    percentile,
    request_samples,
    slo_report,
)
from repro.obs.export import (
    events_from_dicts,
    events_to_dicts,
    to_chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
)

__all__ = [
    "EVENT_KINDS", "NULL_TRACER", "Event", "NullTracer", "TraceContext",
    "Tracer",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "merge_snapshots",
    "rollup_by_role",
    "CriticalPath", "StageSpan", "critical_path", "spans_from_events",
    "spans_from_requests", "stage_breakdown",
    "SLO", "RequestSample", "percentile", "request_samples", "slo_report",
    "events_from_dicts", "events_to_dicts", "to_chrome_trace",
    "validate_chrome_trace", "write_chrome_trace",
]
