"""Request-lifecycle tracing: typed events in per-instance ring buffers.

The orchestrator's whole §4 story — "collect agent-specific information
for online workflow analysis" — presumes someone can actually *see*
where a workflow's latency goes.  This module is that someone: every
layer of the stack (load balancer, dispatcher, batch scheduler, engine,
cluster, simulator) emits :class:`Event`\\ s into a :class:`Tracer`, and
the ``obs`` siblings turn the streams into critical paths
(``critical_path.py``), SLO/goodput reports (``slo.py``), and
Chrome/Perfetto traces (``export.py``).

Design constraints, in order:

* **Near-zero overhead when disabled.**  Tracing is off by default:
  every call site holds a :data:`NULL_TRACER` whose ``enabled`` is
  ``False`` and guards the emit (``if tracer.enabled: tracer.emit(...)``)
  — the disabled cost is one attribute load and a branch, no call, no
  allocation.  A CI gate bounds the *enabled* overhead too
  (``benchmarks/latency_breakdown.py``: ``tracing_overhead_pct <= 5``).
* **Lock-free hot path.**  Events land in per-instance ring buffers
  (``instance_id`` keys a ring; control-plane events use ``-1``): an
  emit is one list-slot store plus an integer increment, no locks.
  Each ring is single-writer by construction — a cluster engine's
  events are emitted either from its dispatch worker or from the
  control-plane collect, never both concurrently (the cluster resolves
  the dispatch future before collecting), and control-plane events stay
  on the control-plane thread.
* **Bounded memory.**  Rings overwrite oldest-first past ``capacity``;
  ``dropped()`` reports how many events rolled off, so an exporter can
  say "truncated" instead of silently lying.
* **Sim/real parity.**  The simulator emits the *same* event schema with
  simulated timestamps (``emit(..., ts=now)``); the real path defaults
  to the tracer's ``clock``.  Sim-vs-real breakdowns are diffable.

Event taxonomy (``kind``):

======================  =====================================================
``submit``              request enqueued at the load balancer
``dispatch``            load balancer placed it on an instance
``migrate-candidate``   starvation valve engaged: request waited so long it
                        is force-placed (the natural seed for live migration)
``admit``               instance scheduler admitted it (KV allocated);
                        ``data['cached']`` = prefix-cache tokens served free
``prefill-chunk``       one prompt chunk composed into an iteration
                        (``data['start']/['end']/['last']``)
``first-token``         the request's first generated token was computed
``decode``              one decode token booked for the request
``iteration``           one engine iteration composed (``data['n_chunks']``,
                        ``['n_decode']``, ``['n_tokens']``)
``preempt``             request evicted by recompute-preemption
``evict``               cold prefix-cache blocks reclaimed (``data['n']``)
``oom-fence``           dispatcher fenced the instance after a real OOM
``handoff-start``       prefill finished on a prefill-role instance; its KV
                        snapshot is leaving (``data['to']`` = decode target,
                        ``['n_blocks']``/``['n_bytes']`` = transfer size)
``handoff-complete``    the decode target adopted the request
                        (``data['src']``, ``['cached']`` = prefix blocks
                        served from the target's cache instead of the wire)
``scale-up``            autoscaler minted an instance (``data['n']`` = fleet
                        size after; ``data['role']`` on role-typed clusters)
``scale-down``          autoscaler retired an instance (same ``data``)
``finish``              request completed (``data['out']`` = output tokens)
``fault-injected``      a planned :class:`~repro.serving.faults.FaultSpec`
                        fired (``data['fault']`` = crash/straggle/oom/
                        transfer, ``['step']`` = instance iteration index)
``failure-detected``    recovery declared an instance dead or straggling
                        (``data['reason']``, ``['n_lost']`` = in-flight
                        requests to reconstruct on a crash)
``recovery-replay``     a lost request was reconstructed: re-queued with
                        prompt + already-emitted tokens (``data['replayed']``
                        = tokens to re-emit verbatim, ``['retry']``)
``handoff-strand``      a prefill-complete request found no decode capacity
                        and will decode colocated (``data['attempts']``,
                        ``['permanent']`` once the retry cap is spent)
``shed``                the overload valve dropped a request judged unable
                        to meet its deadline (``data['slack']``,
                        ``['queued']`` = balancer depth at shed time)
======================  =====================================================
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, NamedTuple, Optional

EVENT_KINDS = (
    "submit", "dispatch", "migrate-candidate", "admit", "prefill-chunk",
    "first-token", "decode", "iteration", "preempt", "evict", "oom-fence",
    "handoff-start", "handoff-complete", "scale-up", "scale-down",
    "finish",
    "fault-injected", "failure-detected", "recovery-replay",
    "handoff-strand", "shed",
)


class Event(NamedTuple):
    """One trace event.  ``instance_id == -1`` marks control-plane events
    (balancer/dispatcher); ``req_id == -1`` marks instance-level events
    with no single owning request (``iteration``, ``oom-fence``)."""
    ts: float
    kind: str
    req_id: int
    instance_id: int
    agent: str
    msg_id: str
    data: dict


@dataclasses.dataclass
class TraceContext:
    """Carried by a :class:`~repro.serving.request.Request` once it enters
    a traced control plane: the workflow trace id (message id), this
    request's span id, and the upstream stage it descends from — enough
    for ``critical_path.py`` to stitch agent stages into a DAG without a
    global side table."""
    trace_id: str
    span_id: int
    parent_name: Optional[str] = None


class _Ring:
    """Fixed-capacity overwrite-oldest event buffer.  Single-writer: an
    append is one slot store + one int increment (GIL-atomic enough that
    concurrent *readers* see a consistent prefix)."""

    __slots__ = ("buf", "n", "cap")

    def __init__(self, cap: int):
        self.buf: List[Optional[Event]] = [None] * cap
        self.n = 0
        self.cap = cap

    def append(self, evt: Event):
        self.buf[self.n % self.cap] = evt
        self.n += 1

    def events(self) -> List[Event]:
        if self.n <= self.cap:
            return [e for e in self.buf[: self.n] if e is not None]
        i = self.n % self.cap
        return [e for e in self.buf[i:] + self.buf[:i] if e is not None]

    @property
    def dropped(self) -> int:
        return max(0, self.n - self.cap)


class Tracer:
    """Per-instance lock-free event rings behind one emit surface.

    ``enabled`` is the call-site guard flag; a :class:`NullTracer`
    (:data:`NULL_TRACER`) keeps it ``False`` so guarded call sites cost
    one branch when tracing is off.  ``clock`` stamps events on the real
    path; the simulator always passes explicit ``ts``.
    """

    enabled: bool = True

    def __init__(self, capacity_per_instance: int = 1 << 16,
                 clock=time.monotonic):
        assert capacity_per_instance > 0
        self.capacity = capacity_per_instance
        self.clock = clock
        self._rings: Dict[int, _Ring] = {}

    # ------------------------------------------------------------------ emit
    def emit(self, kind: str, req_id: int = -1, instance_id: int = -1,
             agent: str = "", msg_id: str = "",
             ts: Optional[float] = None, **data):
        assert kind in EVENT_KINDS, f"unknown event kind {kind!r}"
        ring = self._rings.get(instance_id)
        if ring is None:
            # setdefault: first-emit race between two instance threads
            # can only ever target *different* keys (rings are
            # per-instance single-writer), so this is belt-and-braces
            ring = self._rings.setdefault(instance_id, _Ring(self.capacity))
        ring.append(Event(self.clock() if ts is None else ts, kind,
                          req_id, instance_id, agent, msg_id, data))

    # ----------------------------------------------------------------- views
    def events(self, instance_id: Optional[int] = None) -> List[Event]:
        """Events oldest-first; merged across rings (stable sort by
        timestamp) unless one instance is requested."""
        if instance_id is not None:
            ring = self._rings.get(instance_id)
            return ring.events() if ring is not None else []
        out: List[Event] = []
        for ring in self._rings.values():
            out.extend(ring.events())
        out.sort(key=lambda e: e.ts)
        return out

    def instance_ids(self) -> List[int]:
        return sorted(self._rings)

    def dropped(self) -> int:
        """Events that rolled off a full ring (exporters should surface
        a non-zero value as truncation, never pretend completeness)."""
        return sum(r.dropped for r in self._rings.values())

    def clear(self):
        self._rings.clear()

    def __len__(self) -> int:
        return sum(min(r.n, r.cap) for r in self._rings.values())


class NullTracer(Tracer):
    """The disabled singleton: ``enabled`` False, ``emit`` a no-op.
    Call sites hold this by default, so un-traced runs execute one
    attribute load + branch per would-be event."""

    enabled = False

    def __init__(self):
        super().__init__(capacity_per_instance=1)

    def emit(self, *a, **kw):  # pragma: no cover - trivially nothing
        pass


NULL_TRACER = NullTracer()
