"""SLO attainment and goodput-under-SLO (ROADMAP item 5).

Bare wall/token says how fast a system is; *goodput under SLO* says how
much of that speed users actually experience — the fraction (and rate)
of workflows whose end-to-end deadline was met, plus per-request TTFT /
TPOT / e2e attainment (Astraea's deadline-aware framing, PAPERS.md).

Inputs are deliberately plain: per-request records need ``msg_id``,
``arrival``/``exec_start``/``first_token``/``finish`` timestamps and an
``output_len`` — satisfied by both :class:`~repro.serving.request.Request`
(real path and sim) and the stage spans ``critical_path.py`` rebuilds
from trace events, so SLO reports diff across sim and real runs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency targets + per-workflow deadline (seconds).
    ``None`` disables a clause (it neither passes nor fails)."""
    ttft_s: Optional[float] = None        # time to first token
    tpot_s: Optional[float] = None        # mean time per output token
    e2e_s: Optional[float] = None         # request arrival -> finish
    workflow_deadline_s: Optional[float] = None   # workflow start -> done


@dataclasses.dataclass
class RequestSample:
    """The timing tuple one finished request contributes."""
    msg_id: str
    arrival: float
    finish: float
    output_len: int
    exec_start: float = -1.0
    first_token: float = -1.0

    @classmethod
    def from_request(cls, r) -> "RequestSample":
        return cls(msg_id=r.msg_id, arrival=r.arrival_time,
                   finish=r.finish_time, output_len=r.output_len,
                   exec_start=r.exec_start_time,
                   first_token=getattr(r, "first_token_time", -1.0))

    @property
    def ttft(self) -> float:
        if self.first_token < 0:
            return float("nan")
        return self.first_token - self.arrival

    @property
    def tpot(self) -> float:
        if self.first_token < 0:
            return float("nan")
        return (self.finish - self.first_token) / max(self.output_len - 1, 1)

    @property
    def e2e(self) -> float:
        return self.finish - self.arrival

    def meets(self, slo: SLO) -> bool:
        """Every enabled per-request clause holds.  A clause whose input
        timing is missing (NaN) fails closed — an unmeasured latency is
        not an attained one."""
        for target, value in ((slo.ttft_s, self.ttft),
                              (slo.tpot_s, self.tpot),
                              (slo.e2e_s, self.e2e)):
            if target is not None and not (value == value and value <= target):
                return False
        return True


def request_samples(requests: Iterable) -> List[RequestSample]:
    return [RequestSample.from_request(r) for r in requests
            if getattr(r, "finish_time", -1.0) >= 0]


def slo_report(samples: List[RequestSample], slo: SLO,
               duration_s: Optional[float] = None) -> Dict[str, float]:
    """Attainment + goodput in one flat dict.

    * ``request_attainment`` — fraction of finished requests meeting all
      enabled per-request clauses;
    * ``workflow_attainment`` (a.k.a. ``goodput_slo``) — fraction of
      workflows (grouped by ``msg_id``) whose span from first request
      arrival to last finish is within ``workflow_deadline_s`` AND whose
      every member request met its per-request clauses;
    * ``goodput_wf_per_s`` — attained workflows per second of
      ``duration_s`` (omitted when no duration is given);
    * ``good_token_frac`` — output tokens produced inside attained
      workflows / all output tokens (tokens spent on deadline-missing
      workflows are wasted work).
    """
    out: Dict[str, float] = {"n_requests": float(len(samples))}
    if not samples:
        out.update(request_attainment=0.0, workflow_attainment=0.0,
                   goodput_slo=0.0, good_token_frac=0.0, n_workflows=0.0)
        return out
    req_ok = [s.meets(slo) for s in samples]
    out["request_attainment"] = sum(req_ok) / len(samples)

    by_wf: Dict[str, List[int]] = {}
    for i, s in enumerate(samples):
        by_wf.setdefault(s.msg_id, []).append(i)
    n_good, good_tokens, all_tokens = 0, 0, 0
    for idxs in by_wf.values():
        span = max(samples[i].finish for i in idxs) \
            - min(samples[i].arrival for i in idxs)
        tokens = sum(samples[i].output_len for i in idxs)
        all_tokens += tokens
        ok = all(req_ok[i] for i in idxs)
        if slo.workflow_deadline_s is not None:
            ok = ok and span <= slo.workflow_deadline_s
        if ok:
            n_good += 1
            good_tokens += tokens
    out["n_workflows"] = float(len(by_wf))
    out["workflow_attainment"] = n_good / len(by_wf)
    out["goodput_slo"] = out["workflow_attainment"]
    out["good_token_frac"] = good_tokens / max(all_tokens, 1)
    if duration_s is not None and duration_s > 0:
        out["goodput_wf_per_s"] = n_good / duration_s
    return out


def percentile(xs: List[float], p: float) -> float:
    """Nearest-rank percentile over finite values; NaN-safe, no numpy."""
    xs = sorted(x for x in xs if x == x and not math.isinf(x))
    if not xs:
        return float("nan")
    i = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
    return xs[i]
