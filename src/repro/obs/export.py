"""Trace exporters: Chrome/Perfetto ``trace_event`` JSON + plain dicts.

``to_chrome_trace`` turns a tracer's event stream into the Trace Event
Format both ``chrome://tracing`` and https://ui.perfetto.dev consume:
one *process* per instance (pid = instance_id, control plane = pid 0 via
offset), one *thread* per request (tid = req_id) so a cluster drain
renders as per-engine tracks with per-request span rows.  Lifecycle
phases become "X" complete events (queued / prefill / decode), one-shot
kinds (preempt, evict, oom-fence, migrate-candidate, iteration) become
"i" instants.  Timestamps are microseconds, rebased to the earliest
event so traces start at t=0.

``events_to_dicts`` / ``events_from_dicts`` are the loss-free plain-dict
round-trip (the sim and tests use it); ``validate_chrome_trace`` is the
schema check the export test and CI artifact step run.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List

from repro.obs.trace import EVENT_KINDS, Event

# kinds rendered as instant markers rather than span edges
_INSTANT_KINDS = ("preempt", "evict", "oom-fence", "migrate-candidate",
                  "iteration", "dispatch", "prefill-chunk", "decode")

# chrome://tracing rejects pid/tid < 0; shift so control plane (-1) = 0
_PID_OFF = 1


def _us(ts: float, t0: float) -> float:
    return (ts - t0) * 1e6


def to_chrome_trace(events: Iterable[Event], *, dropped: int = 0) -> dict:
    """Build a Trace Event Format dict (``{"traceEvents": [...]}``).

    Span construction per request: ``submit -> admit`` renders as a
    ``queued`` X-event on the submitting track; ``admit -> first-token``
    as ``prefill`` and ``first-token -> finish`` as ``decode`` on the
    executing instance's track (``admit -> finish`` collapses to one
    ``exec`` span when no first-token event was captured).  Requests
    still in flight at capture time get no span (no fabricated ends).
    """
    events = sorted(events, key=lambda e: e.ts)
    if not events:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t0 = events[0].ts
    out: List[dict] = []
    pids_named: Dict[int, bool] = {}
    tids_named: Dict[tuple, bool] = {}

    def meta(pid: int, tid: int, agent: str, req_id: int):
        if pid not in pids_named:
            pids_named[pid] = True
            name = "control-plane" if pid == 0 else f"engine{pid - _PID_OFF}"
            out.append({"name": "process_name", "ph": "M", "pid": pid,
                        "args": {"name": name}})
        if (pid, tid) not in tids_named:
            tids_named[(pid, tid)] = True
            label = f"req{req_id}" + (f" [{agent}]" if agent else "")
            out.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid, "args": {"name": label}})

    def span(name: str, pid: int, tid: int, ts: float, dur: float,
             agent: str, req_id: int, args: dict):
        meta(pid, tid, agent, req_id)
        out.append({"name": name, "ph": "X", "pid": pid, "tid": tid,
                    "ts": _us(ts, t0), "dur": max(dur, 0.0) * 1e6,
                    "cat": "request", "args": args})

    # per-request lifecycle anchors
    sub: Dict[int, Event] = {}
    adm: Dict[int, Event] = {}
    ft: Dict[int, Event] = {}
    for e in events:
        if e.req_id < 0:
            continue
        if e.kind == "submit":
            sub.setdefault(e.req_id, e)
        elif e.kind == "admit":
            adm.setdefault(e.req_id, e)
        elif e.kind == "first-token":
            ft[e.req_id] = e
        elif e.kind == "finish":
            s, a = sub.get(e.req_id), adm.get(e.req_id)
            pid = e.instance_id + _PID_OFF
            args = {"msg_id": e.msg_id, **{k: v for k, v in e.data.items()
                                           if isinstance(v, (int, float, str))}}
            if s is not None:
                qend = a.ts if a is not None else e.ts
                span("queued", s.instance_id + _PID_OFF, e.req_id,
                     s.ts, qend - s.ts, e.agent or s.agent, e.req_id,
                     {"msg_id": s.msg_id})
            if a is not None:
                f = ft.get(e.req_id)
                if f is not None and a.ts <= f.ts <= e.ts:
                    span("prefill", pid, e.req_id, a.ts, f.ts - a.ts,
                         e.agent, e.req_id, {"cached": a.data.get("cached", 0)})
                    span("decode", pid, e.req_id, f.ts, e.ts - f.ts,
                         e.agent, e.req_id, args)
                else:
                    span("exec", pid, e.req_id, a.ts, e.ts - a.ts,
                         e.agent, e.req_id, args)
            ft.pop(e.req_id, None)

    # instants (markers) — rendered where they happened
    for e in events:
        if e.kind not in _INSTANT_KINDS:
            continue
        pid = e.instance_id + _PID_OFF
        tid = e.req_id if e.req_id >= 0 else 0
        meta(pid, tid, e.agent, e.req_id)
        out.append({"name": e.kind, "ph": "i", "pid": pid, "tid": tid,
                    "ts": _us(e.ts, t0), "s": "t", "cat": "marker",
                    "args": {k: v for k, v in e.data.items()
                             if isinstance(v, (int, float, str))}})

    trace = {"traceEvents": out, "displayTimeUnit": "ms"}
    if dropped:
        trace["otherData"] = {"dropped_events": dropped}
    return trace


def write_chrome_trace(path: str, events: Iterable[Event], *,
                       dropped: int = 0) -> dict:
    trace = to_chrome_trace(events, dropped=dropped)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def validate_chrome_trace(trace: dict) -> List[str]:
    """Schema check; returns a list of problems (empty = valid)."""
    errs: List[str] = []
    evs = trace.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    for i, e in enumerate(evs):
        ph = e.get("ph")
        if ph not in ("X", "i", "M"):
            errs.append(f"[{i}] bad ph {ph!r}")
            continue
        if not isinstance(e.get("pid"), int) or e["pid"] < 0:
            errs.append(f"[{i}] bad pid {e.get('pid')!r}")
        if ph == "M":
            if not e.get("args", {}).get("name"):
                errs.append(f"[{i}] metadata without args.name")
            continue
        if not isinstance(e.get("ts"), (int, float)) or e["ts"] < 0:
            errs.append(f"[{i}] bad ts {e.get('ts')!r}")
        if ph == "X" and (not isinstance(e.get("dur"), (int, float))
                          or e["dur"] < 0):
            errs.append(f"[{i}] X event with bad dur {e.get('dur')!r}")
        if not e.get("name"):
            errs.append(f"[{i}] unnamed event")
    return errs


# --------------------------------------------------------------- plain dicts
def events_to_dicts(events: Iterable[Event]) -> List[dict]:
    return [e._asdict() for e in events]


def events_from_dicts(dicts: Iterable[dict]) -> List[Event]:
    out = []
    for d in dicts:
        assert d["kind"] in EVENT_KINDS, f"unknown event kind {d['kind']!r}"
        out.append(Event(**d))
    return out
