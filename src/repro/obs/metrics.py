"""Zero-dependency metrics registry: counters, gauges, histograms.

One registry per instrumented object (a :class:`PagedModelRunner`, an
:class:`~repro.serving.engine.LLMEngine`), merged upward by
``snapshot()`` calls — the cluster's snapshot prefixes each engine's so
the whole serving stack flattens into one dict the benchmarks and the
BENCH JSON pipeline consume directly.

The ad-hoc perf counters that accumulated across PRs 3-5
(``PagedModelRunner.n_dispatches``, jit recompile counts, pool-bytes
probes in ``benchmarks/iteration_fusion.py``) now live here; the old
attributes remain as thin property aliases so existing tests and CI
gates keep reading them unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union


@dataclasses.dataclass
class Counter:
    """Monotonic-by-convention accumulator.  ``value`` is plain
    read/write so legacy ``obj.n_dispatches += 1`` aliases keep working
    through a property."""
    name: str
    value: float = 0.0

    def inc(self, n: float = 1.0):
        self.value += n


@dataclasses.dataclass
class Gauge:
    """Last-write-wins sample (queue depth, pool bytes, cache size)."""
    name: str
    value: float = 0.0

    def set(self, v: float):
        self.value = float(v)


class Histogram:
    """Running count/sum/min/max plus a bounded sample window for
    percentiles.  The window keeps the most recent ``window`` samples
    (overwrite-oldest) — adequate for serving-latency quantiles at the
    scales the benchmarks run, with strictly bounded memory."""

    __slots__ = ("name", "count", "total", "min", "max", "_win", "_n", "window")

    def __init__(self, name: str, window: int = 2048):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.window = window
        self._win: List[float] = [0.0] * window
        self._n = 0

    def observe(self, v: float):
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self._win[self._n % self.window] = v
        self._n += 1

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        n = min(self._n, self.window)
        if n == 0:
            return 0.0
        xs = sorted(self._win[:n])
        i = min(n - 1, max(0, int(round(p / 100.0 * (n - 1)))))
        return xs[i]

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0.0}
        return {"count": float(self.count), "mean": self.mean(),
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99), "max": self.max}


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Name -> metric, create-on-first-use.  ``snapshot()`` flattens to a
    plain dict (histograms expand to ``name.count`` / ``name.mean`` /
    ``name.p50`` / ``name.p95`` / ``name.p99`` / ``name.max``) — the
    exact shape ``benchmarks/common.write_bench_json`` expects."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, cls, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, **kw)
            self._metrics[name] = m
        assert isinstance(m, cls), \
            f"metric {name!r} already registered as {type(m).__name__}"
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, window: int = 2048) -> Histogram:
        return self._get(name, Histogram, window=window)

    # ------------------------------------------------------------ convenience
    def inc(self, name: str, n: float = 1.0):
        self.counter(name).inc(n)

    def set(self, name: str, v: float):
        self.gauge(name).set(v)

    def observe(self, name: str, v: float):
        self.histogram(name).observe(v)

    # ---------------------------------------------------------------- export
    def snapshot(self, prefix: str = "") -> Dict[str, float]:
        out: Dict[str, float] = {}
        for name, m in self._metrics.items():
            key = prefix + name
            if isinstance(m, Histogram):
                for k, v in m.summary().items():
                    out[f"{key}.{k}"] = v
            else:
                out[key] = m.value
        return out


def merge_snapshots(parts: Dict[str, Optional[Dict[str, float]]]) -> Dict[str, float]:
    """Merge labelled snapshots into one flat dict: ``{"engine0": {...}}``
    becomes ``{"engine0.metric": ...}``.  ``None`` parts are skipped."""
    out: Dict[str, float] = {}
    for label, snap in parts.items():
        if snap is None:
            continue
        for k, v in snap.items():
            out[f"{label}.{k}" if label else k] = v
    return out


def rollup_by_role(snapshot: Dict[str, float]) -> Dict[str, Dict[str, float]]:
    """Regroup a merged cluster snapshot by instance ROLE.

    Per-instance keys carry the cluster's :meth:`metrics_label` prefix —
    ``prefill0.n_admitted``, ``decode1.n_finished`` for role-typed
    instances, ``engine<i>.*`` (rolled up as ``general``) for flat ones.
    Returns ``{role: {metric: summed value}}``; additive metrics
    (counters, gauges, histogram ``.count``s) sum across a role's
    instances, which is what per-role attribution consumes.  Keys
    without an ``<alpha><digits>.`` instance prefix (cluster aggregates
    like ``queue_depth``) are skipped."""
    out: Dict[str, Dict[str, float]] = {}
    for key, v in snapshot.items():
        label, dot, metric = key.partition(".")
        if not dot or not label or not label[-1].isdigit():
            continue
        role = label.rstrip("0123456789")
        if not role.isalpha():
            continue
        if role == "engine":
            role = "general"
        bucket = out.setdefault(role, {})
        bucket[metric] = bucket.get(metric, 0.0) + v
    return out
