"""Workflow critical-path analysis from agent-stage spans.

The paper's Fig. 3 agent profiles — how much of a workflow's latency is
queuing vs LLM execution per stage — were assumed inputs; this module
*measures* them.  Stage spans (one per LLM request) are stitched into a
per-workflow DAG by upstream links, and the critical path is walked back
from the last-finishing stage: at each hop the predecessor is the
upstream stage whose finish is latest among those that causally precede
this stage's arrival.  Each stage on the path decomposes into

* ``queue``    — stage arrival -> LLM execution start (balancer queue +
  instance waiting queue + any re-queueing after preemption),
* ``prefill``  — execution start -> first generated token (TTFT minus
  queueing),
* ``decode``   — first token -> finish,
* ``orch``     — predecessor finish -> this stage's arrival (agent-local
  compute + message-bus hop: the orchestration gap).

Spans come from either trace events (:func:`spans_from_events` — the
tracer's ``submit``/``admit``/``first-token``/``finish`` kinds) or
directly from finished :class:`~repro.serving.request.Request` objects
(:func:`spans_from_requests`), so the same analysis runs on the real
cluster, the simulator, and stored traces.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

from repro.obs.trace import Event


@dataclasses.dataclass
class StageSpan:
    """One agent stage (one LLM request) of a workflow trace."""
    name: str                     # agent name
    msg_id: str                   # workflow trace id
    upstream: Optional[str]       # upstream agent name (None = entry stage)
    arrival: float                # arrival at the LLM service (stage start)
    exec_start: float = -1.0      # LLM execution start (admission)
    first_token: float = -1.0     # first generated token computed
    finish: float = -1.0          # request completed
    req_id: int = -1

    # ------------------------------------------------------------ breakdown
    @property
    def queue(self) -> float:
        return max(self.exec_start - self.arrival, 0.0) \
            if self.exec_start >= 0 else 0.0

    @property
    def prefill(self) -> float:
        if self.first_token < 0 or self.exec_start < 0:
            return 0.0
        return max(self.first_token - self.exec_start, 0.0)

    @property
    def decode(self) -> float:
        if self.finish < 0:
            return 0.0
        t0 = self.first_token if self.first_token >= 0 else self.exec_start
        return max(self.finish - t0, 0.0) if t0 >= 0 else 0.0

    @property
    def total(self) -> float:
        return max(self.finish - self.arrival, 0.0) if self.finish >= 0 else 0.0


def spans_from_requests(requests: Iterable) -> List[StageSpan]:
    return [StageSpan(name=r.agent_name, msg_id=r.msg_id,
                      upstream=r.upstream_name, arrival=r.arrival_time,
                      exec_start=r.exec_start_time,
                      first_token=getattr(r, "first_token_time", -1.0),
                      finish=r.finish_time, req_id=r.req_id)
            for r in requests if getattr(r, "finish_time", -1.0) >= 0]


def spans_from_events(events: Iterable[Event]) -> List[StageSpan]:
    """Rebuild stage spans from a trace-event stream.  ``submit`` opens a
    span; ``admit``/``first-token``/``finish`` fill it in.  A request
    preempted and re-admitted keeps its *first* admit as execution start
    (matching ``Request.exec_start_time``); its recompute cost shows up
    as inflated prefill/decode, which is exactly the truth."""
    spans: Dict[int, StageSpan] = {}
    for e in events:
        if e.req_id < 0:
            continue
        if e.kind == "submit":
            spans[e.req_id] = StageSpan(
                name=e.agent, msg_id=e.msg_id,
                upstream=e.data.get("upstream"), arrival=e.ts,
                req_id=e.req_id)
            continue
        s = spans.get(e.req_id)
        if s is None:
            # stream truncated (ring overwrote the submit): open a span
            # at this event so downstream stitching still works
            s = spans[e.req_id] = StageSpan(
                name=e.agent, msg_id=e.msg_id,
                upstream=e.data.get("upstream"), arrival=e.ts,
                req_id=e.req_id)
        if e.kind == "admit" and s.exec_start < 0:
            s.exec_start = e.ts
        elif e.kind == "first-token":
            s.first_token = e.ts   # last wins: preemption recomputes it
        elif e.kind == "finish":
            s.finish = e.ts
    return [s for s in spans.values() if s.finish >= 0]


@dataclasses.dataclass
class CriticalPath:
    """The longest causal chain of one workflow, entry -> last finisher."""
    msg_id: str
    stages: List[StageSpan]
    gaps: List[float]             # gaps[i] = orchestration gap BEFORE stage i

    @property
    def total(self) -> float:
        if not self.stages:
            return 0.0
        return self.stages[-1].finish - (self.stages[0].arrival - self.gaps[0])

    def breakdown(self) -> Dict[str, float]:
        """Path-wide per-category seconds; sums to ~``total``."""
        return {
            "queue": sum(s.queue for s in self.stages),
            "prefill": sum(s.prefill for s in self.stages),
            "decode": sum(s.decode for s in self.stages),
            "orch": sum(self.gaps),
            "total": self.total,
        }

    def stage_rows(self) -> List[Dict[str, float]]:
        return [{"agent": s.name, "queue": s.queue, "prefill": s.prefill,
                 "decode": s.decode, "orch": g, "total": s.total + g}
                for s, g in zip(self.stages, self.gaps)]


def critical_path(spans: Iterable[StageSpan],
                  msg_id: Optional[str] = None) -> CriticalPath:
    """Critical path of one workflow's stage spans.

    With ``msg_id`` None the spans must all share one workflow.  The walk
    starts at the stage with the latest finish and repeatedly moves to
    the causal predecessor: the span named ``upstream`` whose finish is
    <= this stage's arrival (small float slack), latest such finish
    winning — i.e. the dependency that actually gated this stage's
    start.  Fan-ins (several upstreams with the same name) resolve to
    the latest gating one, fan-outs resolve by walking only the chain
    that ends last, which is the definition of the critical path."""
    eps = 1e-9
    pool = [s for s in spans if msg_id is None or s.msg_id == msg_id]
    if not pool:
        return CriticalPath(msg_id or "", [], [])
    assert len({s.msg_id for s in pool}) == 1, \
        "critical_path expects stages of a single workflow (pass msg_id)"
    cur = max(pool, key=lambda s: s.finish)
    chain = [cur]
    while cur.upstream is not None:
        cands = [s for s in pool
                 if s.name == cur.upstream and s.finish <= cur.arrival + eps
                 and s is not cur]
        if not cands:
            # dangling upstream (trace truncation or a failed stage):
            # close the path here rather than fabricate a predecessor
            break
        cur = max(cands, key=lambda s: s.finish)
        chain.append(cur)
    chain.reverse()
    gaps = [0.0] + [max(chain[i].arrival - chain[i - 1].finish, 0.0)
                    for i in range(1, len(chain))]
    return CriticalPath(chain[0].msg_id, chain, gaps)


def stage_breakdown(spans: Iterable[StageSpan]) -> Dict[str, Dict[str, float]]:
    """Flat per-category stats over ALL spans (not just the critical
    path): mean and p99 of queue / prefill / decode seconds — the
    FCFS-vs-Kairos decomposition ``benchmarks/latency_breakdown.py``
    reports."""
    from repro.obs.slo import percentile
    spans = list(spans)
    out: Dict[str, Dict[str, float]] = {}
    for cat in ("queue", "prefill", "decode", "total"):
        xs = [getattr(s, cat) for s in spans]
        out[cat] = {"mean": sum(xs) / len(xs) if xs else 0.0,
                    "p99": percentile(xs, 99) if xs else 0.0}
    return out
