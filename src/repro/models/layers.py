"""Shared neural-net building blocks (pure-functional, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / (d_in ** 0.5)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray,
           preferred_element_type=None) -> jnp.ndarray:
    """``preferred_element_type`` widens the down-projection accumulator:
    a tensor-parallel caller whose ``w_down`` is row-sharded requests
    fp32 partial sums so the cross-shard psum rounds to the activation
    dtype ONCE, after the full contraction (matching the single-device
    rounding point)."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down,
                      preferred_element_type=preferred_element_type)


def init_ffn(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_table(max_len: int, head_dim: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Return (sin, cos) tables of shape (max_len, head_dim // 2), fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = jnp.arange(max_len, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, head_dim); sin/cos: (S, head_dim//2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., :, None, :]  # (S, 1, half) broadcasting over heads
    cos = cos[..., :, None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(dt)


def rope_at(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """sin/cos at explicit integer positions (any shape (...,))."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"embed": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, cfg.d_model, cfg.vocab_size, dtype)
    return p


def embed_tokens(params: dict, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["embed"], ids, axis=0)


def lm_logits(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, params["embed"])
    return jnp.einsum("...d,dv->...v", x, params["lm_head"])


def softmax_cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray, mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean next-token CE. logits (..., V) fp-any, targets int (...,).

    The gold logit is extracted with a one-hot contraction rather than
    ``take_along_axis``: with vocab-sharded logits GSPMD lowers the gather
    by replicating the full fp32 logits across the mesh (measured as the
    dominant collective on 34B-scale training, §Perf iteration 4); the
    contraction form keeps the vocab dim sharded and reduces only (B, S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.einsum("...v,...v->...", logits, onehot)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
