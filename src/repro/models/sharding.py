"""GSPMD sharding rules for all architectures × input shapes (DESIGN.md §5).

Mesh axes: ("data", "model") single pod, ("pod", "data", "model") multi-pod.
Batch shards over pod×data; weights megatron-style over model; MoE experts
expert-parallel over model when divisible, else per-expert tensor parallel;
optional FSDP adds a data-axis shard on weight d_model dims (kimi-k2
training).  long_500k (batch=1) context-shards the KV sequence dim over
pod×data.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


# --------------------------------------------------------------------------- #
# parameter rules
# --------------------------------------------------------------------------- #


def param_pspec(path: Tuple[str, ...], leaf, cfg: ModelConfig, mesh: Mesh,
                fsdp: bool = False) -> P:
    """Map a parameter-tree path to a PartitionSpec.

    The returned spec addresses the TRAILING dims of the (possibly
    layer-stacked) leaf; leading stack dims are padded with None.
    """
    key = path[-1]
    msize = _axis_size(mesh, "model")
    d_axis = "data" if (fsdp and "data" in mesh.axis_names) else None

    def base() -> Optional[Tuple]:
        m = cfg.moe
        # ---- embeddings -----------------------------------------------------
        # vocab dim only: FSDP-sharding the d_model dim here would put the
        # contraction dim of the LM head on `data` and force a full-logits
        # fp32 all-reduce (measured 16.5 GiB/op on chameleon train, §Perf)
        if key == "embed":
            return ("model", None)
        if key == "lm_head":
            return (None, "model")
        # ---- MoE ------------------------------------------------------------
        if key == "router":
            return (None, None)
        if key in ("w_gate", "w_up", "w_down") and leaf.ndim >= 3 and m is not None:
            ep = _div(m.num_experts, msize)
            if key == "w_down":   # (E, f, d)
                return ("model", None, d_axis) if ep else (None, "model", d_axis)
            return ("model", d_axis, None) if ep else (None, d_axis, "model")
        # ---- dense ffn -------------------------------------------------------
        if key in ("w_gate", "w_up"):
            return (d_axis, "model")
        if key == "w_down":
            return ("model", d_axis)
        # ---- attention -------------------------------------------------------
        if key in ("wq",):
            return (d_axis, "model")
        if key in ("wk", "wv"):
            kv_flat = cfg.num_kv_heads * cfg.resolved_head_dim
            return (d_axis, "model") if _div(kv_flat, msize) else (None, None)
        if key == "wo":
            return ("model", d_axis)
        # ---- rwkv ------------------------------------------------------------
        if key in ("wr", "wg"):
            return (d_axis, "model")
        if key in ("w_lora_a", "w_lora_b", "w_bias", "u", "mu", "ln_x"):
            return None
        # ---- mamba -----------------------------------------------------------
        if key == "in_proj":
            return (d_axis, "model")
        if key == "out_proj":
            return ("model", d_axis)
        if key == "conv_w":
            return (None, "model")
        if key in ("conv_b", "d_skip", "dt_bias"):
            return ("model",)
        if key == "x_proj":
            return ("model", None)
        if key == "dt_proj":
            return (None, "model")
        if key == "a_log":
            return ("model", None)
        return None

    spec = base()
    if spec is None:
        return P()
    spec = tuple(spec)[-leaf.ndim:] if len(spec) > leaf.ndim else spec
    # verify divisibility; drop axes that don't divide (GSPMD would pad —
    # we prefer explicit replication for weights)
    dims = leaf.shape[leaf.ndim - len(spec):]
    fixed = []
    for ax, dim in zip(spec, dims):
        if ax is None:
            fixed.append(None)
            continue
        size = np.prod([_axis_size(mesh, a) for a in
                        (ax if isinstance(ax, tuple) else (ax,))])
        fixed.append(ax if _div(dim, int(size)) else None)
    pad = (None,) * (leaf.ndim - len(fixed))
    return P(*(pad + tuple(fixed)))


def param_shardings(params_shape, cfg: ModelConfig, mesh: Mesh, fsdp: bool = False):
    """Tree of NamedShardings matching an (abstract) params/opt-state tree."""
    def one(path, leaf):
        keys = tuple(getattr(k, "key", getattr(k, "idx", None)) for k in path)
        keys = tuple(str(k) for k in keys if k is not None)
        return NamedSharding(mesh, param_pspec(keys, leaf, cfg, mesh, fsdp))

    return jax.tree_util.tree_map_with_path(one, params_shape)


# --------------------------------------------------------------------------- #
# activation / batch / cache rules
# --------------------------------------------------------------------------- #


def batch_pspec(shape: ShapeConfig, mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    if _div(shape.global_batch, n_dp):
        return P(dp, None)
    return P(None, None)              # long_500k: batch 1 replicated


def cache_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, cache_shape) -> dict:
    """PartitionSpecs for the decode cache tree.

    decode_32k: batch over pod×data, kv-heads over model (when divisible).
    long_500k (batch=1): KV **sequence** dim over pod×data (context
    parallelism) — GSPMD inserts the partial-softmax collectives."""
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    msize = _axis_size(mesh, "model")
    batch_ok = _div(shape.global_batch, n_dp)
    kv_ok = _div(cfg.num_kv_heads, msize)
    # GQA with n_kv < model size: split-KV (flash-decoding style) — shard
    # the cache SEQUENCE dim over `model`.  Scores stay S-sharded through
    # the softmax (GSPMD inserts only the tiny global-max/sum collectives)
    # and the PV contraction all-reduces just (B,H,1,hd).  The earlier
    # head_dim-sharding alternative forced a 268MB/layer score all-reduce
    # and an involuntary fp32 rematerialization of the cache (§Perf log).
    kv_axis = "model" if kv_ok else None
    seq_axis_model = None if kv_ok else "model"
    di = cfg.ssm_expand * cfg.d_model

    specs = {}
    for key, leaf in cache_shape.items():
        if key == "pos":
            specs[key] = P()
        elif key in ("kv", "memory_kv"):
            # (L, 2, B, S, KV, hd)
            seq = leaf.shape[3]
            if batch_ok:
                sa = seq_axis_model if _div(seq, msize) else None
                specs[key] = P(None, None, dp, sa, kv_axis, None)
            else:
                if kv_ok:
                    sa = dp if _div(seq, n_dp) else None
                else:
                    sa = (dp + ("model",)) if _div(seq, n_dp * msize) else (
                        dp if _div(seq, n_dp) else None)
                specs[key] = P(None, None, None, sa, kv_axis, None)
        elif key == "rwkv_state":      # (L, B, H, hd, hd)
            specs[key] = P(None, dp if batch_ok else None, None, None, None)
        elif key in ("rwkv_shift1", "rwkv_shift2"):   # (L, B, d)
            specs[key] = P(None, dp if batch_ok else None,
                           "model" if _div(cfg.d_model, msize) else None)
        elif key == "mamba_h":         # (L, B, di, N)
            specs[key] = P(None, dp if batch_ok else None,
                           "model" if _div(di, msize) else None, None)
        elif key == "mamba_conv":      # (L, B, k-1, di)
            specs[key] = P(None, dp if batch_ok else None, None,
                           "model" if _div(di, msize) else None)
        else:
            specs[key] = P()
    return specs


# --------------------------------------------------------------------------- #
# serving (paged-engine) rules
# --------------------------------------------------------------------------- #

# Paged KV pool (L, 2, num_blocks, block_size, n_kv, hd): shard the
# KV-head dim over "model" — the pool's logical shape is unchanged, the
# BlockManager stays head-agnostic (block ids address whole cross-shard
# pages), and each tensor-parallel shard holds exactly the head slice
# its megatron-sharded K/V projections produce.
POOL_PSPEC = P(None, None, None, None, "model", None)


def serving_param_specs(params, cfg: ModelConfig, mesh: Mesh):
    """PartitionSpec tree for the paged serving engine's params.

    The scanned layer stack gets the megatron rules from
    :func:`param_pspec` (QKV/O and FFN column/row-sharded over "model",
    so the only per-layer collectives are the two standard all-reduces).
    Embeddings / LM head / final norm are REPLICATED: the serving head
    is argmax-only, and a vocab-sharded head would trade the (tiny)
    replicated-weight memory for a per-iteration vocab collective on
    the hot path.
    """
    def one(path, leaf):
        keys = tuple(getattr(k, "key", getattr(k, "idx", None)) for k in path)
        keys = tuple(str(k) for k in keys if k is not None)
        if "layers" in keys:
            return param_pspec(keys, leaf, cfg, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(one, params)


def validate_serving_tp(cfg: ModelConfig, tp: int) -> None:
    """Raise unless a ``tp``-way megatron shard of this config is exact.

    The shard_map'd engine step assumes every sharded dim divides: a
    silently-replicated weight (param_pspec's GSPMD fallback) would make
    the per-layer psum double-count that block's contribution.
    """
    if tp <= 1:
        return
    if cfg.num_kv_heads % tp or cfg.num_heads % tp:
        raise ValueError(
            f"model_parallel={tp} must divide num_heads={cfg.num_heads} "
            f"and num_kv_heads={cfg.num_kv_heads} ({cfg.name})")
    if cfg.d_ff % tp:
        raise ValueError(
            f"model_parallel={tp} must divide d_ff={cfg.d_ff} ({cfg.name})")
    if cfg.moe is not None:
        raise ValueError(
            "tensor-parallel paged serving of MoE archs is not supported "
            "(expert-parallel serving: see ROADMAP)")


def should_fsdp(cfg: ModelConfig, kind: str) -> bool:
    """Shard weights over the `data` axis as well (FSDP-style).

    Training: Adam keeps 12 bytes/param — 16-way model parallel alone OOMs
    a 16 GB v5e above ~10B params (jamba train measured 54.6 GiB/dev
    before this rule, 16x16 mesh; §Perf iteration 1).
    Serving: bf16 weights alone exceed HBM above ~64B params at 16-way
    (kimi-k2 decode measured 128 GiB/dev before; 8 GiB/dev after).
    """
    if kind == "train":
        return cfg.param_count() > 8e9
    return cfg.param_count() > 40e9
