"""Composable language models covering all assigned architecture families.

A :class:`LanguageModel` is a stateless object built from a
:class:`ModelConfig`; parameters and caches are explicit pytrees.  Layer
stacks are *scanned* (stacked params, ``lax.scan``) so the HLO stays small
at 62 layers and GSPMD partitions one layer body.  Mixed-kind stacks
(Jamba's 7:1 mamba:attn with alternating MoE) scan over uniform 8-layer
super-blocks.

Public entry points (all pure):
  init_params(key)                         -> params
  loss(params, batch)                      -> (scalar, metrics)    # train
  prefill(params, tokens[, frames])        -> (logits, cache)      # inference
  decode_step(params, cache, tokens)       -> (logits, cache)      # one token
  init_cache(batch, max_len)               -> zeroed cache pytree
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import (
    embed_tokens,
    init_embedding,
    init_ffn,
    lm_logits,
    rms_norm,
    softmax_cross_entropy,
    swiglu,
)
from repro.models.moe import init_moe, moe_ffn

# =============================================================================
# per-layer param init
# =============================================================================


def _init_attn_layer(key, cfg: ModelConfig, dtype, ffn_kind: str) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attn.init_attention(k1, cfg, dtype),
    }
    if ffn_kind == "moe":
        p["moe"] = init_moe(k2, cfg, dtype)
    else:
        p["ffn"] = init_ffn(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_rwkv_layer(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "tmix": ssm.init_rwkv_time_mix(k1, cfg, dtype),
        "cmix": ssm.init_rwkv_channel_mix(k2, cfg, dtype),
    }


def _init_mamba_layer(key, cfg: ModelConfig, dtype, ffn_kind: str) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mamba": ssm.init_mamba(k1, cfg, dtype),
    }
    if ffn_kind == "moe":
        p["moe"] = init_moe(k2, cfg, dtype)
    else:
        p["ffn"] = init_ffn(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_encoder_layer(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attn.init_attention(k1, cfg, dtype),
        "ffn": init_ffn(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_decoder_xattn_layer(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln_x": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attn.init_attention(k1, cfg, dtype),
        "xattn": attn.init_attention(k2, cfg, dtype, cross=True),
        "ffn": init_ffn(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def _stack_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# =============================================================================
# LanguageModel
# =============================================================================


class LanguageModel:
    """Decoder-only LM (dense / MoE / VLM / RWKV / Jamba-hybrid)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        # Optional NamedSharding for (B, S, d) activations.  Constraining
        # the scan carry at every layer boundary is essential under GSPMD:
        # without it the partitioner replicated the carry across `data`
        # and every shard computed the FULL global batch (measured
        # f32[256,4096,1376] all-reduces on chameleon train, §Perf it. 4).
        self.act_sharding = None
        kinds = cfg.layer_kinds
        self.uniform_kind = kinds[0] if len(set(kinds)) == 1 else None
        if self.uniform_kind is None:
            # Jamba-style periodic pattern; find the smallest repeating unit
            self.block_period = next(
                p for p in range(1, cfg.num_layers + 1)
                if cfg.num_layers % p == 0 and kinds == kinds[:p] * (cfg.num_layers // p))
            self.n_blocks = cfg.num_layers // self.block_period
            self.block_kinds = kinds[: self.block_period]
        moe_layers = set(cfg.moe_layer_indices())
        self.ffn_kinds = tuple(
            "moe" if i in moe_layers else "dense" for i in range(cfg.num_layers))
        if self.uniform_kind is None:
            # ffn pattern must repeat with the block (jamba: moe period 2 | block 8)
            assert self.ffn_kinds == self.ffn_kinds[: self.block_period] * self.n_blocks

    def _block_ffn_kind(self, i: int) -> str:
        return self.ffn_kinds[i]

    def _constrain(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.act_sharding is not None and x.ndim == 3:
            return jax.lax.with_sharding_constraint(x, self.act_sharding)
        return x

    # -- flags ---------------------------------------------------------------
    def _is_global_flags(self) -> Optional[jnp.ndarray]:
        cfg = self.cfg
        if cfg.global_attn_every:
            return jnp.array(
                [(i % cfg.global_attn_every) == (cfg.global_attn_every - 1)
                 for i in range(cfg.num_layers)])
        if cfg.sliding_window is not None:
            return jnp.zeros((cfg.num_layers,), bool)
        return None

    # =========================================================================
    # init
    # =========================================================================
    def init_params(self, key) -> dict:
        cfg, dtype = self.cfg, self.dtype
        k_emb, k_layers = jax.random.split(key)
        params = init_embedding(k_emb, cfg, dtype)
        params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if self.uniform_kind == "attn":
            ffn_kind = self.ffn_kinds[0]
            params["layers"] = _stack_init(
                lambda k: _init_attn_layer(k, cfg, dtype, ffn_kind), k_layers, cfg.num_layers)
        elif self.uniform_kind == "rwkv":
            params["layers"] = _stack_init(
                lambda k: _init_rwkv_layer(k, cfg, dtype), k_layers, cfg.num_layers)
        else:  # jamba blocks
            def init_block(k):
                ks = jax.random.split(k, self.block_period)
                md, mm = [], []
                blk = {}
                for i, kind in enumerate(self.block_kinds):
                    fk = self._block_ffn_kind(i)
                    if kind == "attn":
                        blk["attn"] = _init_attn_layer(ks[i], cfg, dtype, fk)
                    elif fk == "moe":
                        mm.append(_init_mamba_layer(ks[i], cfg, dtype, fk))
                    else:
                        md.append(_init_mamba_layer(ks[i], cfg, dtype, fk))
                if md:
                    blk["mamba_dense"] = jax.tree.map(lambda *xs: jnp.stack(xs), *md)
                if mm:
                    blk["mamba_moe"] = jax.tree.map(lambda *xs: jnp.stack(xs), *mm)
                return blk
            params["layers"] = _stack_init(init_block, k_layers, self.n_blocks)
        return params

    # =========================================================================
    # caches
    # =========================================================================
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        cache: dict = {"pos": jnp.zeros((), jnp.int32)}
        n_attn = len(cfg.attn_layer_indices)
        if n_attn:
            cache["kv"] = jnp.zeros(
                (n_attn, 2, batch, max_len, cfg.num_kv_heads, hd), self.dtype)
        kinds = cfg.layer_kinds
        n_rwkv = sum(1 for k in kinds if k == "rwkv")
        if n_rwkv:
            h = cfg.d_model // cfg.rwkv_head_dim
            cache["rwkv_state"] = jnp.zeros(
                (n_rwkv, batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32)
            cache["rwkv_shift1"] = jnp.zeros((n_rwkv, batch, cfg.d_model), self.dtype)
            cache["rwkv_shift2"] = jnp.zeros((n_rwkv, batch, cfg.d_model), self.dtype)
        n_mamba = sum(1 for k in kinds if k == "mamba")
        if n_mamba:
            di = cfg.ssm_expand * cfg.d_model
            cache["mamba_h"] = jnp.zeros((n_mamba, batch, di, cfg.ssm_state_dim), jnp.float32)
            cache["mamba_conv"] = jnp.zeros(
                (n_mamba, batch, cfg.ssm_conv_dim - 1, di), self.dtype)
        return cache

    # =========================================================================
    # layer bodies (shared by train / prefill / decode)
    # =========================================================================
    def _attn_layer_fwd(self, lp, x, is_global, ffn_kind, mode):
        cfg = self.cfg
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if mode == "prefill":
            o, kv = attn.causal_attention(
                lp["attn"], h, cfg, is_global=is_global, return_kv=True)
        else:
            o = attn.causal_attention(lp["attn"], h, cfg, is_global=is_global)
            kv = None
        x = x + o
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if ffn_kind == "moe":
            f, aux = moe_ffn(lp["moe"], h2, cfg)
        else:
            f, aux = swiglu(h2, **lp["ffn"]), jnp.zeros((), jnp.float32)
        return x + f, aux, kv

    def _attn_layer_decode(self, lp, x, cache_kv, pos, is_global, ffn_kind):
        cfg = self.cfg
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        o, new_kv = attn.decode_attention(lp["attn"], h, cache_kv, pos, cfg,
                                          is_global=is_global)
        x = x + o
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if ffn_kind == "moe":
            f, _ = moe_ffn(lp["moe"], h2, cfg)
        else:
            f = swiglu(h2, **lp["ffn"])
        return x + f, new_kv

    def _rwkv_layer_fwd(self, lp, x, state, s1, s2, mode):
        cfg = self.cfg
        fn = ssm.rwkv_time_mix_step if mode == "decode" else ssm.rwkv_time_mix
        o, new_state, new_s1 = fn(lp["tmix"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                                  state, s1, cfg)
        x = x + o
        o2, new_s2 = ssm.rwkv_channel_mix(lp["cmix"], rms_norm(x, lp["ln2"], cfg.norm_eps), s2)
        return x + o2, new_state, new_s1, new_s2

    def _mamba_layer_fwd(self, lp, x, h_state, conv_state, ffn_kind, mode):
        cfg = self.cfg
        fn = ssm.mamba_step if mode == "decode" else ssm.mamba_forward
        o, new_h, new_conv = fn(lp["mamba"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                                h_state, conv_state, cfg)
        x = x + o
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if ffn_kind == "moe":
            f, aux = moe_ffn(lp["moe"], h2, cfg)
        else:
            f, aux = swiglu(h2, **lp["ffn"]), jnp.zeros((), jnp.float32)
        return x + f, aux, new_h, new_conv

    # =========================================================================
    # full-sequence forward (train / prefill)
    # =========================================================================
    def _forward_seq(self, params, tokens, mode: str):
        cfg = self.cfg
        x = self._constrain(embed_tokens(params, tokens).astype(self.dtype))
        b, s = tokens.shape
        flags = self._is_global_flags()
        aux_total = jnp.zeros((), jnp.float32)
        cache = self.init_cache(b, s) if mode == "prefill" else None

        if self.uniform_kind == "attn":
            ffn_kind = self.ffn_kinds[0]

            def body(carry, xs):
                xx, aux = carry
                lp, flag = xs
                xx = self._constrain(xx)
                xx, a, kv = self._attn_layer_fwd(lp, xx, flag, ffn_kind, mode)
                return (xx, aux + a), (jnp.stack(kv) if kv is not None else jnp.zeros((), self.dtype))

            if mode == "train":
                body = jax.checkpoint(body)
            xs = (params["layers"], flags if flags is not None
                  else jnp.zeros((cfg.num_layers,), bool))
            (x, aux_total), kvs = jax.lax.scan(body, (x, aux_total), xs)
            if mode == "prefill":
                cache["kv"] = kvs
                cache["pos"] = jnp.asarray(s, jnp.int32)

        elif self.uniform_kind == "rwkv":
            h = cfg.d_model // cfg.rwkv_head_dim
            st0 = jnp.zeros((b, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32)
            sh0 = jnp.zeros((b, cfg.d_model), self.dtype)

            def body(carry, lp):
                xx, aux = carry
                xx = self._constrain(xx)
                xx, st, sh1, sh2 = self._rwkv_layer_fwd(lp, xx, st0, sh0, sh0, mode)
                return (xx, aux), (st, sh1.astype(self.dtype), sh2.astype(self.dtype))

            if mode == "train":
                body = jax.checkpoint(body)
            (x, aux_total), (sts, sh1s, sh2s) = jax.lax.scan(body, (x, aux_total), params["layers"])
            if mode == "prefill":
                cache["rwkv_state"], cache["rwkv_shift1"], cache["rwkv_shift2"] = sts, sh1s, sh2s
                cache["pos"] = jnp.asarray(s, jnp.int32)

        else:  # jamba blocks
            di = cfg.ssm_expand * cfg.d_model
            h0 = jnp.zeros((b, di, cfg.ssm_state_dim), jnp.float32)
            c0 = jnp.zeros((b, cfg.ssm_conv_dim - 1, di), self.dtype)

            def block_body(carry, blk):
                xx, aux = carry
                xx = self._constrain(xx)
                i_md = i_mm = 0
                kvs, hs, convs = None, [], []
                for i, kind in enumerate(self.block_kinds):
                    fk = self._block_ffn_kind(i)
                    if kind == "attn":
                        xx, a, kv = self._attn_layer_fwd(blk["attn"], xx, None, fk, mode)
                        kvs = kv
                    else:
                        group, idx = ("mamba_moe", i_mm) if fk == "moe" else ("mamba_dense", i_md)
                        lp = jax.tree.map(lambda t: t[idx], blk[group])
                        xx, a, nh, nc = self._mamba_layer_fwd(lp, xx, h0, c0, fk, mode)
                        hs.append(nh)
                        convs.append(nc)
                        if fk == "moe":
                            i_mm += 1
                        else:
                            i_md += 1
                    aux = aux + a
                out = (jnp.stack(kvs) if kvs is not None else jnp.zeros((), self.dtype),
                       jnp.stack(hs), jnp.stack(convs))
                return (xx, aux), out

            if mode == "train":
                block_body = jax.checkpoint(block_body)
            (x, aux_total), (kvs, hs, convs) = jax.lax.scan(
                block_body, (x, aux_total), params["layers"])
            if mode == "prefill":
                cache["kv"] = kvs
                cache["mamba_h"] = hs.reshape(-1, *hs.shape[2:])
                cache["mamba_conv"] = convs.reshape(-1, *convs.shape[2:])
                cache["pos"] = jnp.asarray(s, jnp.int32)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux_total, cache

    # =========================================================================
    # public API
    # =========================================================================
    def loss(self, params, batch):
        x, aux, _ = self._forward_seq(params, batch["tokens"], "train")
        logits = lm_logits(params, x, self.cfg)
        mask = batch.get("mask")
        ce = softmax_cross_entropy(logits, batch["labels"], mask)
        return ce + aux, {"ce": ce, "aux": aux}

    def prefill(self, params, tokens):
        x, _, cache = self._forward_seq(params, tokens, "prefill")
        logits = lm_logits(params, x[:, -1], self.cfg)
        return logits, cache

    def decode_step(self, params, cache, tokens):
        """tokens (B,1) -> (logits (B,V), updated cache)."""
        cfg = self.cfg
        x = embed_tokens(params, tokens).astype(self.dtype)
        pos = cache["pos"]
        flags = self._is_global_flags()

        if self.uniform_kind == "attn":
            ffn_kind = self.ffn_kinds[0]

            def body(xx, xs):
                lp, kv_slice, flag = xs
                xx = self._constrain(xx)
                xx, new_kv = self._attn_layer_decode(lp, xx, kv_slice, pos, flag, ffn_kind)
                return xx, new_kv

            xs = (params["layers"], cache["kv"],
                  flags if flags is not None else jnp.zeros((cfg.num_layers,), bool))
            x, new_kvs = jax.lax.scan(body, x, xs)
            new_cache = dict(cache, kv=new_kvs, pos=pos + 1)

        elif self.uniform_kind == "rwkv":
            def body(xx, xs):
                lp, st, sh1, sh2 = xs
                xx = self._constrain(xx)
                xx, nst, ns1, ns2 = self._rwkv_layer_fwd(lp, xx, st, sh1, sh2, "decode")
                return xx, (nst, ns1.astype(self.dtype), ns2.astype(self.dtype))

            x, (sts, s1s, s2s) = jax.lax.scan(
                body, x, (params["layers"], cache["rwkv_state"],
                          cache["rwkv_shift1"], cache["rwkv_shift2"]))
            new_cache = dict(cache, rwkv_state=sts, rwkv_shift1=s1s,
                             rwkv_shift2=s2s, pos=pos + 1)

        else:  # jamba
            n_m = sum(1 for k in self.block_kinds if k != "attn")
            hs_in = cache["mamba_h"].reshape(self.n_blocks, n_m, *cache["mamba_h"].shape[1:])
            convs_in = cache["mamba_conv"].reshape(
                self.n_blocks, n_m, *cache["mamba_conv"].shape[1:])

            def block_body(xx, xs):
                blk, kv_slice, hs, convs = xs
                xx = self._constrain(xx)
                i_md = i_mm = i_m = 0
                new_kv, new_hs, new_convs = None, [], []
                for i, kind in enumerate(self.block_kinds):
                    fk = self._block_ffn_kind(i)
                    if kind == "attn":
                        xx, new_kv = self._attn_layer_decode(blk["attn"], xx, kv_slice, pos, None, fk)
                    else:
                        group, idx = ("mamba_moe", i_mm) if fk == "moe" else ("mamba_dense", i_md)
                        lp = jax.tree.map(lambda t: t[idx], blk[group])
                        xx, _, nh, nc = self._mamba_layer_fwd(lp, xx, hs[i_m], convs[i_m], fk, "decode")
                        new_hs.append(nh)
                        new_convs.append(nc)
                        i_m += 1
                        if fk == "moe":
                            i_mm += 1
                        else:
                            i_md += 1
                return xx, (new_kv, jnp.stack(new_hs), jnp.stack(new_convs))

            x, (kvs, hs, convs) = jax.lax.scan(
                block_body, x, (params["layers"], cache["kv"], hs_in, convs_in))
            new_cache = dict(cache, kv=kvs,
                             mamba_h=hs.reshape(-1, *hs.shape[2:]),
                             mamba_conv=convs.reshape(-1, *convs.shape[2:]),
                             pos=pos + 1)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_logits(params, x[:, 0], cfg)
        return logits, new_cache


# =============================================================================
# Encoder-decoder (seamless-m4t): audio-frame encoder stub input
# =============================================================================


class EncDecModel:
    """Enc-dec transformer; encoder consumes precomputed frame embeddings."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.is_encdec
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.act_sharding = None

    def _constrain(self, x):
        if self.act_sharding is not None and x.ndim == 3:
            return jax.lax.with_sharding_constraint(x, self.act_sharding)
        return x

    def init_params(self, key) -> dict:
        cfg, dtype = self.cfg, self.dtype
        k1, k2, k3 = jax.random.split(key, 3)
        params = init_embedding(k1, cfg, dtype)
        params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        params["enc_final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        params["encoder"] = _stack_init(
            lambda k: _init_encoder_layer(k, cfg, dtype), k2, cfg.num_encoder_layers)
        params["decoder"] = _stack_init(
            lambda k: _init_decoder_xattn_layer(k, cfg, dtype), k3, cfg.num_layers)
        return params

    def encode(self, params, frames):
        """frames (B, S_enc, d_model) — stub frontend output."""
        cfg = self.cfg
        x = frames.astype(self.dtype)

        def body(xx, lp):
            xx = self._constrain(xx)
            h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
            o = attn.causal_attention(lp["attn"], h, cfg, causal=False)
            xx = xx + o
            h2 = rms_norm(xx, lp["ln2"], cfg.norm_eps)
            return xx + swiglu(h2, **lp["ffn"]), None

        x, _ = jax.lax.scan(body, x, params["encoder"])
        return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)

    def _memory_kv(self, params, memory):
        cfg = self.cfg

        def body(_, lp):
            return None, jnp.stack(attn.project_memory_kv(lp["xattn"], memory, cfg))

        _, mkv = jax.lax.scan(body, None, params["decoder"])
        return mkv  # (L, 2, B, S_enc, KV, hd)

    def _decoder_layer(self, lp, x, mem_kv, mode, cache_kv=None, pos=None):
        cfg = self.cfg
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if mode == "decode":
            o, new_kv = attn.decode_attention(lp["attn"], h, cache_kv, pos, cfg)
        elif mode == "prefill":
            o, (k, v) = attn.causal_attention(lp["attn"], h, cfg, return_kv=True)
            new_kv = jnp.stack([k, v])
        else:
            o = attn.causal_attention(lp["attn"], h, cfg)
            new_kv = None
        x = x + o
        hx = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        x = x + attn.cross_attention(lp["xattn"], hx, (mem_kv[0], mem_kv[1]), cfg)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + swiglu(h2, **lp["ffn"]), new_kv

    def loss(self, params, batch):
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        mkvs = self._memory_kv(params, memory)
        x = embed_tokens(params, batch["tokens"]).astype(self.dtype)

        def body(xx, xs):
            lp, mkv = xs
            xx = self._constrain(xx)
            xx, _ = self._decoder_layer(lp, xx, mkv, "train")
            return xx, None

        body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, (params["decoder"], mkvs))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_logits(params, x, cfg)
        ce = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
        return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

    def init_cache(self, batch: int, max_len: int, enc_len: int) -> dict:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        return {
            "pos": jnp.zeros((), jnp.int32),
            "kv": jnp.zeros((cfg.num_layers, 2, batch, max_len, cfg.num_kv_heads, hd), self.dtype),
            "memory_kv": jnp.zeros(
                (cfg.num_layers, 2, batch, enc_len, cfg.num_kv_heads, hd), self.dtype),
        }

    def prefill(self, params, tokens, frames):
        cfg = self.cfg
        memory = self.encode(params, frames)
        mkvs = self._memory_kv(params, memory)
        x = embed_tokens(params, tokens).astype(self.dtype)

        def body(xx, xs):
            lp, mkv = xs
            xx = self._constrain(xx)
            xx, kv = self._decoder_layer(lp, xx, mkv, "prefill")
            return xx, kv

        x, kvs = jax.lax.scan(body, x, (params["decoder"], mkvs))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_logits(params, x[:, -1], cfg)
        cache = {"pos": jnp.asarray(tokens.shape[1], jnp.int32), "kv": kvs, "memory_kv": mkvs}
        return logits, cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = embed_tokens(params, tokens).astype(self.dtype)
        pos = cache["pos"]

        def body(xx, xs):
            lp, kv_slice, mkv = xs
            xx, new_kv = self._decoder_layer(lp, xx, mkv, "decode", kv_slice, pos)
            return xx, new_kv

        x, new_kvs = jax.lax.scan(body, x, (params["decoder"], cache["kv"], cache["memory_kv"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_logits(params, x[:, 0], cfg)
        return logits, dict(cache, kv=new_kvs, pos=pos + 1)


def build_model(cfg: ModelConfig):
    return EncDecModel(cfg) if cfg.is_encdec else LanguageModel(cfg)
