"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba selective SSM.

Both are implemented in a *chunked* form for training/prefill (sequence
split into chunks; inter-chunk state carried by lax.scan; intra-chunk
contributions computed with relative decays which are always <= 0 in log
space, so ``exp`` never overflows) and an O(1) single-step form for
decode.  TPU adaptation note (DESIGN.md §3): chunking is chosen so the
intra-chunk working set fits VMEM-scale tiles and matmul dims stay
MXU-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm

# ---------------------------------------------------------------------------
# RWKV6 time mix (data-dependent per-channel decay, matrix-valued state)
# ---------------------------------------------------------------------------

RWKV_CHUNK = 32
_DECAY_LORA = 64


def init_rwkv_time_mix(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    ks = jax.random.split(key, 8)
    p = {
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(jnp.float32),  # r,k,v,g,w mixes
        "wr": dense_init(ks[1], d, d, dtype),
        "wk": dense_init(ks[2], d, d, dtype),
        "wv": dense_init(ks[3], d, d, dtype),
        "wg": dense_init(ks[4], d, d, dtype),
        "w_lora_a": dense_init(ks[5], d, _DECAY_LORA, dtype),
        "w_lora_b": dense_init(ks[6], _DECAY_LORA, d, dtype),
        "w_bias": jnp.full((d,), -1.0, jnp.float32),
        "u": (jax.random.normal(ks[7], (h, hd), jnp.float32) * 0.1).astype(jnp.float32),
        "wo": dense_init(jax.random.fold_in(key, 99), d, d, dtype),
        "ln_x": jnp.zeros((d,), jnp.float32),
    }
    return p


def _rwkv_mix(x: jnp.ndarray, x_prev: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Token shift interpolation: x + mu*(shift(x) - x)."""
    return x + (x_prev - x) * mu.astype(x.dtype)


def _rwkv_projections(p: dict, x: jnp.ndarray, shift: jnp.ndarray, cfg: ModelConfig):
    """x (B,S,d), shift (B,d) = last token of previous segment."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    xs = jnp.concatenate([shift[:, None], x[:, :-1]], axis=1)
    mu = p["mu"]
    r = jnp.einsum("bsd,de->bse", _rwkv_mix(x, xs, mu[0]), p["wr"])
    k = jnp.einsum("bsd,de->bse", _rwkv_mix(x, xs, mu[1]), p["wk"])
    v = jnp.einsum("bsd,de->bse", _rwkv_mix(x, xs, mu[2]), p["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", _rwkv_mix(x, xs, mu[3]), p["wg"]).astype(jnp.float32))
    wx = _rwkv_mix(x, xs, mu[4])
    w_log = -jax.nn.softplus(
        (jnp.einsum("bsd,dr->bsr", wx, p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
        + p["w_bias"])                                        # (B,S,d) <= 0
    shape = (b, s, h, hd)
    return (r.reshape(shape).astype(jnp.float32), k.reshape(shape).astype(jnp.float32),
            v.reshape(shape).astype(jnp.float32), g, w_log.reshape(shape), x[:, -1])


def _rwkv_chunk(r, k, v, w_log, u, state):
    """One chunk of the WKV recurrence.

    r,k,v,w_log: (B,C,H,hd) fp32; u (H,hd); state (B,H,hd,hd).
    o_t = r_t . S_{t-1} + (r_t . (u*k_t)) v_t ;  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    Relative log decays b_t - a_j (j<t) are sums of w_log over (j, t) so
    they are <= 0 -> exp() is safe.
    """
    c = r.shape[1]
    a = jnp.cumsum(w_log, axis=1)            # inclusive  (B,C,H,hd)
    b_ex = a - w_log                          # exclusive
    # inter-chunk: o_inter[t] = (r_t * exp(b_t)) @ S0
    r_dec = r * jnp.exp(b_ex)
    o_inter = jnp.einsum("bchd,bhde->bche", r_dec, state)
    # intra-chunk strict-lower scores with per-dim relative decay
    dlog = b_ex[:, :, None] - a[:, None, :]   # (B,Ct,Cj,H,hd); <=0 for j<t
    mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])[None, :, :, None, None]
    dec = jnp.where(mask, jnp.exp(jnp.minimum(dlog, 0.0)), 0.0)
    scores = jnp.einsum("bthd,bjhd,btjhd->bhtj", r, k, dec)
    o_intra = jnp.einsum("bhtj,bjhe->bthe", scores, v)
    # bonus diagonal (current token, weight u)
    rb = jnp.einsum("bthd,hd,bthd->bth", r, u, k)
    o = o_inter + o_intra + rb[..., None] * v
    # state update: S_end = diag(exp(a_C)) S0 + sum_j (exp(a_C - a_j) * k_j)^T v_j
    a_last = a[:, -1]                         # (B,H,hd)
    k_dec = k * jnp.exp(a_last[:, None] - a)  # <=0 exponent
    new_state = state * jnp.exp(a_last)[..., None] + jnp.einsum("bjhd,bjhe->bhde", k_dec, v)
    return o, new_state


def rwkv_time_mix(p: dict, x: jnp.ndarray, state: jnp.ndarray, shift: jnp.ndarray,
                  cfg: ModelConfig, chunk: int = RWKV_CHUNK):
    """Full-sequence (train/prefill). Returns (out (B,S,d), state, shift)."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    r, k, v, g, w_log, new_shift = _rwkv_projections(p, x, shift, cfg)
    pad = (-s) % chunk
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        w_log = jnp.pad(w_log, ((0, 0), (0, pad), (0, 0), (0, 0)))  # decay 1, k=0 -> no-op
    nc = (s + pad) // chunk

    def body(st, xs):
        rc, kc, vc, wc = xs
        o, st2 = _rwkv_chunk(rc, kc, vc, wc, p["u"], st)
        return st2, o

    resh = lambda t: t.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    new_state, outs = jax.lax.scan(body, state, (resh(r), resh(k), resh(v), resh(w_log)))
    o = outs.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, hd)[:, :s]
    o = rms_norm(o, p["ln_x"].reshape(h, hd), cfg.norm_eps)  # per-head group norm
    o = (o.reshape(b, s, d) * g).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", o, p["wo"])
    return out, new_state, new_shift


def rwkv_time_mix_step(p: dict, x: jnp.ndarray, state: jnp.ndarray, shift: jnp.ndarray,
                       cfg: ModelConfig):
    """Single-token decode. x (B,1,d). Returns (out, state, shift)."""
    b, _, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    r, k, v, g, w_log, new_shift = _rwkv_projections(p, x, shift, cfg)
    r, k, v, w_log = (t[:, 0] for t in (r, k, v, w_log))     # (B,H,hd)
    o = jnp.einsum("bhd,bhde->bhe", r, state)
    rb = jnp.einsum("bhd,hd,bhd->bh", r, p["u"], k)
    o = o + rb[..., None] * v
    new_state = state * jnp.exp(w_log)[..., None] + jnp.einsum("bhd,bhe->bhde", k, v)
    o = rms_norm(o[:, None], p["ln_x"].reshape(h, hd), cfg.norm_eps)
    o = (o.reshape(b, 1, d) * g.reshape(b, 1, d)).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", o, p["wo"]), new_state, new_shift


def init_rwkv_channel_mix(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": jax.random.uniform(jax.random.fold_in(key, 1), (2, d), jnp.float32),
        "wk": dense_init(k1, d, cfg.d_ff, dtype),
        "wv": dense_init(k2, cfg.d_ff, d, dtype),
        "wr": dense_init(k3, d, d, dtype),
    }


def rwkv_channel_mix(p: dict, x: jnp.ndarray, shift: jnp.ndarray):
    """Squared-ReLU FFN with receptance gate; shift (B,d). Returns (out, shift)."""
    xs = jnp.concatenate([shift[:, None], x[:, :-1]], axis=1)
    xk = _rwkv_mix(x, xs, p["mu"][0])
    xr = _rwkv_mix(x, xs, p["mu"][1])
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"]).astype(jnp.float32)))
    vv = jnp.einsum("bsf,fd->bsd", k.astype(x.dtype), p["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]).astype(jnp.float32))
    return (r * vv.astype(jnp.float32)).astype(x.dtype), x[:, -1]


# ---------------------------------------------------------------------------
# Mamba selective SSM
# ---------------------------------------------------------------------------

MAMBA_CHUNK = 128


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    rank = max(d // 16, 1)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_dim, di), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], di, rank + 2 * n, dtype),
        "dt_proj": dense_init(ks[3], rank, di, dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _mamba_conv(p: dict, x: jnp.ndarray, conv_state: jnp.ndarray):
    """Causal depthwise conv, kernel k. x (B,S,di); conv_state (B,k-1,di)."""
    kk = p["conv_w"].shape[0]
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(kk):
        out = out + xp[:, j:j + x.shape[1]].astype(jnp.float32) * p["conv_w"][j].astype(jnp.float32)
    out = out + p["conv_b"]
    new_state = xp[:, -(kk - 1):] if kk > 1 else conv_state
    return out.astype(x.dtype), new_state


def _mamba_scan_inputs(p: dict, xc: jnp.ndarray, cfg: ModelConfig):
    """xc (B,S,di) post-conv+silu -> dt (B,S,di) fp32, B/C (B,S,N) fp32."""
    n = cfg.ssm_state_dim
    rank = p["dt_proj"].shape[0]
    proj = jnp.einsum("bsd,de->bse", xc, p["x_proj"])
    dt_r, bm, cm = jnp.split(proj, [rank, rank + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_r, p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])
    return dt, bm.astype(jnp.float32), cm.astype(jnp.float32)


def mamba_forward(p: dict, x: jnp.ndarray, h_state: jnp.ndarray, conv_state: jnp.ndarray,
                  cfg: ModelConfig, chunk: int = MAMBA_CHUNK):
    """Full-sequence. x (B,S,d); h_state (B,di,N) fp32; conv (B,k-1,di).
    Returns (out (B,S,d), h_state, conv_state)."""
    b, s, d = x.shape
    n = cfg.ssm_state_dim
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc_raw, new_conv = _mamba_conv(p, x_in, conv_state)
    xc = jax.nn.silu(xc_raw.astype(jnp.float32)).astype(x.dtype)
    dt, bm, cm = _mamba_scan_inputs(p, xc, cfg)
    a_mat = -jnp.exp(p["a_log"])                            # (di,N) < 0
    pad = (-s) % chunk
    if pad:
        xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))        # dt=0 -> a=1,b=0: no-op
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    else:
        xc_p = xc
    nc = (s + pad) // chunk
    di = xc.shape[-1]
    resh3 = lambda t: t.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)

    def body(h0, xs):
        dtc, bc, cc, xcc = xs                               # (B,C,di)/(B,C,N)
        a = jnp.exp(dtc[..., None] * a_mat)                 # (B,C,di,N) in (0,1]
        bx = (dtc * xcc.astype(jnp.float32))[..., None] * bc[:, :, None, :]

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        a_cum, b_scan = jax.lax.associative_scan(combine, (a, bx), axis=1)
        h = a_cum * h0[:, None] + b_scan                    # (B,C,di,N)
        y = jnp.einsum("bcdn,bcn->bcd", h, cc)
        return h[:, -1], y

    new_h, ys = jax.lax.scan(body, h_state, (resh3(dt), resh3(bm), resh3(cm), resh3(xc_p)))
    y = ys.transpose(1, 0, 2, 3).reshape(b, nc * chunk, di)[:, :s]
    y = y + p["d_skip"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), new_h, new_conv


def mamba_step(p: dict, x: jnp.ndarray, h_state: jnp.ndarray, conv_state: jnp.ndarray,
               cfg: ModelConfig):
    """Single-token decode. x (B,1,d)."""
    b = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc_raw, new_conv = _mamba_conv(p, x_in, conv_state)
    xc = jax.nn.silu(xc_raw.astype(jnp.float32)).astype(x.dtype)
    dt, bm, cm = _mamba_scan_inputs(p, xc, cfg)
    a_mat = -jnp.exp(p["a_log"])
    a = jnp.exp(dt[:, 0, :, None] * a_mat)                  # (B,di,N)
    bx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * bm[:, 0, None, :]
    new_h = a * h_state + bx
    y = jnp.einsum("bdn,bn->bd", new_h, cm[:, 0])
    y = y + p["d_skip"] * xc[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None], new_h, new_conv
