"""GQA attention: training/prefill (optionally query-chunked for long S,
sliding-window masks) and single-token decode against a KV cache.

Conventions: activations (B, S, d); q/k/v (B, S, H, hd); caches
(2, B, Smax, n_kv, hd) per layer (stacked on a leading layer dim by the
model).  All softmax math in fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_norm, rope_at

NEG_INF = -1e30
LOCAL_ROPE_THETA = 10_000.0  # gemma3: local layers use 10k, global layers cfg.rope_theta


def _dual_rope(positions: jnp.ndarray, hd: int, cfg: ModelConfig,
               is_global: Optional[jnp.ndarray], rope_theta: Optional[float]):
    """sin/cos; when ``is_global`` is traced and the arch mixes local/global
    layers, select between the local (10k) and global (cfg.rope_theta) tables."""
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    if is_global is None or cfg.global_attn_every == 0:
        return rope_at(positions, hd, theta)
    sg, cg = rope_at(positions, hd, theta)
    sl, cl = rope_at(positions, hd, LOCAL_ROPE_THETA)
    return jnp.where(is_global, sg, sl), jnp.where(is_global, cg, cl)


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, h * hd, dtype),
        "wk": dense_init(k2, d, kv * hd, dtype),
        "wv": dense_init(k3, d, kv * hd, dtype),
        "wo": dense_init(k4, h * hd, d, dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(p: dict, xq: jnp.ndarray, xkv: jnp.ndarray, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", xq, p["wq"]).reshape(*xq.shape[:2], cfg.num_heads, hd)
    k = jnp.einsum("bsd,de->bse", xkv, p["wk"]).reshape(*xkv.shape[:2], cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", xkv, p["wv"]).reshape(*xkv.shape[:2], cfg.num_kv_heads, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q (B,Sq,H,hd), k (B,Sk,KV,hd) -> scores (B, KV, G, Sq, Sk) fp32."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    return s / (hd ** 0.5)


def _gqa_out(probs: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """probs (B,KV,G,Sq,Sk), v (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    b, kvh, g, sq, _ = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, kvh * g, v.shape[-1])


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: Optional[int],
               k_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Additive bias (…, Sq, Sk): causal (+ sliding window, + validity)."""
    causal = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        causal &= (q_pos[:, None] - k_pos[None, :]) < window
    bias = jnp.where(causal, 0.0, NEG_INF)
    if k_valid is not None:
        bias = jnp.where(k_valid[None, :], bias, NEG_INF)
    return bias


def causal_attention(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                     window: Optional[int] = None,
                     is_global: Optional[jnp.ndarray] = None,
                     positions: Optional[jnp.ndarray] = None,
                     rope_theta: Optional[float] = None,
                     q_chunk: int = 1024,
                     causal: bool = True,
                     return_kv: bool = False):
    """Full-sequence causal attention (train / prefill).

    ``is_global`` (traced bool, for scan-uniform layer stacks): when given
    and False, the per-arch sliding window applies; when True, full causal.
    Query-chunked via lax.scan when S > q_chunk to bound the score
    materialization at (B,H,q_chunk,S).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    sin, cos = _dual_rope(positions, cfg.resolved_head_dim, cfg, is_global, rope_theta)
    q, k, v = _project_qkv(p, x, x, cfg)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)

    win = cfg.sliding_window if window is None else window

    def attend(q_blk, qpos_blk):
        scores = _gqa_scores(q_blk, k)  # (B,KV,G,sq,S)
        if not causal:
            probs = jax.nn.softmax(scores, axis=-1)
            return _gqa_out(probs, v)
        bias_local = _mask_bias(qpos_blk, positions, win)
        bias_full = _mask_bias(qpos_blk, positions, None)
        if is_global is None or win is None:
            bias = bias_local if win is not None else bias_full
        else:
            bias = jnp.where(is_global, bias_full, bias_local)
        probs = jax.nn.softmax(scores + bias, axis=-1)
        return _gqa_out(probs, v)

    if s > q_chunk and s % q_chunk == 0:
        nq = s // q_chunk
        qs = q.reshape(b, nq, q_chunk, *q.shape[2:]).transpose(1, 0, 2, 3, 4)
        ps = positions.reshape(nq, q_chunk)

        def body(_, xs):
            q_blk, qpos_blk = xs
            return None, attend(q_blk, qpos_blk)

        _, outs = jax.lax.scan(body, None, (qs, ps))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, cfg.num_heads, -1)
    else:
        out = attend(q, positions)

    o = jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p["wo"])
    if return_kv:
        return o, (k, v)
    return o


def decode_attention(p: dict, x: jnp.ndarray, cache_kv: jnp.ndarray,
                     pos: jnp.ndarray, cfg: ModelConfig, *,
                     window: Optional[int] = None,
                     is_global: Optional[jnp.ndarray] = None,
                     rope_theta: Optional[float] = None):
    """One-token decode. x (B,1,d); cache_kv (2,B,Smax,KV,hd); pos scalar =
    index where the new token's K/V is written (number of tokens already
    in the cache).  Returns (out (B,1,d), updated cache_kv).
    """
    b = x.shape[0]
    smax = cache_kv.shape[2]
    sin, cos = _dual_rope(pos[None], cfg.resolved_head_dim, cfg, is_global, rope_theta)
    q, k, v = _project_qkv(p, x, x, cfg)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    # write new kv at slot `pos`
    cache_k = jax.lax.dynamic_update_slice(cache_kv[0], k.astype(cache_kv.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_kv[1], v.astype(cache_kv.dtype), (0, pos, 0, 0))
    k_pos = jnp.arange(smax)
    valid = k_pos <= pos
    scores = _gqa_scores(q, cache_k)  # (B,KV,G,1,Smax)
    win = cfg.sliding_window if window is None else window
    dist = pos - k_pos
    in_win = (dist < win) if win is not None else jnp.ones_like(valid)
    if is_global is not None and win is not None:
        keep = valid & (in_win | is_global)
    elif win is not None:
        keep = valid & in_win
    else:
        keep = valid
    bias = jnp.where(keep, 0.0, NEG_INF)[None, None, None, None, :]
    probs = jax.nn.softmax(scores + bias, axis=-1)
    out = _gqa_out(probs, cache_v).reshape(b, 1, -1)
    o = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return o, jnp.stack([cache_k, cache_v])


def cross_attention(p: dict, x: jnp.ndarray, memory_kv: tuple[jnp.ndarray, jnp.ndarray],
                    cfg: ModelConfig, memory_valid: Optional[jnp.ndarray] = None):
    """Decoder->encoder cross attention; memory_kv precomputed (K, V)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k, v = memory_kv
    scores = _gqa_scores(q, k)
    if memory_valid is not None:
        scores = jnp.where(memory_valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v).reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", out, p["wo"])


def project_memory_kv(p: dict, memory: jnp.ndarray, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    b, t, _ = memory.shape
    k = jnp.einsum("btd,de->bte", memory, p["wk"]).reshape(b, t, cfg.num_kv_heads, hd)
    v = jnp.einsum("btd,de->bte", memory, p["wv"]).reshape(b, t, cfg.num_kv_heads, hd)
    return k, v
