"""Mixture-of-Experts FFN with capacity-bounded sort-scatter dispatch.

TPU adaptation (DESIGN.md §3/§5): instead of the GShard (G,S,E,C) one-hot
dispatch einsum (O(n*E*C) memory — infeasible at kimi scale: 1M tokens x
384 experts), tokens are ranked inside their expert segment via a single
argsort + bincount, scattered into a dense (E, C, d) buffer, processed
with one batched expert matmul (MXU-friendly), and gathered back.  Expert
dim E is sharded over `model` when divisible (expert parallel — GSPMD
inserts the all-to-all at the data->expert boundary); otherwise d_expert
is sharded (per-expert tensor parallel).  Aux load-balance loss follows
Switch/GShard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, init_ffn, swiglu


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    ew = lambda k, a, b: (jax.random.normal(k, (m.num_experts, a, b), jnp.float32)
                          / (a ** 0.5)).astype(dtype)
    p = {
        "router": dense_init(ks[0], d, m.num_experts, jnp.float32),
        "w_gate": ew(ks[1], d, m.d_expert),
        "w_up": ew(ks[2], d, m.d_expert),
        "w_down": ew(ks[3], m.d_expert, d),
    }
    if m.num_shared_experts:
        d_sh = m.d_shared or m.num_shared_experts * m.d_expert
        p["shared"] = init_ffn(ks[4], d, d_sh, dtype)
    return p


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    return max(8, min(c, n_tokens))


def moe_ffn(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    """x (B,S,d) -> (out (B,S,d), aux_loss scalar fp32)."""
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.num_experts, m.top_k
    xf = x.reshape(n, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (n,E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                      # (n,k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # ---- dispatch: rank within expert segment via sort -------------------
    c = capacity(n, cfg)
    ef = idx.reshape(-1)                                     # (n*k,)
    order = jnp.argsort(ef)                                  # stable
    se = ef[order]
    counts = jnp.bincount(ef, length=e)                      # (E,)
    seg_start = jnp.cumsum(counts) - counts
    pos = jnp.arange(n * k) - seg_start[se]                  # rank in segment
    keep = pos < c
    slot = se * c + pos                                      # (n*k,) sorted order
    tok = order // k

    buf = jnp.zeros((e * c, d), x.dtype)
    buf = buf.at[jnp.where(keep, slot, e * c)].set(xf[tok], mode="drop")
    h = buf.reshape(e, c, d)

    # ---- expert computation (batched over E) -----------------------------
    hg = jnp.einsum("ecd,edf->ecf", h, p["w_gate"])
    hu = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    hh = jax.nn.silu(hg.astype(jnp.float32)).astype(x.dtype) * hu
    y = jnp.einsum("ecf,efd->ecd", hh, p["w_down"]).reshape(e * c, d)

    # ---- combine ----------------------------------------------------------
    contrib_sorted = y[jnp.minimum(slot, e * c - 1)] * keep[:, None].astype(y.dtype)
    inv = jnp.argsort(order)
    contrib = contrib_sorted[inv].reshape(n, k, d)
    out = jnp.sum(contrib * gate[..., None].astype(y.dtype), axis=1)

    if "shared" in p:
        out = out + swiglu(xf, **p["shared"])

    # ---- Switch-style aux load-balance loss --------------------------------
    frac_tokens = jnp.bincount(ef, length=e).astype(jnp.float32) / (n * k)
    frac_probs = jnp.mean(probs, axis=0)
    aux = m.router_aux_loss * e * jnp.sum(frac_tokens * frac_probs)

    return out.reshape(b, s, d), aux
