from repro.models.model import EncDecModel, LanguageModel, build_model

__all__ = ["EncDecModel", "LanguageModel", "build_model"]
