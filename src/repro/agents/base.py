"""Kairos developer API (paper Listing 1): ``BaseAgent`` + ``Workflow``.

Agents subclass :class:`BaseAgent`, override ``_run_impl`` and call
``self.generate(...)`` to hit the shared LLM service — the call blocks
(the paper's multi-threaded architecture) while the driver loop runs the
load balancer and engine iterations.  System identifiers are injected and
propagated transparently through the message bus.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.agents.messaging import Headers, MessageBus
from repro.core import Orchestrator
from repro.core.orchestrator import HardwareProfile
from repro.models import build_model
from repro.obs.trace import NULL_TRACER, TraceContext, Tracer
from repro.serving import LLMEngine, ServingCluster, ServingConfig
from repro.serving.request import Request, RequestState


class BaseAgent:
    """Subclass and override ``_run_impl(input_data, metadata)``; return
    ``(output_payload, next_agent_name_or_None)``.

    ``system_prompt`` (class attribute or ``add_agent`` argument) declares
    the agent's fixed preamble.  It is prepended to every ``generate``
    call and flagged as a shareable prefix, so engines with prefix caching
    serve its KV from shared pages instead of re-prefilling it, and the
    dispatcher's memory ramps stop double-counting it."""

    system_prompt: str = ""

    def __init__(self, name: str, workflow: "Workflow"):
        self.name = name
        self.workflow = workflow
        self._sys_tokens: Optional[np.ndarray] = None

    def system_prompt_tokens(self) -> np.ndarray:
        if self._sys_tokens is None:
            self._sys_tokens = (self.encode_prompt(self.system_prompt)
                                if self.system_prompt
                                else np.zeros((0,), np.int32))
        return self._sys_tokens

    # -- LLM access (Listing 1: ``self.generate``) ---------------------------
    def generate(self, prompt_tokens, metadata: Headers, max_new_tokens: int = 16) -> List[int]:
        sys_toks = self.system_prompt_tokens()
        shared = len(sys_toks)
        if shared:
            prompt_tokens = np.concatenate(
                [sys_toks, np.asarray(prompt_tokens, np.int32)])
        return self.workflow._llm_call(self.name, prompt_tokens, metadata,
                                       max_new_tokens, shared_prefix_len=shared)

    def encode_prompt(self, text: str, length: Optional[int] = None) -> np.ndarray:
        """Deterministic synthetic tokenizer stand-in."""
        rng = np.random.default_rng(abs(hash(text)) & 0x7FFFFFFF)
        n = length or max(4, len(text) // 4)
        return rng.integers(0, self.workflow.vocab_size, n).astype(np.int32)

    def _run_impl(self, input_data: dict, metadata: Headers) -> Tuple[dict, Optional[str]]:
        raise NotImplementedError


class Workflow:
    """Define engines + agents, then ``run(...)`` user tasks through the
    Kairos load balancer over real paged-KV engine instances.

    Serving knobs come in as ONE :class:`ServingConfig` (``config=``).
    The pre-PR-8 per-knob constructor kwargs (``num_blocks=...``, ...)
    finished their one-release deprecation window and now raise
    ``TypeError`` pointing at ``ServingConfig``."""

    _REMOVED_KWARGS = ("n_instances", "num_blocks", "block_size",
                       "max_batch", "prefix_caching",
                       "prefill_chunk_tokens")

    def __init__(self, app_name: str = "app",
                 config: Optional[ServingConfig] = None, *,
                 pipelined: bool = True, llm_timeout_s: float = 300.0,
                 tracer: Tracer = NULL_TRACER, **legacy):
        if legacy:
            removed = sorted(k for k in legacy if k in self._REMOVED_KWARGS)
            if removed:
                raise TypeError(
                    "Workflow's per-knob serving kwargs were removed; pass "
                    f"config=ServingConfig({', '.join(removed)}, ...) "
                    "instead")
            raise TypeError(
                f"unexpected keyword arguments {sorted(legacy)}")
        if config is None:
            config = ServingConfig(max_batch=4)
        self.app_name = app_name
        self.config = config
        self.prefix_caching = config.prefix_caching
        self.prefill_chunk_tokens = config.prefill_chunk_tokens
        self.pipelined = pipelined
        self.llm_timeout_s = llm_timeout_s
        self.tracer = tracer
        self.bus = MessageBus()
        self.orch = Orchestrator(hardware=HardwareProfile(
            decode_tok_per_s=20.0,
            kv_capacity_tokens=config.kv_capacity_tokens),
            prefix_caching=config.prefix_caching, tracer=tracer)
        self.agents: Dict[str, BaseAgent] = {}
        self.vocab_size = 512
        self._submissions: "queue.Queue[Tuple[Request, threading.Event, list]]" = queue.Queue()
        self._pending: Dict[int, Tuple[Request, threading.Event, list]] = {}
        self._threads: List[threading.Thread] = []
        self._results: Dict[str, dict] = {}
        self._outstanding = 0
        self._lock = threading.Lock()
        self.cluster: Optional[ServingCluster] = None

    @property
    def balancer(self):
        """Back-compat alias: the cluster owns the load balancer now."""
        return self.cluster.balancer if self.cluster is not None else None

    @property
    def engines(self) -> List[LLMEngine]:
        """Back-compat alias; under elasticity the engine list changes at
        runtime, so don't cache it — prefer the cluster contract
        (``submit``/``step``/``drain``/``metrics_snapshot``)."""
        return self.cluster.engines if self.cluster is not None else []

    # ------------------------------------------------------------------ setup
    def add_engine(self, name: str, model: str = "qwen3-1.7b", seed: int = 0):
        """Instantiate the serving cluster described by ``self.config``,
        serving the REDUCED variant of the named architecture (CPU
        container; full configs go through the dry-run).
        ``ServingCluster.from_config`` wires everything the hand-rolled
        loop used to: per-instance runners cloned from one compile,
        orchestrator-backed instance scheduling, OOM fencing feedback,
        the instance schedulers' ``can_admit`` as the dispatcher's admit
        probe — and an engine factory so an attached autoscaler can grow
        the cluster later."""
        from repro.configs import get_config
        cfg = get_config(model).reduced()
        self.vocab_size = cfg.vocab_size
        m = build_model(cfg)
        params = m.init_params(jax.random.PRNGKey(seed))
        self.cluster = ServingCluster.from_config(
            m, params, self.orch, self.config,
            pipelined=self.pipelined, tracer=self.tracer)

    def add_agent(self, agent_name: str, agent_class, use_model: str = "",
                  system_prompt: Optional[str] = None):
        agent = agent_class(agent_name, self)
        if system_prompt is not None:
            agent.system_prompt = system_prompt
        self.agents[agent_name] = agent
        self.bus.subscribe(agent_name, self._on_message)

    # ------------------------------------------------------------------ llm
    def _llm_call(self, agent_name: str, prompt_tokens, metadata: Headers,
                  max_new_tokens: int, shared_prefix_len: int = 0) -> List[int]:
        retries = self.config.llm_retries
        backoff = self.config.llm_backoff_s
        for attempt in range(retries + 1):
            req = Request(
                agent_name=agent_name, msg_id=metadata.msg_id,
                upstream_name=metadata.upstream_name, app_name=metadata.app_name,
                prompt_len=len(prompt_tokens), prompt_tokens=np.asarray(prompt_tokens),
                max_new_tokens=max_new_tokens,
                shared_prefix_len=shared_prefix_len, cache_key=agent_name,
                arrival_time=time.monotonic(), app_start_time=metadata.app_start_time)
            if self.tracer.enabled:
                # workflow trace context: msg_id is the trace id, this LLM
                # call is one span, descended from the upstream agent stage —
                # obs/critical_path.py stitches these into the workflow DAG
                req.trace = TraceContext(trace_id=metadata.msg_id,
                                         span_id=req.req_id,
                                         parent_name=metadata.upstream_name)
            ev = threading.Event()
            box: list = []
            self._submissions.put((req, ev, box))
            if ev.wait(timeout=self.llm_timeout_s):
                if req.state in (RequestState.FAILED, RequestState.SHED):
                    # the serving layer gave up on this request (recovery
                    # budget spent, or the overload valve shed it) — fail
                    # the workflow rather than hand back a bogus stream
                    raise RuntimeError(
                        f"LLM call by agent {agent_name!r} "
                        f"(msg {metadata.msg_id}) was "
                        f"{'shed' if req.state is RequestState.SHED else 'failed'}"
                        " by the serving layer")
                return box[0]
            if attempt < retries:
                # capped exponential backoff, then a FRESH request: the
                # timed-out one may still finish later — its orphaned
                # event/box pair just gets dropped.  Retries stay inside
                # this call, so the workflow's outstanding count is
                # untouched until the stage truly fails.
                time.sleep(min(backoff * (2.0 ** attempt), 8.0 * backoff))
        # surface the deadlock instead of masking it as an empty
        # generation: the exception propagates through the agent
        # thread, which marks this workflow failed in the results
        raise TimeoutError(
            f"LLM call by agent {agent_name!r} (msg {metadata.msg_id}) "
            f"timed out after {self.llm_timeout_s:.0f}s "
            f"({retries + 1} attempt{'s' if retries else ''})")

    # ------------------------------------------------------------------ agents
    def _on_message(self, msg):
        agent = self.agents[msg.topic]

        def work():
            try:
                out, nxt = agent._run_impl(msg.payload, msg.headers)
            except Exception as e:
                # a failed stage (e.g. an LLM-call TimeoutError) ends its
                # workflow with an error result instead of hanging run()
                # on an _outstanding count that never reaches zero
                with self._lock:
                    self._results[msg.headers.msg_id] = {
                        "failed": True, "agent": agent.name,
                        "error": f"{type(e).__name__}: {e}"}
                    self._outstanding -= 1
                # finalize the partial trace like the success path does:
                # earlier stages' completion records must not park in the
                # analyzer forever (and their latency samples still feed
                # the priority distributions)
                self.orch.on_workflow_complete(msg.headers.msg_id)
                return
            if nxt is not None:
                self.bus.publish(nxt, out, Headers(
                    msg_id=msg.headers.msg_id, app_name=msg.headers.app_name,
                    upstream_name=agent.name,
                    app_start_time=msg.headers.app_start_time))
            else:
                with self._lock:
                    self._results[msg.headers.msg_id] = out
                    self._outstanding -= 1
                self.orch.on_workflow_complete(msg.headers.msg_id)

        t = threading.Thread(target=work, daemon=True)
        t.start()
        self._threads.append(t)

    # ------------------------------------------------------------ observability
    def trace_spans(self):
        """Agent-stage spans stitched from the shared tracer's event
        streams (one span per LLM call, linked by upstream agent)."""
        from repro.obs.critical_path import spans_from_events
        return spans_from_events(self.tracer.events())

    def critical_path(self, msg_id: str):
        """End-to-end critical path of one workflow: the causal chain of
        agent stages ending at the last finisher, with per-stage
        queue/prefill/decode and orchestration-gap breakdown."""
        from repro.obs.critical_path import critical_path
        return critical_path(self.trace_spans(), msg_id)

    def metrics_snapshot(self) -> dict:
        """The cluster's flattened metrics registry snapshot."""
        assert self.cluster is not None, "call add_engine first"
        return self.cluster.metrics_snapshot()

    def prefix_cache_stats(self) -> dict:
        """Aggregate prefill-token savings across engine instances,
        derived from the cluster's public metrics snapshot."""
        snap = self.cluster.metrics_snapshot()

        def total(metric: str) -> float:
            return sum(v for k, v in snap.items()
                       if k.endswith(f".{metric}"))

        saved = total("prefill_tokens_saved")
        prefill = total("prefill_tokens")
        return {"prefill_tokens": prefill, "prefill_tokens_saved": saved,
                "kv_cached_tokens": total("kv_cached_tokens"),
                "savings": saved / max(prefill + saved, 1)}

    # ------------------------------------------------------------------ run
    def submit_task(self, entry_agent: str, input_data: dict) -> str:
        msg_id = self.bus.new_msg_id(self.app_name)
        with self._lock:
            self._outstanding += 1
        self.bus.publish(entry_agent, input_data, Headers(
            msg_id=msg_id, app_name=self.app_name, upstream_name=None,
            app_start_time=time.monotonic()))
        return msg_id

    def run(self, timeout: float = 300.0) -> Dict[str, dict]:
        """Driver loop: drain bus -> agent threads -> cluster step.

        The cluster step runs the balancer tick, the breadth-first
        pipelined engine iterations, and the control-plane feedback
        (completion records, dispatcher slot release, OOM fencing); this
        loop only bridges agent threads to it."""
        assert self.cluster is not None, "call add_engine first"
        t_end = time.monotonic() + timeout
        while time.monotonic() < t_end:
            with self._lock:
                if self._outstanding == 0 and self._submissions.empty():
                    break
            # prune finished agent threads (long-lived workflows would
            # otherwise accumulate one dead Thread object per message)
            self._threads = [t for t in self._threads if t.is_alive()]
            self.bus.drain()
            while not self._submissions.empty():
                req, ev, box = self._submissions.get()
                self._pending[req.req_id] = (req, ev, box)
                self.cluster.submit(req)
            for r in self.cluster.step():
                _, ev, box = self._pending.pop(r.req_id)
                box.append(list(r.output_tokens))
                ev.set()
            if not self.cluster.has_work:
                time.sleep(0.002)
        return dict(self._results)
