from repro.agents.base import BaseAgent, Workflow
from repro.agents.messaging import Headers, Message, MessageBus

__all__ = ["BaseAgent", "Workflow", "Headers", "Message", "MessageBus"]
