"""In-process message bus — the Kafka stand-in (DESIGN.md §7).

Same pub/sub + header-propagation semantics the paper uses Kafka for:
topics per agent, messages carry the Kairos system identifiers in headers
(msg_id, upstream, app, application-level start time) and are delivered
in publish order by the workflow driver.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional

_msg_counter = itertools.count()


@dataclasses.dataclass
class Headers:
    """Transparently propagated system identifiers (§4.1)."""
    msg_id: str
    app_name: str
    upstream_name: Optional[str]
    app_start_time: float


@dataclasses.dataclass
class Message:
    topic: str
    payload: dict
    headers: Headers


class MessageBus:
    """Synchronous topic queue with subscriber callbacks (drained by the
    workflow driver loop — swap-in point for a real Kafka client)."""

    def __init__(self):
        self._queues: Dict[str, collections.deque] = collections.defaultdict(collections.deque)
        self._subs: Dict[str, List[Callable[[Message], None]]] = collections.defaultdict(list)

    def subscribe(self, topic: str, fn: Callable[[Message], None]):
        self._subs[topic].append(fn)

    def publish(self, topic: str, payload: dict, headers: Headers):
        self._queues[topic].append(Message(topic, payload, headers))

    def drain(self, max_messages: int = 256) -> int:
        n = 0
        for topic, q in list(self._queues.items()):
            while q and n < max_messages:
                msg = q.popleft()
                for fn in self._subs.get(topic, ()):
                    fn(msg)
                n += 1
        return n

    @staticmethod
    def new_msg_id(app: str) -> str:
        return f"{app}-{next(_msg_counter)}-{int(time.time()*1e3) % 100000}"
