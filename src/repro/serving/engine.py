"""LLM engine instance: paged-KV model runner + continuous batching.

``PagedModelRunner`` executes real tokens with the paged KV pool (the
Pallas kernel's layout; ref backend on CPU, pallas on TPU).
``LLMEngine`` implements vLLM-style continuous batching with dynamic
memory allocation and preemption-by-recompute — the behaviours the paper's
dispatcher is designed around (§2.2.3).

Engines expose the *status monitor* surface Kairos polls (§3 overview):
KV memory in use / capacity, running/waiting counts, preemption counter.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.scheduler import SchedulerPolicy
from repro.kernels import ops as kops
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER, Tracer
from repro.models import attention as attn_mod
from repro.models.layers import embed_tokens, lm_logits, rms_norm, swiglu
from repro.models.model import LanguageModel
from repro.models.moe import moe_ffn
from repro.models.sharding import (
    POOL_PSPEC,
    serving_param_specs,
    validate_serving_tp,
)
from repro.serving.batch_scheduler import (
    BatchScheduler,
    IterationBatch,
    SchedStats,
    TokenPrefixMatcher,
    flatten_plan,
)
from repro.serving.faults import InstanceCrashed
from repro.serving.kv_cache import BlockManager
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request


# =============================================================================
# Paged model runner (uniform-attention architectures)
# =============================================================================


def _layer_qkv(lp, xx, sin, cos, cfg):
    """Shared transformer-layer head for every runner path: pre-norm, QKV
    projection, RoPE on q/k.  The paths differ only in how the fresh KV is
    scattered and which attention kernel consumes it."""
    h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
    q, k, v = attn_mod._project_qkv(lp["attn"], h, h, cfg)
    return attn_mod.apply_rope(q, sin, cos), attn_mod.apply_rope(k, sin, cos), v


def _layer_finish(xx, o, lp, cfg, axis: Optional[str] = None):
    """Shared transformer-layer tail: attention output projection and the
    FFN/MoE block, both residual.  ``o`` is (B, S, H*hd).

    ``axis`` names the tensor-parallel mesh axis when this body runs
    inside shard_map: ``o`` then holds the LOCAL head slice and ``wo``
    the matching row slice, so the projection yields a partial sum —
    the all-reduce here, plus the matching one after the row-sharded
    FFN down-projection, are the standard two megatron collectives per
    layer (the only ones on the sharded hot path).  Both partial sums
    are accumulated and psum'd in fp32, rounding to the activation
    dtype once AFTER the full contraction — the same rounding point as
    the unsharded einsum, which is what keeps tp>1 token streams
    bit-identical to the tp=1 differential baseline in bf16."""
    if axis is not None:
        attn_out = jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"],
                              preferred_element_type=jnp.float32)
        attn_out = jax.lax.psum(attn_out, axis).astype(xx.dtype)
    else:
        attn_out = jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"])
    xx = xx + attn_out
    h2 = rms_norm(xx, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        f, _ = moe_ffn(lp["moe"], h2, cfg)
        if axis is not None:
            f = jax.lax.psum(f, axis)
    elif axis is not None:
        f = swiglu(h2, **lp["ffn"], preferred_element_type=jnp.float32)
        f = jax.lax.psum(f, axis).astype(xx.dtype)
    else:
        f = swiglu(h2, **lp["ffn"])
    return xx + f


class PagedModelRunner:
    """Runs a :class:`LanguageModel` against a paged KV pool.

    Pool: (L, 2, num_blocks, block_size, n_kv, hd).  Decode is batched
    across sequences at arbitrary positions via block tables.

    **In-place pool semantics** (``donate_pool``, default on): every
    jitted step function *donates* the pool argument, so XLA writes the
    updated pool into the very buffer it read — one resident pool buffer
    per runner for the lifetime of the process, zero pool-copy bytes per
    dispatch.  Without donation each dispatch materializes a second
    full-size pool buffer just to change a few KV rows (and the pre-PR5
    out-of-jit ``at[].set`` writes in ``prefill``/``copy_block`` copied
    the whole pool *again* to write one block).  The donation invariant:
    a pool reference passed to a step function is DEAD on return — every
    call site here rebinds ``self.pool`` from the function's result in
    the same statement, and nothing else may retain a pool reference
    across a dispatch.  ``donate_pool=False`` keeps the copying
    behaviour as a differential baseline (token streams are identical;
    only buffer traffic changes).

    ``ragged_backend`` picks the lowering for the fused iteration's
    prefill attention (`kernels.ops.ragged_segment_attention`): the
    native segment-tiled kernel ("pallas"/"interpret"), the pure-jnp
    segment-bounded oracle ("ref"), or the legacy flatten-and-repeat
    lowering onto the decode kernel ("flat"/"flat_interpret"/"flat_ref",
    kept for differential tests).  Defaults to ``backend``.

    **Tensor parallelism** (``mesh``): given a ("data", "model") mesh
    slice, the runner shards megatron-style over the "model" axis —
    QKV/O and FFN weights per ``models.sharding.param_pspec``, the KV
    pool over KV heads (``POOL_PSPEC``; logical pool shape unchanged,
    the BlockManager stays head-agnostic) — and lowers every step
    function through ``shard_map``.  Each shard runs the SAME fused
    iteration body on its local KV-head slice (the attention kernels'
    kv_head grid dim is simply the local head count; block tables and
    ragged metadata are replicated), and the only collectives per layer
    are the two standard megatron all-reduces.  Donation survives
    sharding: jit aliases the pool shard-for-shard, so each device
    keeps ONE resident pool shard for the runner's lifetime
    (``pool_address()`` returns the per-shard address tuple).  A
    ``mesh`` whose "model" axis is 1 only *places* the arrays on that
    slice's device — the computation is the exact single-device
    baseline, which is what keeps tp=1 bit-identical for differential
    tests.
    """

    def __init__(self, model: LanguageModel, params, num_blocks: int,
                 block_size: int, max_batch: int = 8,
                 backend: Optional[str] = None,
                 ragged_backend: Optional[str] = None,
                 donate_pool: bool = True,
                 mesh: Optional[Mesh] = None):
        cfg = model.cfg
        assert model.uniform_kind == "attn", "paged runner serves attention archs"
        assert cfg.sliding_window is None, "windowed paged decode: see DESIGN.md"
        self.model, self.cfg = model, cfg
        self.block_size, self.num_blocks = block_size, num_blocks
        self.max_batch = max_batch
        self.backend = backend or kops.default_backend()
        self.ragged_backend = ragged_backend or self.backend
        self.donate_pool = donate_pool
        # ---- tensor-parallel mesh placement (tp=1 + mesh=None is the
        # exact single-device baseline: no shard_map, no collectives) ----
        self.mesh = mesh
        tp = (int(mesh.shape["model"])
              if mesh is not None and "model" in mesh.axis_names else 1)
        validate_serving_tp(cfg, tp)
        self.tp = tp
        self._tp_axis = "model" if tp > 1 else None
        hd = cfg.resolved_head_dim
        # local (per-shard) config: the step bodies reshape activations
        # by head counts, and under shard_map each shard owns 1/tp of
        # the KV heads plus their whole query-head groups (heads are
        # laid out group-contiguous, so the megatron column slice of
        # wq/wk/wv is exactly a KV-head-aligned slice).  head_dim is
        # pinned so resolved_head_dim can't drift with num_heads.
        self._lcfg = (dataclasses.replace(
            cfg, num_heads=cfg.num_heads // tp,
            num_kv_heads=cfg.num_kv_heads // tp, head_dim=hd)
            if tp > 1 else cfg)
        self._pool_pspec = POOL_PSPEC if tp > 1 else P()
        if mesh is not None:
            specs = (serving_param_specs(params, cfg, mesh)
                     if tp > 1 else jax.tree_util.tree_map(lambda _: P(),
                                                           params))
            self._param_specs = specs
            params = jax.device_put(params, jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), specs))
        else:
            self._param_specs = None
        self.params = params
        self.pool = self._new_pool()
        # perf counters now live on a metrics registry (obs.metrics);
        # n_dispatches is a property alias over it — device *op
        # dispatches* issued (jitted calls plus standalone ops like the
        # legacy path's per-chunk jnp.argmax — each is a separately
        # launched device computation).  Plain device->host transfers of
        # already-computed arrays (np.asarray on a result) execute no op
        # and are not counted on either path.
        self.metrics = MetricsRegistry()
        self.n_dispatches = 0
        if self._tp_axis is None:
            decode = self._build_decode()
            fused = self._build_fused()
            suffix = self._build_suffix_prefill()
            copy = self._build_copy_block()
        else:
            # lower every step body through shard_map: params enter with
            # their megatron specs, the pool with its KV-head shard, the
            # ragged batch metadata (tokens / positions / block tables /
            # scalar-prefetched scatter slots) replicated.  Outputs:
            # next-token ids are replicated (each shard computes the
            # identical argmax from the psum'ed activations and the
            # replicated LM head), the pool keeps its shard spec so jit
            # donation aliases shard-for-shard.
            rep = P()
            ppar, pspec = self._param_specs, self._pool_pspec
            decode = self._smap(self._build_decode(),
                                (ppar, pspec) + (rep,) * 4, (rep, pspec))
            fused = self._smap(self._build_fused(),
                               (ppar, pspec) + (rep,) * 10, (rep, pspec))
            copy = self._smap(self._build_copy_block(),
                              (pspec, rep, rep), pspec)
            raw_suffix = self._build_suffix_prefill()
            smap = self._smap

            def suffix(params, pool, tokens, ctx_bt, write_idx, n_cached):
                # n_cached is a static python int (jit static_argnames),
                # consumed by slicing inside the body — bind it BEFORE
                # shard_map so it never becomes a traced spec'd operand;
                # each n_cached specialization re-wraps at trace time.
                fn = smap(functools.partial(raw_suffix, n_cached=n_cached),
                          (ppar, pspec, rep, rep, rep), (rep, pspec))
                return fn(params, pool, tokens, ctx_bt, write_idx)
        write_blocks = self._build_write_blocks()
        if self._tp_axis is not None:
            write_blocks = self._smap(
                write_blocks,
                (self._pool_pspec, self._pool_pspec, P()), self._pool_pspec)
        self._decode_fn = self._jit_pool(decode)
        self._prefill_fn = jax.jit(self.model.prefill)
        self._suffix_fn = self._jit_pool(suffix,
                                         static_argnames=("n_cached",))
        self._fused_fn = self._jit_pool(fused)
        self._scatter_fn = self._jit_pool(self._build_scatter_prefill(),
                                          pool_argnum=0)
        self._copy_block_fn = self._jit_pool(copy, pool_argnum=0)
        self._write_blocks_fn = self._jit_pool(write_blocks, pool_argnum=0)

    def _new_pool(self) -> jnp.ndarray:
        """Fresh zeroed KV pool, placed on this runner's mesh slice with
        the KV-head shard spec (or the default device when meshless)."""
        cfg = self.cfg
        pool = jnp.zeros(
            (cfg.num_layers, 2, self.num_blocks, self.block_size,
             cfg.num_kv_heads, cfg.resolved_head_dim), self.model.dtype)
        if self.mesh is not None:
            pool = jax.device_put(pool,
                                  NamedSharding(self.mesh, self._pool_pspec))
        return pool

    def _smap(self, fn, in_specs, out_specs):
        """shard_map a step body over this runner's mesh slice.
        check_rep=False: the Pallas/interpret attention backends defeat
        replication inference, and every replicated output here is
        replicated by construction (psum'ed activations x replicated
        head)."""
        return shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)

    @property
    def n_dispatches(self) -> int:
        """Alias over ``metrics.counter("n_dispatches")`` — kept
        read/write (``runner.n_dispatches += 1``) so pre-registry call
        sites and BENCH gates work unchanged."""
        return int(self.metrics.counter("n_dispatches").value)

    @n_dispatches.setter
    def n_dispatches(self, v: int):
        self.metrics.counter("n_dispatches").value = float(v)

    def metrics_snapshot(self) -> dict:
        """Registry snapshot with the derived gauges refreshed (compiled
        specializations, resident pool bytes)."""
        self.metrics.set("jit_cache_size", self.jit_cache_size())
        self.metrics.set("pool_bytes",
                         self.pool.size * self.pool.dtype.itemsize)
        return self.metrics.snapshot()

    def _jit_pool(self, fn, pool_argnum: int = 1, **kw):
        """jit a step function that threads the KV pool in and out; with
        ``donate_pool`` the pool argument's buffer is donated so the
        update happens in place (the returned pool aliases the input)."""
        if self.donate_pool:
            kw["donate_argnums"] = (pool_argnum,)
        return jax.jit(fn, **kw)

    def pool_address(self) -> Optional[int]:
        """Device buffer address of the pool, or None when the runtime
        doesn't expose one.  With donation active the address is stable
        across dispatches (the perf-guard test and the fusion benchmark's
        ``pool_bytes_copied_per_iter`` metric both watch it).  May block
        on an in-flight dispatch — call between synced iterations only.
        Only a *missing* API degrades to None: a RuntimeError (e.g. a
        deleted buffer — a stale reference surviving past its donation)
        must propagate, not masquerade as an unsupported probe.

        A sharded pool returns a TUPLE of per-shard addresses (one per
        addressable shard, shard-index order): donation under shard_map
        aliases shard-for-shard, so EVERY position must be stable across
        dispatches — the sharded perf tests and ``benchmarks/shard_scale``
        compare whole tuples."""
        try:
            shards = self.pool.addressable_shards
            if len(shards) > 1:
                return tuple(s.data.unsafe_buffer_pointer() for s in shards)
            return self.pool.unsafe_buffer_pointer()
        except (AttributeError, NotImplementedError):
            return None

    def jit_cache_size(self) -> int:
        """Total compiled specializations across the runner's jitted entry
        points — the recompile counter the fusion benchmark/CI tracks.
        ``_cache_size`` is a private jax API (0.4.x); degrade to 0 rather
        than break benchmarks/tests if a future release drops it."""
        return sum(getattr(f, "_cache_size", lambda: 0)() for f in
                   (self._decode_fn, self._prefill_fn, self._suffix_fn,
                    self._fused_fn, self._scatter_fn, self._copy_block_fn,
                    self._write_blocks_fn))

    # -- block-granular KV transfer (live request migration) ------------------
    def read_blocks(self, block_ids: Sequence[int]) -> np.ndarray:
        """Gather the KV of ``block_ids`` to host:
        (L, 2, n_blocks, block_size, n_kv, hd) numpy.  The gather is a
        fresh buffer — the pool itself is only *read*, never donated, so
        ``pool_address()`` is unchanged by this call (the migration tests
        witness exactly that).  Like every pool read it must run between
        synced iterations: an in-flight donated dispatch may be
        overwriting the pool concurrently."""
        self.n_dispatches += 1
        return np.asarray(self.pool[:, :, jnp.asarray(block_ids, jnp.int32)])

    def write_blocks(self, kv: np.ndarray, block_ids: Sequence[int]):
        """Scatter transferred KV into ``block_ids`` — the restore half of
        a live migration.  One jitted dispatch with the pool donated
        (``self.pool`` rebinds from the result in the same statement), so
        the target instance keeps its single resident pool buffer."""
        assert kv.shape[2] == len(block_ids)
        self.n_dispatches += 1
        self.pool = self._write_blocks_fn(
            self.pool, jnp.asarray(kv, self.pool.dtype),
            jnp.asarray(block_ids, jnp.int32))

    def _build_write_blocks(self):
        def write(pool, kv, bt):
            return pool.at[:, :, bt].set(kv)
        return write

    # -- prefill: run the model once, scatter its contiguous KV into pages ---
    def prefill(self, tokens: jnp.ndarray, block_table: List[int]):
        """tokens (S,) int32 -> last-token logits (V,). Fills the pool.

        Two dispatches: the model prefill and the (donated) pool scatter
        — the scatter used to be an out-of-jit ``at[].set`` that copied
        the entire pool to write one prompt's pages, and was not counted
        in ``n_dispatches`` at all.

        Tensor-parallel runners route through the shard_map'd suffix
        path with ``n_cached=0`` instead: the monolithic ``model.prefill``
        produces full-head contiguous KV, which has no per-shard scatter
        (tp=1 keeps the exact legacy two-dispatch lowering as the
        differential baseline)."""
        if self.tp > 1:
            return self.prefill_suffix(tokens, block_table, 0)
        nb = -(-tokens.shape[0] // self.block_size)
        self.n_dispatches += 2
        logits, cache = self._prefill_fn(self.params, tokens[None])
        bt = jnp.asarray(block_table[:nb], jnp.int32)
        self.pool = self._scatter_fn(self.pool, cache["kv"], bt)
        return logits[0]

    def _build_scatter_prefill(self):
        bs = self.block_size

        def scatter(pool, kv, bt):
            """kv (L,2,1,S,kv,hd) contiguous prefill KV -> the pages in
            ``bt``; pool donated, so the scatter is in place."""
            s = kv.shape[3]
            nb = bt.shape[0]
            kv = jnp.pad(kv, [(0, 0), (0, 0), (0, 0), (0, nb * bs - s),
                              (0, 0), (0, 0)])
            kv = kv.reshape(kv.shape[0], 2, nb, bs, *kv.shape[4:])
            return pool.at[:, :, bt].set(kv)

        return scatter

    # -- chunk prefill: attend over resident KV, compute only new tokens ------
    def prefill_suffix(self, tokens: jnp.ndarray, block_table: List[int],
                       n_cached: int):
        """tokens (S,) = the next prompt chunk; block_table covers the
        whole prompt.  The chunk attends over the ``n_cached`` tokens
        already resident in the pool (shared cached prefix and/or earlier
        chunks of this prompt) plus itself; only the chunk's KV is
        written.  ``n_cached`` may be any value >= 0 — chunk boundaries
        need not align to blocks (the last resident block may be
        partially filled and is completed in place)."""
        s = tokens.shape[0]
        bs = self.block_size
        assert s > 0 and 0 <= n_cached
        n_ctx_blocks = -(-n_cached // bs)
        ctx_bt = jnp.asarray(block_table[:n_ctx_blocks], jnp.int32)
        write_idx = jnp.asarray(
            [block_table[p // bs] * bs + p % bs
             for p in range(n_cached, n_cached + s)], jnp.int32)
        self.n_dispatches += 1
        logits, self.pool = self._suffix_fn(
            self.params, self.pool, jnp.asarray(tokens, jnp.int32),
            ctx_bt, write_idx, n_cached)
        return logits

    def copy_block(self, src: int, dst: int):
        """Copy-on-write data path: duplicate one physical block.  One
        jitted (donated) dispatch moving exactly one block — the old
        out-of-jit ``at[].set`` rebuilt the whole pool per copy, and
        baked the block ids into the op (src/dst are traced scalars
        here, so every copy shares one compiled specialization)."""
        self.n_dispatches += 1
        self.pool = self._copy_block_fn(self.pool, src, dst)

    def _build_copy_block(self):
        def copy(pool, src, dst):
            return pool.at[:, :, dst].set(pool[:, :, src])
        return copy

    def _build_suffix_prefill(self):
        cfg = self._lcfg
        axis = self._tp_axis
        hd = cfg.resolved_head_dim

        def step(params, pool, tokens, ctx_bt, write_idx, n_cached):
            s = tokens.shape[0]
            positions = n_cached + jnp.arange(s, dtype=jnp.int32)
            sin, cos = attn_mod.rope_at(positions, hd, cfg.rope_theta)
            k_pos = jnp.arange(n_cached + s, dtype=jnp.int32)
            bias = jnp.where(positions[:, None] >= k_pos[None, :],
                             0.0, attn_mod.NEG_INF)[None, None, None]
            x = embed_tokens(params, tokens[None]).astype(pool.dtype)  # (1,S,d)

            def body(xx, xs):
                lp, pool_layer = xs
                q, k, v = _layer_qkv(lp, xx, sin, cos, cfg)
                # resident K/V: gather the covering pages (already rope'd
                # at write), keep the first n_cached rows — the last page
                # may be partially filled by an earlier chunk
                pk = pool_layer[0][ctx_bt].reshape(
                    -1, cfg.num_kv_heads, hd)[:n_cached]
                pv = pool_layer[1][ctx_bt].reshape(
                    -1, cfg.num_kv_heads, hd)[:n_cached]
                kf = jnp.concatenate([pk[None], k], axis=1)   # (1, P+S, kv, hd)
                vf = jnp.concatenate([pv[None], v], axis=1)
                scores = attn_mod._gqa_scores(q, kf)
                probs = jax.nn.softmax(scores + bias, axis=-1)
                o = attn_mod._gqa_out(probs, vf).reshape(1, s, -1)
                return _layer_finish(xx, o, lp, cfg, axis), \
                    jnp.stack([k[0], v[0]])                   # (2, S, kv, hd)

            x, kvs = jax.lax.scan(body, x, (params["layers"], pool))
            # scatter the chunk's KV at its exact token slots — per-token
            # flat indices, so chunks may start or end mid-block
            flat = pool.reshape(*pool.shape[:2], -1, cfg.num_kv_heads, hd)
            pool = flat.at[:, :, write_idx].set(kvs).reshape(pool.shape)
            x = rms_norm(x, params["final_norm"], cfg.norm_eps)
            logits = lm_logits(params, x[:, -1], cfg)
            return logits[0], pool

        return step

    # -- fused ragged iteration: one dispatch per engine step -----------------
    def run_iteration(self, batch: IterationBatch) -> jnp.ndarray:
        """Execute a whole :class:`IterationBatch` — every prefill chunk,
        every decode token, and the plan's copy-on-write block copies — as
        ONE jitted device dispatch, returning next-token argmax ids (S,)
        for every segment row.  The result is a *device* array: jax async
        dispatch means this call returns before the compute finishes, so
        a cluster loop can issue the next engine's iteration while this
        one runs; the caller syncs (one transfer) only when it actually
        consumes the token values.  The per-chunk path pays K+1 dispatches
        and K blocking argmax syncs for the same work.

        The pool argument is donated: ``self.pool`` is rebound from the
        call's result in the same statement, so the dead input reference
        can never be observed, and the next-token output is a distinct
        (non-aliased) buffer — deferring its host sync via
        :class:`TokenBuffer` never touches donated storage."""
        self.n_dispatches += 1
        # numpy arrays go straight to the jitted call: the C++ dispatch
        # path converts them far cheaper than 12 python-level jnp.asarray
        # round-trips (measured ~1.7 ms/iteration at smoke scale)
        nxt, self.pool = self._fused_fn(
            self.params, self.pool, batch.tokens_p, batch.positions_p,
            batch.tables_p, batch.tokens_d, batch.positions_d,
            batch.tables_d, batch.write_slots, batch.sample_rows,
            batch.cow_src, batch.cow_dst)
        return nxt

    def _build_fused(self):
        cfg = self._lcfg
        axis = self._tp_axis
        hd = cfg.resolved_head_dim
        backend = self.backend
        ragged_backend = self.ragged_backend

        def step(params, pool, tokens_p, positions_p, tables_p,
                 tokens_d, positions_d, tables_d, write_slots, sample_rows,
                 cow_src, cow_dst):
            # copy-on-write first: decode rows write into the copies.
            # dst never aliases another pair's src (dsts come off the free
            # list, srcs are shared), so one vectorized copy is exact;
            # padding pairs point dst past the pool and drop
            pool = pool.at[:, :, cow_dst].set(pool[:, :, cow_src], mode="drop")
            sp, lmax = tokens_p.shape
            tp = sp * lmax
            tokens = jnp.concatenate([tokens_p.reshape(-1), tokens_d])
            positions = jnp.concatenate([positions_p.reshape(-1), positions_d])
            x = embed_tokens(params, tokens[None]).astype(pool.dtype)  # (1,T,d)
            sin, cos = attn_mod.rope_at(positions, hd, cfg.rope_theta)

            def body(xx, xs):
                lp, pool_layer = xs
                q, k, v = _layer_qkv(lp, xx, sin, cos, cfg)
                # scatter every fresh K/V into its pool slot BEFORE
                # attending: a token then reads earlier same-iteration
                # tokens (its own chunk's prefix, or another chunk that
                # shares its cached-prefix blocks) straight from the pool;
                # padding rows carry an out-of-range slot and drop
                kp = pool_layer[0].reshape(-1, cfg.num_kv_heads, hd).at[
                    write_slots].set(k[0], mode="drop").reshape(pool_layer[0].shape)
                vp = pool_layer[1].reshape(-1, cfg.num_kv_heads, hd).at[
                    write_slots].set(v[0], mode="drop").reshape(pool_layer[1].shape)
                g = cfg.num_heads // cfg.num_kv_heads
                qg = q[0].reshape(-1, cfg.num_kv_heads, g, hd)
                # chunk rows attend as dense (Sp, L) tiles through the
                # short per-chunk tables (segment-blocked causal: pages
                # gathered once per chunk, not once per token); decode
                # rows through their full tables via the classic paged
                # kernel — chunk tokens never gather the longest decode
                # context
                op = kops.ragged_segment_attention(
                    qg[:tp].reshape(sp, lmax, cfg.num_kv_heads, g, hd),
                    kp, vp, tables_p, positions_p, backend=ragged_backend)
                od = kops.paged_attention(
                    qg[tp:], kp, vp, tables_d, positions_d + 1,
                    backend=backend)
                o = jnp.concatenate(
                    [op.reshape(tp, cfg.num_kv_heads, g, hd), od])
                o = o.reshape(1, -1, cfg.num_heads * hd)
                return _layer_finish(xx, o, lp, cfg, axis), jnp.stack([kp, vp])

            x, new_pool = jax.lax.scan(body, x, (params["layers"], pool))
            x = rms_norm(x, params["final_norm"], cfg.norm_eps)
            rows = x[0][sample_rows]                       # (S, d)
            logits = lm_logits(params, rows, cfg)          # (S, V)
            return jnp.argmax(logits, -1).astype(jnp.int32), new_pool

        return step

    # -- batched paged decode --------------------------------------------------
    def _build_decode(self):
        cfg = self._lcfg
        axis = self._tp_axis
        hd = cfg.resolved_head_dim
        bs = self.block_size
        backend = self.backend

        def step(params, pool, tokens, positions, block_tables, live):
            """tokens (B,), positions (B,), block_tables (B, nbmax), live (B,) bool."""
            x = embed_tokens(params, tokens[:, None]).astype(pool.dtype)   # (B,1,d)
            ctx = jnp.where(live, positions + 1, 1).astype(jnp.int32)
            sin, cos = attn_mod.rope_at(positions[:, None], hd, cfg.rope_theta)

            def body(xx, xs):
                lp, pool_layer = xs
                q, k, v = _layer_qkv(lp, xx, sin, cos, cfg)
                # write k/v at (table[pos // bs], pos % bs); dead batch slots
                # point past the pool (mode="drop") so they can never stomp a
                # live page — block tables may now be shared across sequences
                flat = block_tables[jnp.arange(tokens.shape[0]), positions // bs] * bs \
                    + positions % bs
                flat = jnp.where(live, flat, pool_layer[0].shape[0] * bs)
                kp = pool_layer[0].reshape(-1, cfg.num_kv_heads, hd).at[flat].set(
                    k[:, 0], mode="drop").reshape(pool_layer[0].shape)
                vp = pool_layer[1].reshape(-1, cfg.num_kv_heads, hd).at[flat].set(
                    v[:, 0], mode="drop").reshape(pool_layer[1].shape)
                g = cfg.num_heads // cfg.num_kv_heads
                qg = q.reshape(q.shape[0], cfg.num_kv_heads, g, hd)
                o = kops.paged_attention(qg, kp, vp, block_tables, ctx, backend=backend)
                o = o.reshape(q.shape[0], 1, cfg.num_heads * hd)
                return _layer_finish(xx, o, lp, cfg, axis), jnp.stack([kp, vp])

            x, new_pool = jax.lax.scan(body, x, (params["layers"], pool))
            x = rms_norm(x, params["final_norm"], cfg.norm_eps)
            logits = lm_logits(params, x[:, 0], cfg)
            return logits, new_pool

        return step

    def decode_batch(self, tokens: np.ndarray, positions: np.ndarray,
                     block_tables: np.ndarray, live: np.ndarray):
        """All inputs padded to a fixed batch; returns logits (B, V)."""
        self.n_dispatches += 1
        logits, self.pool = self._decode_fn(
            self.params, self.pool,
            jnp.asarray(tokens, jnp.int32), jnp.asarray(positions, jnp.int32),
            jnp.asarray(block_tables, jnp.int32), jnp.asarray(live, bool))
        return logits

    def clone(self) -> "PagedModelRunner":
        """A new runner over the same model/params with a fresh zeroed KV
        pool, *sharing* this runner's compiled step functions (the jitted
        callables close over config/backend only; params and pool are
        traced arguments).  A multi-instance cluster built from clones
        pays for one compile per shape bucket, not one per instance.

        Safe under donation: donation is per *call*, not per compiled
        function — each clone owns its own pool buffer and donates only
        that buffer when it dispatches, so instances never alias (and a
        shared jitted fn called concurrently from cluster worker threads
        donates each caller's pool independently).  The fresh pool is
        built from static shape/dtype, never by reading the source
        runner's buffer — cloning is legal even while the source has a
        dispatch in flight.

        Sharded runners clone the same way WITHIN a mesh slice: the
        clone shares the placed (sharded) params and the compiled
        shard_map'd step fns — compiled executables close over the
        slice's device set, so same-slice instances pay one compile.  A
        runner for a DIFFERENT slice cannot be cloned (its executables
        are bound to other devices); build it with
        ``PagedModelRunner(..., mesh=other_slice)`` instead."""
        c = object.__new__(PagedModelRunner)
        c.model, c.cfg, c.params = self.model, self.cfg, self.params
        c.block_size, c.num_blocks = self.block_size, self.num_blocks
        c.max_batch, c.backend = self.max_batch, self.backend
        c.ragged_backend = self.ragged_backend
        c.donate_pool = self.donate_pool
        c.mesh, c.tp = self.mesh, self.tp
        c._tp_axis, c._lcfg = self._tp_axis, self._lcfg
        c._pool_pspec = self._pool_pspec
        c._param_specs = self._param_specs
        c.pool = c._new_pool()
        c.metrics = MetricsRegistry()
        c.n_dispatches = 0
        c._decode_fn = self._decode_fn
        c._prefill_fn = self._prefill_fn
        c._suffix_fn = self._suffix_fn
        c._fused_fn = self._fused_fn
        c._scatter_fn = self._scatter_fn
        c._copy_block_fn = self._copy_block_fn
        c._write_blocks_fn = self._write_blocks_fn
        return c

    @classmethod
    def from_config(cls, model: LanguageModel, params, config,
                    backend: Optional[str] = None,
                    mesh: Optional[Mesh] = None) -> "PagedModelRunner":
        """Build a runner from a :class:`~repro.serving.config.ServingConfig`
        (the mesh, being device placement rather than configuration, is
        supplied separately)."""
        return cls(model, params, backend=backend, mesh=mesh,
                   **config.runner_kwargs())


# =============================================================================
# Deferred host sync: lazy next-token references
# =============================================================================


class TokenBuffer:
    """The next-token ids of one fused dispatch, synced to host lazily.

    ``run_iteration`` returns a device array whose compute may still be
    in flight (jax async dispatch).  The buffer converts it to numpy
    exactly once, on first access — so the device->host round-trip (and
    the wait for the producing dispatch) happens only when a token value
    is actually consumed: fed into a later iteration's flatten, checked
    against ``eos_token``, or materialized at request finish.

    Donation audit: the held array is the dispatch's next-token *output*
    — a buffer XLA allocates fresh (outputs alias only donated inputs,
    and the pool's shape can't alias a token vector), so a deferred
    ``host()`` read is safe no matter how many further iterations have
    donated and overwritten the pool in the meantime."""

    __slots__ = ("_dev", "_host")

    def __init__(self, dev):
        self._dev = dev
        self._host: Optional[np.ndarray] = None

    def host(self) -> np.ndarray:
        if self._host is None:
            dev = self._dev               # local ref: a concurrent host()
            if dev is not None:           # call can never hand us None
                self._host = np.asarray(dev)
                self._dev = None          # release the device buffer early
        return self._host


class TokenRef:
    """One row of a :class:`TokenBuffer`: a not-yet-synced token id.

    Converts to ``int`` on demand (``__int__``/``__index__``), so host
    code that stores pending tokens — the engine's next-token map, a
    request's ``output_tokens`` while it is still running — never blocks
    on the device until the value is observed.  Comparison syncs too:
    equality against a plain int is value equality."""

    __slots__ = ("buf", "row")

    def __init__(self, buf: TokenBuffer, row: int):
        self.buf = buf
        self.row = row

    def __int__(self) -> int:
        return int(self.buf.host()[self.row])

    __index__ = __int__

    def __eq__(self, other) -> bool:
        try:
            return int(self) == int(other)
        except (TypeError, ValueError):
            return NotImplemented

    def __hash__(self) -> int:
        return hash(int(self))

    def __repr__(self) -> str:
        return f"TokenRef({int(self)})"


# =============================================================================
# Continuous-batching engine
# =============================================================================

# back-compat alias: engine stats now live on the shared batch scheduler
EngineStats = SchedStats


class LLMEngine:
    """One LLM instance: a :class:`BatchScheduler` drives the runner.

    All scheduling decisions — admission order (``policy``, default
    FCFS), prefix-cache matching, block accounting, growth / eviction /
    preemption, and chunked-prefill batch composition
    (``prefill_chunk_tokens``: per-iteration prefill token budget,
    ``None`` = monolithic) — live in
    :class:`repro.serving.batch_scheduler.BatchScheduler`, shared verbatim
    with the discrete-event simulator's ``SimInstance``; this class only
    executes the plans with real tokens.

    Execution model (``fused_iteration``, default on): each composed
    :class:`IterationPlan` is flattened into one ragged
    :class:`IterationBatch` and executed by a single device dispatch
    (:meth:`PagedModelRunner.run_iteration`) returning every segment's
    next token in one transfer.  A request finishing its prefill starts
    decoding the *next* iteration (its first token is this dispatch's
    argmax), so generated tokens are identical to the legacy per-chunk
    path — kept behind ``fused_iteration=False`` for differential
    testing — which issues one jitted call per prefill chunk plus a
    decode dispatch, with a blocking argmax sync after every chunk."""

    def __init__(self, runner: PagedModelRunner, instance_id: int = 0,
                 max_batch: int = 8, eos_token: int = -1,
                 clock: Callable[[], float] = time.monotonic,
                 enable_prefix_cache: bool = False,
                 policy: Optional[SchedulerPolicy] = None,
                 prefill_chunk_tokens: Optional[int] = None,
                 fused_iteration: bool = True,
                 tracer: Tracer = NULL_TRACER,
                 role: str = "general"):
        self.runner = runner
        self.fused_iteration = fused_iteration
        self._pending: Optional[Tuple[IterationBatch, TokenBuffer]] = None
        self._pending_finished: Optional[List[Request]] = None
        self.bm = BlockManager(runner.num_blocks, runner.block_size)
        self.prefix_cache = (PrefixCache(runner.block_size)
                             if enable_prefix_cache else None)
        self.instance_id = instance_id
        self.max_batch = max_batch
        self.eos_token = eos_token
        self.clock = clock
        self.tracer = tracer
        self._next_tok: dict[int, int] = {}
        # fault plane (serving/faults.py): wired by the cluster; when set,
        # every composed iteration consults the injector mid-dispatch
        self.faults = None
        # wall seconds of the last dispatch+sync, written by the stepping
        # thread — recovery's step-deadline check reads it post-collect
        self.last_step_wall = 0.0
        self.sched = BatchScheduler(
            self.bm, policy=policy, prefix_cache=self.prefix_cache,
            matcher=TokenPrefixMatcher(), max_running=max_batch,
            max_batch=runner.max_batch,
            prefill_chunk_tokens=prefill_chunk_tokens,
            on_preempt=lambda r: self._next_tok.pop(r.req_id, None),
            tracer=tracer, instance_id=instance_id, role=role)

    @classmethod
    def from_config(cls, runner: PagedModelRunner, config, *,
                    instance_id: int = 0, eos_token: int = -1,
                    clock: Callable[[], float] = time.monotonic,
                    policy: Optional[SchedulerPolicy] = None,
                    tracer: Tracer = NULL_TRACER,
                    role: Optional[str] = None) -> "LLMEngine":
        """Build an engine from a :class:`~repro.serving.config.ServingConfig`
        (identity, clock, policy object and tracer are runtime wiring, not
        configuration).  ``role`` overrides ``config.role_of(instance_id)``
        — the autoscaler uses it to mint instances for a specific pool."""
        if role is None:
            role = config.role_of(instance_id)
        return cls(runner, instance_id=instance_id, eos_token=eos_token,
                   clock=clock, policy=policy, tracer=tracer, role=role,
                   **config.engine_kwargs())

    @property
    def role(self) -> str:
        return self.sched.role

    @property
    def waiting(self) -> List[Request]:
        return self.sched.waiting

    @property
    def running(self) -> List[Request]:
        return self.sched.running

    # ------------------------------------------------- pending-token surface
    # (live migration moves a mid-decode request's sampled-but-not-yet-fed
    # token between engines; these keep serving/migration.py off _next_tok)
    def pending_token(self, req_id: int) -> Optional[int]:
        """The request's sampled-but-not-yet-fed next token, materialized
        to a plain int (syncs a deferred :class:`TokenRef`), or None for a
        request still mid-prefill."""
        tok = self._next_tok.get(req_id)
        return None if tok is None else int(tok)

    def set_pending_token(self, req_id: int, tok: int):
        self._next_tok[req_id] = int(tok)

    def drop_pending_token(self, req_id: int):
        self._next_tok.pop(req_id, None)

    @property
    def stats(self) -> SchedStats:
        return self.sched.stats

    # ---------------------------------------------------------------- monitor
    @property
    def kv_capacity_tokens(self) -> int:
        return self.bm.num_blocks * self.bm.block_size

    @property
    def kv_used_tokens(self) -> int:
        return sum(r.total_len for r in self.running)

    @property
    def kv_cached_tokens(self) -> int:
        """Tokens parked in zero-ref prefix-cache blocks (reclaimable)."""
        return self.bm.cached_blocks * self.bm.block_size

    def memory_free_fraction(self) -> float:
        return self.bm.free_blocks / self.bm.num_blocks

    def poll_oom(self) -> bool:
        oom, self.stats.recent_oom = self.stats.recent_oom, False
        return oom

    def metrics_snapshot(self) -> dict:
        """One flat dict of this instance's counters and gauges: the
        runner's registry (dispatches, recompiles, pool bytes, iteration
        histograms) plus scheduler occupancy and prefix-cache stats."""
        m = self.runner.metrics
        m.set("queue_depth", len(self.waiting))
        m.set("running", len(self.running))
        m.set("kv_used_tokens", self.kv_used_tokens)
        m.set("kv_cached_tokens", self.kv_cached_tokens)
        m.set("n_finished", self.stats.n_finished)
        m.set("n_preempted", self.stats.n_preempted)
        m.set("n_admitted", self.stats.n_admitted)
        m.set("prefill_tokens", self.stats.prefill_tokens)
        m.set("prefill_tokens_saved", self.stats.prefill_tokens_saved)
        if self.prefix_cache is not None:
            m.set("prefix_cache_hit_rate", self.prefix_cache.stats.hit_rate())
        return self.runner.metrics_snapshot()

    # ---------------------------------------------------------------- intake
    def submit(self, req: Request):
        req.instance_id = self.instance_id
        self.sched.submit(req)

    # ---------------------------------------------------------------- stepping
    def step(self) -> List[Request]:
        """One continuous-batching iteration; returns finished requests.

        The legacy serial entry point: dispatch + collect back-to-back,
        with the host sync forced — the engine blocks on the device
        result before returning, exactly the pre-pipelining behaviour.
        Cluster loops call :meth:`dispatch_iteration` / :meth:`collect`
        instead to overlap engines."""
        self.dispatch_iteration()
        return self.collect(force_sync=True)

    @property
    def has_pending(self) -> bool:
        """A dispatched-but-not-collected iteration is in flight."""
        return self._pending is not None or self._pending_finished is not None

    def dispatch_iteration(self) -> bool:
        """Compose this engine's next iteration and issue its device
        dispatch WITHOUT waiting for the result (jax async dispatch): the
        returned next-token ids stay on device until :meth:`collect` —
        or a later consumer — actually needs them.  Returns True iff an
        iteration was issued.  On the legacy per-chunk path there is no
        single dispatch to defer; the iteration executes synchronously
        here and ``collect`` just hands back its finishers."""
        assert not self.has_pending, "collect() the previous iteration first"
        plan = self.sched.plan(self.clock())
        if plan is None:
            return False
        if self.faults is not None:
            # mid-dispatch fault point: the plan has already mutated
            # scheduler state (chunk bookkeeping, decode growth), which is
            # exactly what a real worker death leaves behind.  Non-crash
            # effects land first so a storm of ooms still fences.
            eff = self.faults.on_dispatch(self.instance_id)
            if eff.oom:
                self.sched.stats.recent_oom = True
            if eff.delay_s > 0.0:
                time.sleep(eff.delay_s)
            if eff.crash is not None:
                raise InstanceCrashed(self.instance_id, eff.crash.step)
        if not self.fused_iteration:
            self._pending_finished = self._execute_per_chunk(plan)
            return True
        batch = flatten_plan(plan, self.bm, self._next_tok)
        self.runner.metrics.observe("iteration_tokens", batch.n_tokens)
        self.runner.metrics.observe("batch_occupancy", len(batch.segments))
        self._pending = (batch, TokenBuffer(self.runner.run_iteration(batch)))
        return True

    def sync(self):
        """Block until the in-flight iteration's next-token ids are
        host-resident (no-op when nothing is pending).  Cluster worker
        threads call this right after :meth:`dispatch_iteration` so the
        device wait lands on the worker — concurrently with the other
        engines' compute — and never on the control-plane thread."""
        if self._pending is not None:
            self._pending[1].host()

    def collect(self, force_sync: bool = False) -> List[Request]:
        """Book the dispatched iteration's results; returns finished
        requests.  All bookkeeping is host-side metadata: new tokens are
        recorded as :class:`TokenRef`s, so nothing blocks on the device
        unless a request finished (its output materializes), EOS checking
        demands token values, or ``force_sync`` asks for the legacy
        blocking behaviour."""
        if self._pending_finished is not None:
            finished, self._pending_finished = self._pending_finished, None
            return finished
        if self._pending is None:
            return []
        (batch, toks), self._pending = self._pending, None
        if force_sync or self.eos_token >= 0:
            toks.host()
        finished = []
        now = self.clock()
        traced = self.tracer.enabled
        for j, seg in enumerate(batch.segments):
            r = seg.req
            if seg.kind == "prefill":
                if seg.emits_token:
                    self._next_tok[r.req_id] = TokenRef(toks, j)
                    # the final chunk's argmax IS the first generated
                    # token — TTFT is timed at its collection
                    if r.first_token_time < 0:
                        r.first_token_time = now
                    if traced:
                        self.tracer.emit("first-token", req_id=r.req_id,
                                         instance_id=self.instance_id,
                                         agent=r.agent_name,
                                         msg_id=r.msg_id, ts=now)
                continue
            fed = self._next_tok[r.req_id]
            r.output_tokens.append(fed)
            r.output_len += 1
            self._next_tok[r.req_id] = TokenRef(toks, j)
            if traced:
                self.tracer.emit("decode", req_id=r.req_id,
                                 instance_id=self.instance_id,
                                 agent=r.agent_name, msg_id=r.msg_id, ts=now)
            done = (r.output_len >= r.max_new_tokens
                    or (self.eos_token >= 0
                        and int(toks.host()[j]) == self.eos_token))
            if done:
                r.output_tokens[:] = [int(t) for t in r.output_tokens]
                self.sched.finish(r, self.clock())
                self._next_tok.pop(r.req_id, None)
                finished.append(r)
        return finished

    def _execute_per_chunk(self, plan) -> List[Request]:
        """Legacy differential-testing path: one jitted dispatch per
        prefill chunk (plus a blocking argmax sync each) and a separate
        decode dispatch."""
        # prefill chunks, in plan order: a chunk may attend shared blocks
        # written by an earlier chunk of this very iteration
        for c in plan.chunks:
            toks = jnp.asarray(
                np.asarray(c.req.prompt_tokens)[c.start:c.end], jnp.int32)
            table = self.bm.block_table(c.req.req_id)
            if c.start == 0 and c.is_last:
                logits = self.runner.prefill(toks, table)
            else:
                logits = self.runner.prefill_suffix(toks, table, c.start)
            if c.is_last:
                # jnp.argmax is its own device op dispatch, and int()
                # blocks on it — one round-trip per completed chunk (the
                # fused path folds every argmax into the main dispatch
                # and returns them in one transfer instead)
                self.runner.n_dispatches += 1
                self._next_tok[c.req.req_id] = int(jnp.argmax(logits))
                if c.req.first_token_time < 0:
                    c.req.first_token_time = self.clock()
                if self.tracer.enabled:
                    self.tracer.emit("first-token", req_id=c.req.req_id,
                                     instance_id=self.instance_id,
                                     agent=c.req.agent_name,
                                     msg_id=c.req.msg_id,
                                     ts=c.req.first_token_time)
        for src, dst in plan.cow:
            self.runner.copy_block(src, dst)
        if not plan.decode:
            return []
        b = self.runner.max_batch
        batch = plan.decode
        nbmax = max(len(self.bm.block_table(r.req_id)) + 1 for r in batch)
        tokens = np.zeros((b,), np.int32)
        positions = np.zeros((b,), np.int32)
        tables = np.zeros((b, nbmax), np.int32)
        live = np.zeros((b,), bool)
        for i, r in enumerate(batch):
            t = self.bm.block_table(r.req_id)
            tables[i, :len(t)] = t
            tokens[i] = self._next_tok[r.req_id]
            positions[i] = r.total_len
            live[i] = True
        logits = self.runner.decode_batch(tokens, positions, tables, live)
        self.runner.n_dispatches += 1
        nxt = np.asarray(jnp.argmax(logits, -1))
        finished = []
        for i, r in enumerate(batch):
            r.output_tokens.append(int(tokens[i]))
            r.output_len += 1
            self._next_tok[r.req_id] = int(nxt[i])
            done = (r.output_len >= r.max_new_tokens
                    or (self.eos_token >= 0 and int(nxt[i]) == self.eos_token))
            if done:
                self.sched.finish(r, self.clock())
                self._next_tok.pop(r.req_id, None)
                finished.append(r)
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.running and not self.waiting:
                break
        return out
