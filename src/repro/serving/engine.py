"""LLM engine instance: paged-KV model runner + continuous batching.

``PagedModelRunner`` executes real tokens with the paged KV pool (the
Pallas kernel's layout; ref backend on CPU, pallas on TPU).
``LLMEngine`` implements vLLM-style continuous batching with dynamic
memory allocation and preemption-by-recompute — the behaviours the paper's
dispatcher is designed around (§2.2.3).

Engines expose the *status monitor* surface Kairos polls (§3 overview):
KV memory in use / capacity, running/waiting counts, preemption counter.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import SchedulerPolicy
from repro.kernels import ops as kops
from repro.models import attention as attn_mod
from repro.models.layers import embed_tokens, lm_logits, rms_norm, swiglu
from repro.models.model import LanguageModel
from repro.models.moe import moe_ffn
from repro.serving.batch_scheduler import (
    BatchScheduler,
    SchedStats,
    TokenPrefixMatcher,
)
from repro.serving.kv_cache import BlockManager
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request


# =============================================================================
# Paged model runner (uniform-attention architectures)
# =============================================================================


class PagedModelRunner:
    """Runs a :class:`LanguageModel` against a paged KV pool.

    Pool: (L, 2, num_blocks, block_size, n_kv, hd).  Decode is batched
    across sequences at arbitrary positions via block tables.
    """

    def __init__(self, model: LanguageModel, params, num_blocks: int,
                 block_size: int, max_batch: int = 8, backend: Optional[str] = None):
        cfg = model.cfg
        assert model.uniform_kind == "attn", "paged runner serves attention archs"
        assert cfg.sliding_window is None, "windowed paged decode: see DESIGN.md"
        self.model, self.cfg, self.params = model, cfg, params
        self.block_size, self.num_blocks = block_size, num_blocks
        self.max_batch = max_batch
        self.backend = backend or kops.default_backend()
        hd = cfg.resolved_head_dim
        self.pool = jnp.zeros(
            (cfg.num_layers, 2, num_blocks, block_size, cfg.num_kv_heads, hd),
            model.dtype)
        self._decode_fn = self._build_decode()
        self._prefill_fn = jax.jit(self.model.prefill)
        self._suffix_fn = self._build_suffix_prefill()

    # -- prefill: run the model once, scatter its contiguous KV into pages ---
    def prefill(self, tokens: jnp.ndarray, block_table: List[int]):
        """tokens (S,) int32 -> last-token logits (V,). Fills the pool."""
        s = tokens.shape[0]
        logits, cache = self._prefill_fn(self.params, tokens[None])
        kv = cache["kv"]                                   # (L,2,1,S,kv,hd)
        bs = self.block_size
        nb = -(-s // bs)
        pad = nb * bs - s
        kv = jnp.pad(kv, [(0, 0), (0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
        kv = kv.reshape(kv.shape[0], 2, nb, bs, *kv.shape[4:])
        bt = jnp.asarray(block_table[:nb], jnp.int32)
        self.pool = self.pool.at[:, :, bt].set(kv)
        return logits[0]

    # -- chunk prefill: attend over resident KV, compute only new tokens ------
    def prefill_suffix(self, tokens: jnp.ndarray, block_table: List[int],
                       n_cached: int):
        """tokens (S,) = the next prompt chunk; block_table covers the
        whole prompt.  The chunk attends over the ``n_cached`` tokens
        already resident in the pool (shared cached prefix and/or earlier
        chunks of this prompt) plus itself; only the chunk's KV is
        written.  ``n_cached`` may be any value >= 0 — chunk boundaries
        need not align to blocks (the last resident block may be
        partially filled and is completed in place)."""
        s = tokens.shape[0]
        bs = self.block_size
        assert s > 0 and 0 <= n_cached
        n_ctx_blocks = -(-n_cached // bs)
        ctx_bt = jnp.asarray(block_table[:n_ctx_blocks], jnp.int32)
        write_idx = jnp.asarray(
            [block_table[p // bs] * bs + p % bs
             for p in range(n_cached, n_cached + s)], jnp.int32)
        logits, self.pool = self._suffix_fn(
            self.params, self.pool, jnp.asarray(tokens, jnp.int32),
            ctx_bt, write_idx, n_cached)
        return logits

    def copy_block(self, src: int, dst: int):
        """Copy-on-write data path: duplicate one physical block."""
        self.pool = self.pool.at[:, :, dst].set(self.pool[:, :, src])

    def _build_suffix_prefill(self):
        cfg = self.cfg
        hd = cfg.resolved_head_dim

        def step(params, pool, tokens, ctx_bt, write_idx, n_cached):
            s = tokens.shape[0]
            positions = n_cached + jnp.arange(s, dtype=jnp.int32)
            sin, cos = attn_mod.rope_at(positions, hd, cfg.rope_theta)
            k_pos = jnp.arange(n_cached + s, dtype=jnp.int32)
            bias = jnp.where(positions[:, None] >= k_pos[None, :],
                             0.0, attn_mod.NEG_INF)[None, None, None]
            x = embed_tokens(params, tokens[None]).astype(pool.dtype)  # (1,S,d)

            def body(xx, xs):
                lp, pool_layer = xs
                h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
                q, k, v = attn_mod._project_qkv(lp["attn"], h, h, cfg)
                q = attn_mod.apply_rope(q, sin, cos)
                k = attn_mod.apply_rope(k, sin, cos)
                # resident K/V: gather the covering pages (already rope'd
                # at write), keep the first n_cached rows — the last page
                # may be partially filled by an earlier chunk
                pk = pool_layer[0][ctx_bt].reshape(
                    -1, cfg.num_kv_heads, hd)[:n_cached]
                pv = pool_layer[1][ctx_bt].reshape(
                    -1, cfg.num_kv_heads, hd)[:n_cached]
                kf = jnp.concatenate([pk[None], k], axis=1)   # (1, P+S, kv, hd)
                vf = jnp.concatenate([pv[None], v], axis=1)
                scores = attn_mod._gqa_scores(q, kf)
                probs = jax.nn.softmax(scores + bias, axis=-1)
                o = attn_mod._gqa_out(probs, vf).reshape(1, s, -1)
                xx = xx + jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"])
                h2 = rms_norm(xx, lp["ln2"], cfg.norm_eps)
                if "moe" in lp:
                    f, _ = moe_ffn(lp["moe"], h2, cfg)
                else:
                    f = swiglu(h2, **lp["ffn"])
                return xx + f, jnp.stack([k[0], v[0]])        # (2, S, kv, hd)

            x, kvs = jax.lax.scan(body, x, (params["layers"], pool))
            # scatter the chunk's KV at its exact token slots — per-token
            # flat indices, so chunks may start or end mid-block
            flat = pool.reshape(*pool.shape[:2], -1, cfg.num_kv_heads, hd)
            pool = flat.at[:, :, write_idx].set(kvs).reshape(pool.shape)
            x = rms_norm(x, params["final_norm"], cfg.norm_eps)
            logits = lm_logits(params, x[:, -1], cfg)
            return logits[0], pool

        return jax.jit(step, static_argnames=("n_cached",))

    # -- batched paged decode --------------------------------------------------
    def _build_decode(self):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        bs = self.block_size
        backend = self.backend

        def step(params, pool, tokens, positions, block_tables, live):
            """tokens (B,), positions (B,), block_tables (B, nbmax), live (B,) bool."""
            x = embed_tokens(params, tokens[:, None]).astype(pool.dtype)   # (B,1,d)
            ctx = jnp.where(live, positions + 1, 1).astype(jnp.int32)

            def body(carry, xs):
                xx, pool_l_unused = carry, None
                lp, pool_layer = xs
                h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
                q, k, v = attn_mod._project_qkv(lp["attn"], h, h, cfg)
                sin, cos = attn_mod.rope_at(positions[:, None], hd, cfg.rope_theta)
                q = attn_mod.apply_rope(q, sin, cos)
                k = attn_mod.apply_rope(k, sin, cos)
                # write k/v at (table[pos // bs], pos % bs); dead batch slots
                # point past the pool (mode="drop") so they can never stomp a
                # live page — block tables may now be shared across sequences
                flat = block_tables[jnp.arange(tokens.shape[0]), positions // bs] * bs \
                    + positions % bs
                flat = jnp.where(live, flat, pool_layer[0].shape[0] * bs)
                kp = pool_layer[0].reshape(-1, cfg.num_kv_heads, hd).at[flat].set(
                    k[:, 0], mode="drop").reshape(pool_layer[0].shape)
                vp = pool_layer[1].reshape(-1, cfg.num_kv_heads, hd).at[flat].set(
                    v[:, 0], mode="drop").reshape(pool_layer[1].shape)
                g = cfg.num_heads // cfg.num_kv_heads
                qg = q.reshape(q.shape[0], cfg.num_kv_heads, g, hd)
                o = kops.paged_attention(qg, kp, vp, block_tables, ctx, backend=backend)
                o = o.reshape(q.shape[0], 1, cfg.num_heads * hd)
                xx = xx + jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"])
                h2 = rms_norm(xx, lp["ln2"], cfg.norm_eps)
                if "moe" in lp:
                    f, _ = moe_ffn(lp["moe"], h2, cfg)
                else:
                    f = swiglu(h2, **lp["ffn"])
                return xx + f, jnp.stack([kp, vp])

            x, new_pool = jax.lax.scan(body, x, (params["layers"], pool))
            x = rms_norm(x, params["final_norm"], cfg.norm_eps)
            logits = lm_logits(params, x[:, 0], cfg)
            return logits, new_pool

        return jax.jit(step)

    def decode_batch(self, tokens: np.ndarray, positions: np.ndarray,
                     block_tables: np.ndarray, live: np.ndarray):
        """All inputs padded to a fixed batch; returns logits (B, V)."""
        logits, self.pool = self._decode_fn(
            self.params, self.pool,
            jnp.asarray(tokens, jnp.int32), jnp.asarray(positions, jnp.int32),
            jnp.asarray(block_tables, jnp.int32), jnp.asarray(live, bool))
        return logits


# =============================================================================
# Continuous-batching engine
# =============================================================================

# back-compat alias: engine stats now live on the shared batch scheduler
EngineStats = SchedStats


class LLMEngine:
    """One LLM instance: a :class:`BatchScheduler` drives the runner.

    All scheduling decisions — admission order (``policy``, default
    FCFS), prefix-cache matching, block accounting, growth / eviction /
    preemption, and chunked-prefill batch composition
    (``prefill_chunk_tokens``: per-iteration prefill token budget,
    ``None`` = monolithic) — live in
    :class:`repro.serving.batch_scheduler.BatchScheduler`, shared verbatim
    with the discrete-event simulator's ``SimInstance``; this class only
    executes the plans with real tokens."""

    def __init__(self, runner: PagedModelRunner, instance_id: int = 0,
                 max_batch: int = 8, eos_token: int = -1,
                 clock: Callable[[], float] = time.monotonic,
                 enable_prefix_cache: bool = False,
                 policy: Optional[SchedulerPolicy] = None,
                 prefill_chunk_tokens: Optional[int] = None):
        self.runner = runner
        self.bm = BlockManager(runner.num_blocks, runner.block_size)
        self.prefix_cache = (PrefixCache(runner.block_size)
                             if enable_prefix_cache else None)
        self.instance_id = instance_id
        self.max_batch = max_batch
        self.eos_token = eos_token
        self.clock = clock
        self._next_tok: dict[int, int] = {}
        self.sched = BatchScheduler(
            self.bm, policy=policy, prefix_cache=self.prefix_cache,
            matcher=TokenPrefixMatcher(), max_running=max_batch,
            max_batch=runner.max_batch,
            prefill_chunk_tokens=prefill_chunk_tokens,
            on_preempt=lambda r: self._next_tok.pop(r.req_id, None))

    @property
    def waiting(self) -> List[Request]:
        return self.sched.waiting

    @property
    def running(self) -> List[Request]:
        return self.sched.running

    @property
    def stats(self) -> SchedStats:
        return self.sched.stats

    # ---------------------------------------------------------------- monitor
    @property
    def kv_capacity_tokens(self) -> int:
        return self.bm.num_blocks * self.bm.block_size

    @property
    def kv_used_tokens(self) -> int:
        return sum(r.total_len for r in self.running)

    @property
    def kv_cached_tokens(self) -> int:
        """Tokens parked in zero-ref prefix-cache blocks (reclaimable)."""
        return self.bm.cached_blocks * self.bm.block_size

    def memory_free_fraction(self) -> float:
        return self.bm.free_blocks / self.bm.num_blocks

    def poll_oom(self) -> bool:
        oom, self.stats.recent_oom = self.stats.recent_oom, False
        return oom

    # ---------------------------------------------------------------- intake
    def submit(self, req: Request):
        req.instance_id = self.instance_id
        self.sched.submit(req)

    # ---------------------------------------------------------------- stepping
    def step(self) -> List[Request]:
        """One continuous-batching iteration; returns finished requests."""
        plan = self.sched.plan(self.clock())
        if plan is None:
            return []
        # prefill chunks, in plan order: a chunk may attend shared blocks
        # written by an earlier chunk of this very iteration
        for c in plan.chunks:
            toks = jnp.asarray(
                np.asarray(c.req.prompt_tokens)[c.start:c.end], jnp.int32)
            table = self.bm.block_table(c.req.req_id)
            if c.start == 0 and c.is_last:
                logits = self.runner.prefill(toks, table)
            else:
                logits = self.runner.prefill_suffix(toks, table, c.start)
            if c.is_last:
                self._next_tok[c.req.req_id] = int(jnp.argmax(logits))
        for src, dst in plan.cow:
            self.runner.copy_block(src, dst)
        if not plan.decode:
            return []
        b = self.runner.max_batch
        batch = plan.decode
        nbmax = max(len(self.bm.block_table(r.req_id)) + 1 for r in batch)
        tokens = np.zeros((b,), np.int32)
        positions = np.zeros((b,), np.int32)
        tables = np.zeros((b, nbmax), np.int32)
        live = np.zeros((b,), bool)
        for i, r in enumerate(batch):
            t = self.bm.block_table(r.req_id)
            tables[i, :len(t)] = t
            tokens[i] = self._next_tok[r.req_id]
            positions[i] = r.total_len
            live[i] = True
        logits = self.runner.decode_batch(tokens, positions, tables, live)
        nxt = np.asarray(jnp.argmax(logits, -1))
        finished = []
        for i, r in enumerate(batch):
            r.output_tokens.append(int(tokens[i]))
            r.output_len += 1
            self._next_tok[r.req_id] = int(nxt[i])
            done = (r.output_len >= r.max_new_tokens
                    or (self.eos_token >= 0 and int(nxt[i]) == self.eos_token))
            if done:
                self.sched.finish(r, self.clock())
                self._next_tok.pop(r.req_id, None)
                finished.append(r)
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.running and not self.waiting:
                break
        return out
