"""LLM engine instance: paged-KV model runner + continuous batching.

``PagedModelRunner`` executes real tokens with the paged KV pool (the
Pallas kernel's layout; ref backend on CPU, pallas on TPU).
``LLMEngine`` implements vLLM-style continuous batching with dynamic
memory allocation and preemption-by-recompute — the behaviours the paper's
dispatcher is designed around (§2.2.3).

Engines expose the *status monitor* surface Kairos polls (§3 overview):
KV memory in use / capacity, running/waiting counts, preemption counter.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models import attention as attn_mod
from repro.models.layers import embed_tokens, lm_logits, rms_norm, swiglu
from repro.models.model import LanguageModel
from repro.models.moe import moe_ffn
from repro.serving.kv_cache import BlockManager, NoFreeBlocks
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request, RequestState


# =============================================================================
# Paged model runner (uniform-attention architectures)
# =============================================================================


class PagedModelRunner:
    """Runs a :class:`LanguageModel` against a paged KV pool.

    Pool: (L, 2, num_blocks, block_size, n_kv, hd).  Decode is batched
    across sequences at arbitrary positions via block tables.
    """

    def __init__(self, model: LanguageModel, params, num_blocks: int,
                 block_size: int, max_batch: int = 8, backend: Optional[str] = None):
        cfg = model.cfg
        assert model.uniform_kind == "attn", "paged runner serves attention archs"
        assert cfg.sliding_window is None, "windowed paged decode: see DESIGN.md"
        self.model, self.cfg, self.params = model, cfg, params
        self.block_size, self.num_blocks = block_size, num_blocks
        self.max_batch = max_batch
        self.backend = backend or kops.default_backend()
        hd = cfg.resolved_head_dim
        self.pool = jnp.zeros(
            (cfg.num_layers, 2, num_blocks, block_size, cfg.num_kv_heads, hd),
            model.dtype)
        self._decode_fn = self._build_decode()
        self._prefill_fn = jax.jit(self.model.prefill)
        self._suffix_fn = self._build_suffix_prefill()

    # -- prefill: run the model once, scatter its contiguous KV into pages ---
    def prefill(self, tokens: jnp.ndarray, block_table: List[int]):
        """tokens (S,) int32 -> last-token logits (V,). Fills the pool."""
        s = tokens.shape[0]
        logits, cache = self._prefill_fn(self.params, tokens[None])
        kv = cache["kv"]                                   # (L,2,1,S,kv,hd)
        bs = self.block_size
        nb = -(-s // bs)
        pad = nb * bs - s
        kv = jnp.pad(kv, [(0, 0), (0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
        kv = kv.reshape(kv.shape[0], 2, nb, bs, *kv.shape[4:])
        bt = jnp.asarray(block_table[:nb], jnp.int32)
        self.pool = self.pool.at[:, :, bt].set(kv)
        return logits[0]

    # -- suffix prefill: reuse cached prefix KV, compute only new tokens ------
    def prefill_suffix(self, tokens: jnp.ndarray, block_table: List[int],
                       n_cached: int):
        """tokens (S,) = the uncached suffix; block_table covers the whole
        prompt (cached prefix blocks first).  The suffix attends to the
        prefix KV already resident in the pool; only suffix KV is written.
        ``n_cached`` must be a positive multiple of block_size (the prefix
        cache only shares full blocks)."""
        s = tokens.shape[0]
        bs = self.block_size
        assert n_cached > 0 and n_cached % bs == 0 and s > 0
        nbp = n_cached // bs
        nb_total = -(-(n_cached + s) // bs)
        prefix_bt = jnp.asarray(block_table[:nbp], jnp.int32)
        suffix_bt = jnp.asarray(block_table[nbp:nb_total], jnp.int32)
        logits, self.pool = self._suffix_fn(
            self.params, self.pool, jnp.asarray(tokens, jnp.int32),
            prefix_bt, suffix_bt)
        return logits

    def copy_block(self, src: int, dst: int):
        """Copy-on-write data path: duplicate one physical block."""
        self.pool = self.pool.at[:, :, dst].set(self.pool[:, :, src])

    def _build_suffix_prefill(self):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        bs = self.block_size

        def step(params, pool, tokens, prefix_bt, suffix_bt):
            s = tokens.shape[0]
            p_len = prefix_bt.shape[0] * bs
            nbs = suffix_bt.shape[0]
            positions = p_len + jnp.arange(s, dtype=jnp.int32)
            sin, cos = attn_mod.rope_at(positions, hd, cfg.rope_theta)
            k_pos = jnp.arange(p_len + s, dtype=jnp.int32)
            bias = jnp.where(positions[:, None] >= k_pos[None, :],
                             0.0, attn_mod.NEG_INF)[None, None, None]
            x = embed_tokens(params, tokens[None]).astype(pool.dtype)  # (1,S,d)

            def body(xx, xs):
                lp, pool_layer = xs
                h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
                q, k, v = attn_mod._project_qkv(lp["attn"], h, h, cfg)
                q = attn_mod.apply_rope(q, sin, cos)
                k = attn_mod.apply_rope(k, sin, cos)
                # prefix K/V: gather cached pages (already rope'd at write)
                pk = pool_layer[0][prefix_bt].reshape(p_len, cfg.num_kv_heads, hd)
                pv = pool_layer[1][prefix_bt].reshape(p_len, cfg.num_kv_heads, hd)
                kf = jnp.concatenate([pk[None], k], axis=1)   # (1, P+S, kv, hd)
                vf = jnp.concatenate([pv[None], v], axis=1)
                scores = attn_mod._gqa_scores(q, kf)
                probs = jax.nn.softmax(scores + bias, axis=-1)
                o = attn_mod._gqa_out(probs, vf).reshape(1, s, -1)
                xx = xx + jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"])
                h2 = rms_norm(xx, lp["ln2"], cfg.norm_eps)
                if "moe" in lp:
                    f, _ = moe_ffn(lp["moe"], h2, cfg)
                else:
                    f = swiglu(h2, **lp["ffn"])
                return xx + f, jnp.stack([k[0], v[0]])        # (2, S, kv, hd)

            x, kvs = jax.lax.scan(body, x, (params["layers"], pool))
            # scatter only the new suffix KV into its (private) pages
            pad = nbs * bs - s
            kvs = jnp.pad(kvs, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
            kvs = kvs.reshape(kvs.shape[0], 2, nbs, bs, cfg.num_kv_heads, hd)
            pool = pool.at[:, :, suffix_bt].set(kvs)
            x = rms_norm(x, params["final_norm"], cfg.norm_eps)
            logits = lm_logits(params, x[:, -1], cfg)
            return logits[0], pool

        return jax.jit(step)

    # -- batched paged decode --------------------------------------------------
    def _build_decode(self):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        bs = self.block_size
        backend = self.backend

        def step(params, pool, tokens, positions, block_tables, live):
            """tokens (B,), positions (B,), block_tables (B, nbmax), live (B,) bool."""
            x = embed_tokens(params, tokens[:, None]).astype(pool.dtype)   # (B,1,d)
            ctx = jnp.where(live, positions + 1, 1).astype(jnp.int32)

            def body(carry, xs):
                xx, pool_l_unused = carry, None
                lp, pool_layer = xs
                h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
                q, k, v = attn_mod._project_qkv(lp["attn"], h, h, cfg)
                sin, cos = attn_mod.rope_at(positions[:, None], hd, cfg.rope_theta)
                q = attn_mod.apply_rope(q, sin, cos)
                k = attn_mod.apply_rope(k, sin, cos)
                # write k/v at (table[pos // bs], pos % bs); dead batch slots
                # point past the pool (mode="drop") so they can never stomp a
                # live page — block tables may now be shared across sequences
                flat = block_tables[jnp.arange(tokens.shape[0]), positions // bs] * bs \
                    + positions % bs
                flat = jnp.where(live, flat, pool_layer[0].shape[0] * bs)
                kp = pool_layer[0].reshape(-1, cfg.num_kv_heads, hd).at[flat].set(
                    k[:, 0], mode="drop").reshape(pool_layer[0].shape)
                vp = pool_layer[1].reshape(-1, cfg.num_kv_heads, hd).at[flat].set(
                    v[:, 0], mode="drop").reshape(pool_layer[1].shape)
                g = cfg.num_heads // cfg.num_kv_heads
                qg = q.reshape(q.shape[0], cfg.num_kv_heads, g, hd)
                o = kops.paged_attention(qg, kp, vp, block_tables, ctx, backend=backend)
                o = o.reshape(q.shape[0], 1, cfg.num_heads * hd)
                xx = xx + jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"])
                h2 = rms_norm(xx, lp["ln2"], cfg.norm_eps)
                if "moe" in lp:
                    f, _ = moe_ffn(lp["moe"], h2, cfg)
                else:
                    f = swiglu(h2, **lp["ffn"])
                return xx + f, jnp.stack([kp, vp])

            x, new_pool = jax.lax.scan(body, x, (params["layers"], pool))
            x = rms_norm(x, params["final_norm"], cfg.norm_eps)
            logits = lm_logits(params, x[:, 0], cfg)
            return logits, new_pool

        return jax.jit(step)

    def decode_batch(self, tokens: np.ndarray, positions: np.ndarray,
                     block_tables: np.ndarray, live: np.ndarray):
        """All inputs padded to a fixed batch; returns logits (B, V)."""
        logits, self.pool = self._decode_fn(
            self.params, self.pool,
            jnp.asarray(tokens, jnp.int32), jnp.asarray(positions, jnp.int32),
            jnp.asarray(block_tables, jnp.int32), jnp.asarray(live, bool))
        return logits


# =============================================================================
# Continuous-batching engine
# =============================================================================


@dataclasses.dataclass
class EngineStats:
    n_finished: int = 0
    n_preempted: int = 0
    n_admitted: int = 0
    recent_oom: bool = False      # set on preemption; cleared by monitor reads
    prefill_tokens: int = 0       # prompt tokens actually prefilled
    prefill_tokens_saved: int = 0  # prompt tokens served from the prefix cache


class LLMEngine:
    """One LLM instance: waiting queue -> continuous batch -> completions."""

    def __init__(self, runner: PagedModelRunner, instance_id: int = 0,
                 max_batch: int = 8, eos_token: int = -1,
                 clock: Callable[[], float] = time.monotonic,
                 enable_prefix_cache: bool = False):
        self.runner = runner
        self.bm = BlockManager(runner.num_blocks, runner.block_size)
        self.prefix_cache = (PrefixCache(runner.block_size)
                             if enable_prefix_cache else None)
        self.instance_id = instance_id
        self.max_batch = max_batch
        self.eos_token = eos_token
        self.clock = clock
        self.waiting: collections.deque[Request] = collections.deque()
        self.running: List[Request] = []
        self.stats = EngineStats()
        self._next_tok: dict[int, int] = {}

    # ---------------------------------------------------------------- monitor
    @property
    def kv_capacity_tokens(self) -> int:
        return self.bm.num_blocks * self.bm.block_size

    @property
    def kv_used_tokens(self) -> int:
        return sum(r.total_len for r in self.running)

    @property
    def kv_cached_tokens(self) -> int:
        """Tokens parked in zero-ref prefix-cache blocks (reclaimable)."""
        return self.bm.cached_blocks * self.bm.block_size

    def memory_free_fraction(self) -> float:
        return self.bm.free_blocks / self.bm.num_blocks

    def poll_oom(self) -> bool:
        oom, self.stats.recent_oom = self.stats.recent_oom, False
        return oom

    # ---------------------------------------------------------------- intake
    def submit(self, req: Request):
        req.state = RequestState.WAITING
        req.instance_id = self.instance_id
        self.waiting.append(req)

    # ---------------------------------------------------------------- stepping
    def _admit(self):
        while self.waiting and len(self.running) < self.max_batch:
            req = self.waiting[0]
            cache = self.prefix_cache
            hashes: List[int] = []
            cached: List[int] = []
            if cache is not None:
                if req.prefix_hashes is None:
                    req.prefix_hashes = PrefixCache.hash_tokens(
                        req.prompt_tokens, self.bm.block_size)
                hashes = req.prefix_hashes
                cached = cache.match(
                    hashes[:cache.usable_prefix_blocks(req.prompt_len)], self.bm)
            need = self.bm.blocks_needed(req.prompt_len + 1) - len(cached)
            if need > self.bm.free_blocks and cache is not None:
                cache.evict(self.bm, need - self.bm.free_blocks)
            if need > self.bm.free_blocks:
                for b in cached:          # abort: hand the refs back
                    self.bm.ref_release(b)
                break
            self.waiting.popleft()
            n_cached = len(cached) * self.bm.block_size
            if cached:
                table = self.bm.allocate_shared(req.req_id, cached,
                                                req.prompt_len + 1)
            else:
                table = self.bm.allocate(req.req_id, req.prompt_len + 1)
            toks = jnp.asarray(req.prompt_tokens, jnp.int32)
            if n_cached:
                logits = self.runner.prefill_suffix(toks[n_cached:], table,
                                                    n_cached)
            else:
                logits = self.runner.prefill(toks, table)
            if cache is not None:
                full = req.prompt_len // self.bm.block_size
                cache.insert(hashes[:full], table[:full], self.bm)
                cache.note_admitted(len(cached), bool(hashes))
            req.cached_prefix_len = n_cached
            self.stats.prefill_tokens += req.prompt_len - n_cached
            self.stats.prefill_tokens_saved += n_cached
            self._next_tok[req.req_id] = int(jnp.argmax(logits))
            if req.exec_start_time < 0:
                req.exec_start_time = self.clock()
            req.state = RequestState.RUNNING
            self.running.append(req)
            self.stats.n_admitted += 1

    def _preempt_one(self):
        """vLLM recompute policy: victim = latest-arrived running request."""
        victim = max(self.running, key=lambda r: (r.arrival_time, r.req_id))
        self.running.remove(victim)
        self.bm.free(victim.req_id)
        self._next_tok.pop(victim.req_id, None)
        victim.state = RequestState.PREEMPTED
        victim.n_preemptions += 1
        victim.output_len = 0                      # recompute from scratch
        victim.output_tokens.clear()
        self.waiting.appendleft(victim)
        self.stats.n_preempted += 1
        self.stats.recent_oom = True

    def _ensure_growable(self):
        """The whole running batch needs room to grow one token this step
        (cumulative blocks, not per-request).  Under pressure, cold cached
        blocks are evicted before any running request is preempted —
        recompute is far costlier than losing a cache entry."""
        def deficit():
            need = sum(
                max(self.bm.blocks_needed(r.total_len + 1)
                    - len(self.bm.block_table(r.req_id)), 0)
                for r in self.running[: self.runner.max_batch])
            return need - self.bm.free_blocks

        while self.running and deficit() > 0:
            if (self.prefix_cache is not None
                    and self.prefix_cache.evict(self.bm, deficit())):
                continue
            self._preempt_one()

    def step(self) -> List[Request]:
        """One continuous-batching iteration; returns finished requests."""
        self._admit()
        if not self.running:
            return []
        self._ensure_growable()
        if not self.running:
            return []
        b = self.runner.max_batch
        batch = self.running[:b]
        nbmax = max(len(self.bm.block_table(r.req_id)) + 1 for r in batch)
        tokens = np.zeros((b,), np.int32)
        positions = np.zeros((b,), np.int32)
        tables = np.zeros((b, nbmax), np.int32)
        live = np.zeros((b,), bool)
        for i, r in enumerate(batch):
            self.bm.allocate(r.req_id, r.total_len + 1)
            if self.prefix_cache is not None:
                # decode writes at r.total_len: that page must be private
                cow = self.bm.copy_on_write(
                    r.req_id, r.total_len // self.bm.block_size)
                if cow is not None:
                    self.runner.copy_block(*cow)
            t = self.bm.block_table(r.req_id)
            tables[i, :len(t)] = t
            tokens[i] = self._next_tok[r.req_id]
            positions[i] = r.total_len
            live[i] = True
        logits = self.runner.decode_batch(tokens, positions, tables, live)
        nxt = np.asarray(jnp.argmax(logits, -1))
        finished = []
        for i, r in enumerate(batch):
            r.output_tokens.append(int(tokens[i]))
            r.output_len += 1
            self._next_tok[r.req_id] = int(nxt[i])
            done = (r.output_len >= r.max_new_tokens
                    or (self.eos_token >= 0 and int(nxt[i]) == self.eos_token))
            if done:
                r.state = RequestState.FINISHED
                r.finish_time = self.clock()
                self.bm.free(r.req_id)
                self._next_tok.pop(r.req_id, None)
                self.running.remove(r)
                finished.append(r)
                self.stats.n_finished += 1
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.running and not self.waiting:
                break
        return out
