"""Shared-prefix KV reuse: hash-indexed, copy-on-write paged prefix cache.

Multi-agent workloads are dominated by repeated agent system-prompt
prefixes — every Router/Math/Humanities call resends the same preamble
(§2).  This module lets engines skip re-prefilling those tokens: token
sequences are hashed per *full* block with a rolling (radix-style) hash,
so a block's hash commits to the entire token prefix up to and including
that block.  Matching the hash chain of an incoming prompt against the
index yields the longest cached prefix; the engine then prefills only the
suffix and scatters only the new KV.

Block ownership is ref-counted through :class:`BlockManager`
(``kv_cache.py``): a cached block may be referenced by many sequences but
is written by none (cache entries only ever index *full, immutable*
blocks, and writers go through ``copy_on_write``).  When the last
reference drops, the block parks (state CACHED) instead of freeing; under
memory pressure the engine evicts parked blocks in LRU order of last hit.

The same object serves the real paged engine (hashing real token arrays)
and the discrete-event simulator (hashing synthetic per-agent keys via
:meth:`key_chain`), so sim scenarios exercise the identical data
structure and eviction policy.
"""
from __future__ import annotations

import collections
import hashlib
from typing import Dict, List, Sequence

import numpy as np

from repro.serving.kv_cache import BlockManager


class PrefixCacheStats:
    __slots__ = ("hits", "misses", "tokens_saved", "n_evicted", "n_inserted")

    def __init__(self):
        self.hits = 0          # requests that matched >= 1 block
        self.misses = 0        # requests that matched nothing
        self.tokens_saved = 0  # prompt tokens whose prefill was skipped
        self.n_evicted = 0     # blocks reclaimed under memory pressure
        self.n_inserted = 0    # blocks registered into the index

    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "tokens_saved": self.tokens_saved,
                "n_evicted": self.n_evicted, "n_inserted": self.n_inserted,
                "hit_rate": self.hit_rate()}


class PrefixCache:
    """Hash-chain index ``block_hash -> physical block id`` with LRU order.

    The index is an insertion/use-ordered dict: a hit moves the entry to
    the back, so iteration order is exactly LRU.  Entries whose block is
    actively referenced are never evicted (they cost nothing to keep —
    the block would stay allocated anyway)."""

    def __init__(self, block_size: int):
        assert block_size > 0
        self.block_size = block_size
        self._index: "collections.OrderedDict[int, int]" = collections.OrderedDict()
        self.stats = PrefixCacheStats()

    def __len__(self) -> int:
        return len(self._index)

    # ------------------------------------------------------------------ hashing
    @staticmethod
    def hash_tokens(tokens, block_size: int) -> List[int]:
        """Rolling 64-bit hash per full block of ``tokens``: hash i commits
        to tokens[0 : (i+1)*block_size].  Partial tail blocks get no hash —
        only immutable full blocks are ever shared.  64 bits keep the
        collision probability negligible at engine-lifetime cache sizes
        (~1e-12 at 10k distinct blocks); a collision would silently serve
        another prompt's KV, so 32-bit crc alone is not enough."""
        arr = np.ascontiguousarray(np.asarray(tokens, dtype=np.int64))
        out: List[int] = []
        h = b"\x00" * 8
        for i in range(len(arr) // block_size):
            h = hashlib.blake2b(
                h + arr[i * block_size:(i + 1) * block_size].tobytes(),
                digest_size=8).digest()
            out.append(int.from_bytes(h, "little"))
        return out

    @staticmethod
    def key_chain(key: str, n_blocks: int) -> List[int]:
        """Synthetic hash chain for the simulator: deterministic per
        (cache key, block index), chained like :meth:`hash_tokens` so
        prefix-of relationships are preserved."""
        out: List[int] = []
        h = b"\x00" * 8
        for i in range(n_blocks):
            h = hashlib.blake2b(h + f"{key}|{i}".encode(),
                                digest_size=8).digest()
            out.append(int.from_bytes(h, "little"))
        return out

    # ------------------------------------------------------------------ lookup
    def match(self, hashes: Sequence[int], bm: BlockManager) -> List[int]:
        """Longest cached prefix of the hash chain.  Acquires a reference
        on every returned block (caller owns them — pass to
        ``allocate_shared`` or ``ref_release`` them on abort).

        Does NOT update hit/miss stats: admission can still abort on
        capacity, and a stalled head-of-queue request retries its match
        every engine step — call :meth:`note_admitted` once the request
        is actually admitted."""
        blocks: List[int] = []
        for h in hashes:
            b = self._index.get(h)
            if b is None:
                break
            bm.ref_acquire(b)
            self._index.move_to_end(h)
            blocks.append(b)
        return blocks

    def note_admitted(self, n_matched_blocks: int, had_hashes: bool):
        """Record stats for one admitted request."""
        if n_matched_blocks:
            self.stats.hits += 1
            self.stats.tokens_saved += n_matched_blocks * self.block_size
        elif had_hashes:
            self.stats.misses += 1

    def insert(self, hashes: Sequence[int], table: Sequence[int],
               bm: BlockManager) -> List[tuple]:
        """Register freshly prefilled full blocks: hashes[i] -> table[i].
        Already-indexed hashes are kept (first writer wins; the colliding
        block stays private to its sequence).  Returns the ``(hash,
        block)`` pairs actually inserted, so a caller that indexed blocks
        ahead of KV execution can :meth:`retract` them on preemption."""
        inserted = []
        for h, b in zip(hashes, table):
            if h in self._index:
                continue
            self._index[h] = b
            bm.mark_cacheable(b)
            self.stats.n_inserted += 1
            inserted.append((h, b))
        return inserted

    def retract(self, pairs: Sequence[tuple], bm: BlockManager) -> List[int]:
        """De-index entries whose KV was never written (a request whose
        admission inserted them was preempted before its prefill
        executed).  Returns the blocks dropped from the index; they will
        free — not park — once their references release."""
        dropped = []
        for h, b in pairs:
            if self._index.get(h) != b:
                continue
            del self._index[h]
            bm.unmark_cacheable(b)
            dropped.append(b)
        return dropped

    # ------------------------------------------------------------------ evict
    def evict(self, bm: BlockManager, n_blocks: int) -> int:
        """Reclaim up to ``n_blocks`` zero-ref (parked) blocks, coldest
        first.  Returns how many went back to the free list."""
        freed = 0
        if n_blocks <= 0:
            return 0
        for h in list(self._index):
            if freed >= n_blocks:
                break
            b = self._index[h]
            if bm.ref_count(b) > 0:
                continue            # hot: some sequence still reads it
            del self._index[h]
            bm.reclaim(b)
            freed += 1
        self.stats.n_evicted += freed
        return freed

    def clear(self, bm: BlockManager):
        """Drop every zero-ref entry (e.g. on engine reset)."""
        self.evict(bm, len(self._index))

    # ------------------------------------------------------------------ helpers
    def usable_prefix_blocks(self, prompt_len: int) -> int:
        """How many full blocks of a prompt may be served from cache: at
        least one token must always be prefilled to produce next-token
        logits, so reuse is capped at ``prompt_len - 1`` tokens."""
        if prompt_len <= 1:
            return 0
        return (prompt_len - 1) // self.block_size
