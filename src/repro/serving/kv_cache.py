"""Paged KV-cache block manager (vLLM-style, TPU-native layout).

The pool is ``(num_blocks, block_size, n_kv, head_dim)`` per layer (the
layout the Pallas paged-attention kernel consumes).  The manager hands out
physical block ids; sequences own ordered block lists (their block table).

Invariants (property-tested in tests/test_kv_cache.py):
  * a block is owned by at most one sequence;
  * free + allocated == num_blocks;
  * freeing a sequence returns exactly the blocks it held.
"""
from __future__ import annotations

from typing import Dict, List


class NoFreeBlocks(Exception):
    pass


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owned: Dict[int, List[int]] = {}

    # ------------------------------------------------------------------ state
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_blocks / self.num_blocks

    def blocks_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.block_size)

    def can_allocate(self, seq_id: int, num_tokens: int) -> bool:
        have = len(self._owned.get(seq_id, ()))
        need = self.blocks_needed(num_tokens) - have
        return need <= len(self._free)

    # ------------------------------------------------------------- operations
    def allocate(self, seq_id: int, num_tokens: int) -> List[int]:
        """Grow seq's block list to cover num_tokens; returns full table."""
        table = self._owned.setdefault(seq_id, [])
        need = self.blocks_needed(num_tokens) - len(table)
        if need > len(self._free):
            raise NoFreeBlocks(
                f"need {need} blocks, have {len(self._free)} free")
        for _ in range(max(need, 0)):
            table.append(self._free.pop())
        return table

    def free(self, seq_id: int) -> List[int]:
        blocks = self._owned.pop(seq_id, [])
        self._free.extend(reversed(blocks))
        return blocks

    def block_table(self, seq_id: int) -> List[int]:
        return list(self._owned.get(seq_id, ()))

    def owned_seqs(self) -> List[int]:
        return list(self._owned)
