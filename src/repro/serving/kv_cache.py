"""Paged KV-cache block manager (vLLM-style, TPU-native layout).

The pool is ``(num_blocks, block_size, n_kv, head_dim)`` per layer (the
layout the Pallas paged-attention kernel consumes).  The manager hands out
physical block ids; sequences own ordered block lists (their block table).

Blocks are **ref-counted** so the prefix cache (``prefix_cache.py``) can
share immutable shared-prefix blocks across sequences, copy-on-write
style.  A block is in exactly one of three states:

  * FREE    — on the free list;
  * ACTIVE  — referenced by >= 1 sequence block tables (``_ref[b] >= 1``);
  * CACHED  — zero references but retained by the prefix cache (parked;
              its KV is still valid and can be re-acquired or reclaimed).

Invariants (property-tested in tests/test_kv_cache_properties.py):
  * free + active + cached == num_blocks;
  * a block's refcount equals the number of sequence tables containing it;
  * a block referenced by many sequences is written by at most one —
    writers must call :meth:`copy_on_write` first;
  * freeing every sequence and reclaiming every cached block returns the
    manager to all-free.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple


class NoFreeBlocks(Exception):
    pass


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owned: Dict[int, List[int]] = {}
        self._ref: Dict[int, int] = {}        # block -> #tables referencing it
        self._cacheable: Set[int] = set()     # registered with the prefix cache
        self._parked: Set[int] = set()        # CACHED: zero-ref, retained

    # ------------------------------------------------------------------ state
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def cached_blocks(self) -> int:
        return len(self._parked)

    @property
    def hard_used_blocks(self) -> int:
        """Blocks that cannot be reclaimed without hurting a sequence:
        used minus zero-ref parked cache blocks (admission watermarks
        count only these)."""
        return self.used_blocks - len(self._parked)

    @property
    def active_blocks(self) -> int:
        return len(self._ref)

    @property
    def utilization(self) -> float:
        return self.used_blocks / self.num_blocks

    def ref_count(self, block: int) -> int:
        return self._ref.get(block, 0)

    def is_shared(self, block: int) -> bool:
        """True if writing this block would corrupt another reader: either
        multiple tables reference it, or it backs a prefix-cache entry."""
        return self._ref.get(block, 0) > 1 or block in self._cacheable

    def blocks_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.block_size)

    def can_allocate(self, seq_id: int, num_tokens: int) -> bool:
        have = len(self._owned.get(seq_id, ()))
        need = self.blocks_needed(num_tokens) - have
        return need <= len(self._free)

    # ------------------------------------------------------------- refcounting
    def ref_acquire(self, block: int):
        """Take a reference: CACHED -> ACTIVE, or bump an ACTIVE block."""
        if block in self._parked:
            self._parked.discard(block)
            self._ref[block] = 1
        elif block in self._ref:
            self._ref[block] += 1
        else:
            raise KeyError(f"block {block} is free; cannot acquire")

    def ref_release(self, block: int) -> bool:
        """Drop a reference.  At zero the block parks (if cache-registered)
        or returns to the free list.  Returns True iff it parked."""
        n = self._ref.get(block)
        if n is None:
            raise KeyError(f"block {block} has no references")
        if n > 1:
            self._ref[block] = n - 1
            return False
        del self._ref[block]
        if block in self._cacheable:
            self._parked.add(block)
            return True
        self._free.append(block)
        return False

    # ------------------------------------------------------- cache registration
    def mark_cacheable(self, block: int):
        """Prefix cache registers a (full, immutable) block it indexes."""
        assert block in self._ref or block in self._parked
        self._cacheable.add(block)

    def reclaim(self, block: int):
        """Prefix-cache eviction: CACHED -> FREE.  Only zero-ref blocks."""
        assert block in self._parked, f"block {block} not evictable"
        self._parked.discard(block)
        self._cacheable.discard(block)
        self._free.append(block)

    def unmark_cacheable(self, block: int):
        """Cache retraction: the index entry backed by this block was
        dropped, so it must free — not park — when its references
        release.  An already-parked block is reclaimed immediately."""
        self._cacheable.discard(block)
        if block in self._parked:
            self._parked.discard(block)
            self._free.append(block)

    # ------------------------------------------------------------- operations
    def allocate(self, seq_id: int, num_tokens: int) -> List[int]:
        """Grow seq's block list to cover num_tokens; returns full table.
        Fresh blocks start with refcount 1 (owned solely by this seq)."""
        table = self._owned.setdefault(seq_id, [])
        need = self.blocks_needed(num_tokens) - len(table)
        if need > len(self._free):
            raise NoFreeBlocks(
                f"need {need} blocks, have {len(self._free)} free")
        for _ in range(max(need, 0)):
            b = self._free.pop()
            self._ref[b] = 1
            table.append(b)
        return table

    def allocate_shared(self, seq_id: int, shared: List[int],
                        num_tokens: int) -> List[int]:
        """Start a sequence table with ``shared`` prefix blocks (references
        already acquired by the caller, e.g. ``PrefixCache.match``), then
        allocate fresh private blocks out to ``num_tokens``."""
        assert seq_id not in self._owned, "allocate_shared seeds a new table"
        self._owned[seq_id] = list(shared)
        return self.allocate(seq_id, num_tokens)

    def copy_on_write(self, seq_id: int, block_idx: int) -> Optional[Tuple[int, int]]:
        """Make table[block_idx] privately writable.  If the block is shared
        (other readers, or it backs a cache entry), swap in a fresh block and
        return ``(src, dst)`` so the caller can copy the KV data; returns
        None when the block was already private."""
        table = self._owned[seq_id]
        old = table[block_idx]
        if not self.is_shared(old):
            return None
        if not self._free:
            raise NoFreeBlocks("copy-on-write needs a free block")
        new = self._free.pop()
        self._ref[new] = 1
        table[block_idx] = new
        self.ref_release(old)
        return old, new

    def free(self, seq_id: int) -> List[int]:
        """Release the sequence's references.  Returns the blocks that went
        back to the free list (shared/cached blocks merely lose a ref)."""
        blocks = self._owned.pop(seq_id, [])
        freed = []
        for b in reversed(blocks):
            n_free = len(self._free)
            self.ref_release(b)
            if len(self._free) > n_free:
                freed.append(b)
        return freed

    def block_table(self, seq_id: int) -> List[int]:
        return list(self._owned.get(seq_id, ()))

    def owned_seqs(self) -> List[int]:
        return list(self._owned)
