"""Instance-level batch scheduler shared by the real engine and the simulator.

Kairos' workflow-aware priorities (§5) used to stop at the load balancer:
once dispatched, both :class:`~repro.serving.engine.LLMEngine` and the
simulator's ``SimInstance`` fell back to FCFS deques with monolithic
prefill, so a long prompt head-of-line-blocked every running decode for a
full iteration.  This module owns every instance-side scheduling decision
— admission, prefix-cache matching, block accounting, growth / eviction /
preemption, and per-iteration batch composition — so that the real JAX
engine and the discrete-event simulator are thin *execution backends* of
one policy implementation instead of two drifting copies.

Two capabilities live here:

* **Priority-ordered instance queues** — the waiting queue is ordered by a
  :class:`~repro.core.scheduler.SchedulerPolicy` (FCFS for baselines,
  ``KairosScheduler`` for kairos runs), and admission is *strict*: the
  policy-first request that does not fit blocks everything behind it, so
  low-priority work can never slip past a high-priority request under
  memory pressure.  Preemption picks ``max`` by the policy's
  ``victim_key`` — by default the latest arrival (the classic vLLM
  recompute victim, least progress lost), independent of admission order.

* **Chunked prefill** (Sarathi-style) — with ``prefill_chunk_tokens`` set,
  prompts are prefilled in budget-sized chunks interleaved with decode
  steps instead of one monolithic pass, bounding the per-iteration stall a
  long prompt can inflict on running decodes.  ``prefill_chunk_tokens=None``
  reproduces monolithic prefill exactly (token-identical, same block
  accounting).

The scheduler composes an :class:`IterationPlan` per step; the engine
executes it with :class:`~repro.serving.engine.PagedModelRunner` (real
tokens), the simulator prices it with
:meth:`~repro.sim.cost_model.CostModel.iteration_time`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.scheduler import FCFSScheduler, SchedulerPolicy
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.kv_cache import BlockManager
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request, RequestPhase, RequestState


# =============================================================================
# prefix matchers (how a request's shareable prefix is identified)
# =============================================================================


class TokenPrefixMatcher:
    """Real engine: hash the actual prompt tokens (full blocks only)."""

    def __call__(self, req: Request, cache: PrefixCache,
                 bm: BlockManager) -> Tuple[List[int], List[int]]:
        if req.prefix_hashes is None:
            req.prefix_hashes = PrefixCache.hash_tokens(
                req.prompt_tokens, bm.block_size)
        hashes = req.prefix_hashes
        cached = cache.match(
            hashes[:cache.usable_prefix_blocks(req.prompt_len)], bm)
        return hashes, cached


class KeyPrefixMatcher:
    """Simulator: synthetic hash chain from the declared ``cache_key`` /
    ``shared_prefix_len`` (only the agent system prompt is known to be
    content-identical across calls)."""

    def __call__(self, req: Request, cache: PrefixCache,
                 bm: BlockManager) -> Tuple[List[int], List[int]]:
        if not req.cache_key or req.shared_prefix_len <= 0:
            return [], []
        n_blocks = min(req.prompt_len - 1, req.shared_prefix_len) \
            // bm.block_size
        hashes = PrefixCache.key_chain(req.cache_key, n_blocks)
        return hashes, cache.match(hashes, bm)


# =============================================================================
# iteration plan
# =============================================================================


@dataclasses.dataclass
class PrefillChunk:
    """One prompt segment to prefill this iteration: tokens
    ``[start, end)`` of ``req.prompt_tokens``, attending over the
    ``start`` resident tokens already in the pool (cached prefix +
    earlier chunks).  ``is_last`` marks the chunk that completes the
    prompt and yields next-token logits."""
    req: Request
    start: int
    end: int
    is_last: bool


@dataclasses.dataclass
class IterationPlan:
    """What one continuous-batching iteration executes.

    ``prefill_tokens`` — newly computed prompt tokens (sum of chunk sizes);
    ``context_tokens`` — resident tokens those chunks attend over (prices
    the re-read cost of chunked prefill; for monolithic prefill it equals
    the admission cache hit);
    ``cow`` — (src, dst) physical block copies the backend must perform
    before decoding (copy-on-write of shared pages).
    """
    chunks: List[PrefillChunk]
    decode: List[Request]
    cow: List[Tuple[int, int]]
    prefill_tokens: int
    context_tokens: int


# =============================================================================
# flat iteration batch (fused single-dispatch execution)
# =============================================================================

# Bucket floors for the padded static shapes of an IterationBatch.  Every
# dimension is rounded up to floor * 2^k, so the set of distinct compiled
# shapes grows logarithmically with the largest iteration ever composed —
# the jit cache is bounded by a few dozen entries no matter the workload
# (guarded by tests/test_fused_iteration.py).
TOKEN_BUCKET_FLOOR = 4      # chunk-tile length L
CHUNK_SEG_FLOOR = 1         # chunk-tile rows Sp (each padded row costs a
#                             whole L of dead compute, so start at 1)
SEGMENT_BUCKET_FLOOR = 4    # decode rows / sample rows
TABLE_BUCKET_FLOOR = 4      # block-table width
COW_BUCKET_FLOOR = 4        # copy-on-write pairs


def pad_bucket(n: int, floor: int) -> int:
    """Smallest floor * 2^k >= n; 0 stays 0 (an absent part of the batch
    keeps zero-sized static shapes, so e.g. decode-only iterations compile
    away the prefill computation entirely)."""
    if n == 0:
        return 0
    b = floor
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class Segment:
    """Host-side metadata for one row of an :class:`IterationBatch`:
    which request the row belongs to and whether its argmax row yields a
    token the backend must consume (final prefill chunk -> pending first
    token; decode -> next token)."""
    req: Request
    kind: str                  # "prefill" | "decode"
    emits_token: bool


@dataclasses.dataclass
class IterationBatch:
    """One iteration flattened into a single ragged device batch.

    All prefill-chunk tokens (arbitrary mid-block start/end, attending
    cached-prefix KV already resident in the pool) are concatenated with
    all decode tokens into one flat token batch; per-token metadata maps
    each row to its segment's block table, absolute position, and KV
    write slot, so the backend executes the whole iteration in ONE
    dispatch (segment-blocked causal mask, one KV scatter, one argmax
    transfer) instead of one dispatch per chunk plus a decode dispatch.

    The batch keeps the prefill part and the decode part as *separate
    arrays* (concatenated on device): chunk tokens are tiled dense
    (Sp, L) so each chunk's pages are gathered once — through the short
    tables covering its own prompt extent — while single decode tokens
    attend through their full (long) tables via the classic paged decode
    kernel.  A shared per-token layout would force every chunk token to
    gather the longest decode context (measured 3-4x more page-copy
    traffic).  The device row layout is
    ``[chunk s token j -> s*L + j | decode i -> Sp*L + i]``.

    Arrays are padded to a small set of static bucket shapes
    (:func:`pad_bucket`) to bound jit recompilation; padding token rows
    carry an out-of-range ``write_slots`` entry (scatters drop them) and
    padding segment rows are never consumed (``segments`` covers only
    real rows: chunks in plan order, then decodes).  An absent part
    (decode-only or prefill-only iteration) has zero-sized shapes and
    compiles away.
    """
    # -- prefill part: chunks tiled dense (Sp segments x L tokens) -----------
    tokens_p: np.ndarray      # (Sp, L) int32 prompt-chunk token ids
    positions_p: np.ndarray   # (Sp, L) int32 absolute position in the sequence
    tables_p: np.ndarray      # (Sp, nbp) int32: blocks covering each chunk's
    #                           prompt extent [0, end)
    # -- decode part (Td rows; each row is its own segment) ------------------
    tokens_d: np.ndarray      # (Td,) int32 pending next tokens
    positions_d: np.ndarray   # (Td,) int32 write/attend position (total_len)
    tables_d: np.ndarray      # (Td, nbd) int32 full sequence tables
    # -- shared --------------------------------------------------------------
    write_slots: np.ndarray   # (Sp*L+Td,) int32 flat pool slot (block*bs+off)
    #                           in device layout; padding -> n_slots (dropped)
    sample_rows: np.ndarray   # (S,) int32 device-layout row whose logits give
    #                           each segment's next token (padding -> 0)
    cow_src: np.ndarray       # (C,) int32 copy-on-write sources (padding -> 0)
    cow_dst: np.ndarray       # (C,) int32 destinations (padding -> num_blocks,
    #                           dropped by the copy scatter)
    segments: List[Segment]   # host metadata, one per REAL segment row
    n_tokens: int             # real (unpadded) token count

    @property
    def shape_key(self) -> Tuple[int, ...]:
        """The static shapes a jit specializes on — distinct keys bound
        the compile count."""
        return (*self.tokens_p.shape, self.tables_p.shape[1],
                len(self.tokens_d), self.tables_d.shape[1],
                len(self.sample_rows), len(self.cow_src))


def flatten_plan(plan: IterationPlan, bm: BlockManager,
                 next_token: Mapping[int, int]) -> IterationBatch:
    """Flatten an :class:`IterationPlan` into a single ragged
    :class:`IterationBatch`.

    ``next_token`` maps req_id -> pending decode token (the backend's
    sampled-but-not-yet-fed token; any ``int()``-convertible value,
    including the engine's deferred ``TokenRef``).  A request whose
    *final* prefill
    chunk is in this very plan has no pending token yet — its first
    decode token is the argmax of that chunk's logits, computed by this
    same dispatch — so its decode entry is deferred to the next
    iteration (classic prefill->decode pipelining; the generated token
    values are unchanged, only the iteration they land in shifts by one).
    """
    bs = bm.block_size
    n_slots = bm.num_blocks * bs
    segments: List[Segment] = []

    # prefill part: chunks tiled dense (Sp, L), tables trimmed per chunk
    chunks = plan.chunks
    sp = pad_bucket(len(chunks), CHUNK_SEG_FLOOR)
    lp = pad_bucket(max((c.end - c.start for c in chunks), default=0),
                    TOKEN_BUCKET_FLOOR)
    nbp = pad_bucket(max((bm.blocks_needed(c.end) for c in chunks), default=0),
                     TABLE_BUCKET_FLOOR)
    # decode part: one row per running sequence, full tables
    just_completed = {c.req.req_id for c in chunks if c.is_last}
    decode = [r for r in plan.decode if r.req_id not in just_completed]
    td = pad_bucket(len(decode), SEGMENT_BUCKET_FLOOR)
    nbd = pad_bucket(max((len(bm.block_table(r.req_id)) for r in decode),
                         default=0), TABLE_BUCKET_FLOOR)

    tokens_p = np.zeros((sp, lp), np.int32)
    positions_p = np.zeros((sp, lp), np.int32)
    tables_p = np.zeros((sp, nbp), np.int32)
    write_slots = np.full(sp * lp + td, n_slots, np.int32)
    sample_rows = np.zeros(pad_bucket(len(chunks) + len(decode),
                                      SEGMENT_BUCKET_FLOOR), np.int32)
    for s, c in enumerate(chunks):
        table = np.asarray(bm.block_table(c.req.req_id), np.int32)
        n = c.end - c.start
        pos = np.arange(c.start, c.end, dtype=np.int32)
        tokens_p[s, :n] = np.asarray(c.req.prompt_tokens, np.int32)[c.start:c.end]
        positions_p[s, :n] = pos
        tables_p[s, :bm.blocks_needed(c.end)] = table[:bm.blocks_needed(c.end)]
        write_slots[s * lp:s * lp + n] = table[pos // bs] * bs + pos % bs
        sample_rows[s] = s * lp + n - 1
        segments.append(Segment(c.req, "prefill", c.is_last))

    tokens_d = np.zeros(td, np.int32)
    positions_d = np.zeros(td, np.int32)
    tables_d = np.zeros((td, nbd), np.int32)
    for i, r in enumerate(decode):
        table = bm.block_table(r.req_id)
        # int() materializes deferred tokens (engine.TokenRef): feeding a
        # previous iteration's on-device argmax into this batch is the
        # one host sync of the pipelined execution model — by now the
        # producing dispatch has typically drained, so it's a copy, not
        # a stall
        tokens_d[i] = int(next_token[r.req_id])
        positions_d[i] = r.total_len
        tables_d[i, :len(table)] = table
        write_slots[sp * lp + i] = table[r.total_len // bs] * bs \
            + r.total_len % bs
        sample_rows[len(chunks) + i] = sp * lp + i
        segments.append(Segment(r, "decode", True))

    c_pad = pad_bucket(len(plan.cow), COW_BUCKET_FLOOR)
    cow_src = np.zeros(c_pad, np.int32)
    cow_dst = np.full(c_pad, bm.num_blocks, np.int32)
    for i, (src, dst) in enumerate(plan.cow):
        cow_src[i], cow_dst[i] = src, dst
    n_tokens = sum(c.end - c.start for c in chunks) + len(decode)
    return IterationBatch(tokens_p, positions_p, tables_p,
                          tokens_d, positions_d, tables_d,
                          write_slots, sample_rows, cow_src, cow_dst,
                          segments, n_tokens)


@dataclasses.dataclass
class SchedStats:
    n_finished: int = 0
    n_preempted: int = 0
    n_admitted: int = 0
    recent_oom: bool = False      # set on preemption; cleared by monitor reads
    prefill_tokens: int = 0       # prompt tokens actually computed
    prefill_tokens_saved: int = 0  # prompt tokens served from the prefix cache
    n_migrated_out: int = 0       # live requests released to another instance
    n_migrated_in: int = 0        # live requests adopted from another instance


# =============================================================================
# the scheduler
# =============================================================================


class BatchScheduler:
    """Admission + batch composition for one LLM instance.

    Parameters
    ----------
    bm:
        The instance's :class:`BlockManager` (owned by the backend so it
        can also expose monitor surfaces).
    policy:
        Ordering of the waiting queue and preemption-victim choice.
        Default FCFS (vLLM/Parrot semantics).
    prefix_cache / matcher:
        Shared-prefix KV reuse; ``matcher`` maps a request to its hash
        chain + cached blocks (token-hashing for the engine, key-chain
        for the simulator).
    max_running:
        Admission cap: how many requests may hold KV concurrently.
    max_batch:
        Per-iteration execution cap (decode slots).  Defaults to
        ``max_running``.
    prefill_chunk_tokens:
        Per-iteration prefill token budget.  ``None`` = monolithic
        prefill (a prompt is fully prefilled at admission, exactly the
        pre-refactor behaviour).
    watermark:
        Admission high-watermark on *hard* (non-reclaimable) block usage,
        vLLM-style hysteresis against growth thrash.
    on_preempt:
        Backend hook called with the victim request (e.g. the engine
        drops its pending next-token).
    tracer / instance_id:
        Observability: lifecycle events (admit, prefill-chunk, preempt,
        evict, finish) are emitted onto ``tracer``'s ring for
        ``instance_id``.  Defaults to the disabled :data:`NULL_TRACER` —
        every emit site is guarded on ``tracer.enabled`` so un-traced
        runs pay one branch.
    role:
        Disaggregation role of the owning instance.  ``"general"``
        (default) admits and decodes freely — the flat-cluster
        behaviour.  ``"prefill"`` runs chunked prefill only: requests
        whose prompt completes are expected to be handed off
        (``serving/handoff.py``) and are excluded from the decode set
        unless :meth:`allow_colocated_decode` marked them stranded (the
        lossless fallback when every decode pool is full).  ``"decode"``
        never admits from its waiting queue — work arrives exclusively
        through :meth:`adopt`.
    """

    def __init__(self, bm: BlockManager, *,
                 policy: Optional[SchedulerPolicy] = None,
                 prefix_cache: Optional[PrefixCache] = None,
                 matcher=None,
                 max_running: int = 16,
                 max_batch: Optional[int] = None,
                 prefill_chunk_tokens: Optional[int] = None,
                 watermark: float = 0.95,
                 on_preempt: Optional[Callable[[Request], None]] = None,
                 tracer: Tracer = NULL_TRACER,
                 instance_id: int = -1,
                 role: str = "general"):
        assert prefill_chunk_tokens is None or prefill_chunk_tokens > 0
        assert role in ("prefill", "decode", "general"), role
        self.role = role
        # req_ids a prefill-role instance may decode colocated: the
        # handoff driver strands a request here when no decode-capable
        # target can adopt it (retried with exponential backoff up to a
        # cap; decoding meanwhile loses nothing — migration is
        # bit-identical mid-decode)
        self.stranded: set = set()
        # strand-retry control (serving/handoff.py): failed-handoff count
        # per req_id, the sweep number before which a stranded request is
        # not re-offered, and the driver's sweep counter.  Past the cap a
        # request stops being offered at all — permanent colocation
        # instead of re-probing a full decode pool every sweep.
        self.strand_attempts: Dict[int, int] = {}
        self._strand_next: Dict[int, int] = {}
        self._handoff_sweep = 0
        self.bm = bm
        self.policy = policy or FCFSScheduler()
        self.prefix_cache = prefix_cache
        self.matcher = matcher or TokenPrefixMatcher()
        self.max_running = max_running
        self.max_batch = max_batch if max_batch is not None else max_running
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.watermark = watermark
        self.on_preempt = on_preempt
        self.tracer = tracer
        self.instance_id = instance_id
        self._now = 0.0          # timestamp of the current plan() step, so
        #                          preempt/evict emissions inside it are stamped
        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self.stats = SchedStats()
        # hash chain of requests admitted with chunking still in flight:
        # blocks are registered with the cache only once their KV exists
        self._pending_hashes: Dict[int, List[int]] = {}
        self._inserted_blocks: Dict[int, int] = {}
        # monolithic mode indexes blocks at admission, before the backend
        # executes the prefill (so same-plan admissions can share them);
        # the (hash, block) pairs are provisional until the chunk that
        # writes them is composed, and are retracted if the request is
        # preempted first
        self._provisional: Dict[int, List[tuple]] = {}

    # ------------------------------------------------------------------ intake
    def submit(self, req: Request):
        req.state = RequestState.WAITING
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.running or self.waiting)

    def can_admit(self, req: Request,
                  watermark: Optional[float] = None) -> bool:
        """Dispatcher probe: immediate admission capacity — batch slot +
        prompt memory below a high-watermark.  Zero-ref cached blocks are
        reclaimable, so they don't count against the watermark.  The
        probe defaults to the admission watermark minus a 0.05 hysteresis
        margin, so it always answers consistently with what ``_admit``
        will actually do."""
        if watermark is None:
            watermark = self.watermark - 0.05
        if self.role == "decode":
            return False          # decode instances admit only via adopt()
        if len(self.running) + len(self.waiting) >= self.max_running:
            return False
        pending = sum(r.prompt_len + 1 for r in self.waiting)
        need = self.bm.blocks_needed(req.prompt_len + 1 + pending)
        if not self.running and not self.waiting:
            # idle-instance bypass, mirroring _admit: an oversized prompt
            # may commit the whole pool rather than never dispatching
            return need <= self.bm.num_blocks - self.bm.hard_used_blocks
        budget = int(self.bm.num_blocks * watermark) - self.bm.hard_used_blocks
        return need <= budget

    # --------------------------------------------------------------- admission
    def _admit(self, now: float):
        """Admit waiting requests in strict policy order.  The first
        request that does not fit (memory watermark or free blocks)
        blocks admission — priority order is preserved even under
        pressure.  Admission is *not* gated on the prefill budget: an
        admitted prompt holds exactly the memory the monolithic path
        would, and the chunk budget below only shapes when its compute
        happens."""
        if not self.waiting or self.role == "decode":
            return
        watermark_blocks = int(self.bm.num_blocks * self.watermark)
        admitted: List[Request] = []
        for req in self.policy.order(self.waiting):
            if len(self.running) >= self.max_running:
                break
            hashes: List[int] = []
            cached: List[int] = []
            if self.prefix_cache is not None:
                hashes, cached = self.matcher(req, self.prefix_cache, self.bm)
            need = self.bm.blocks_needed(req.prompt_len + 1) - len(cached)
            # watermark first: it ignores reclaimable cached blocks, so
            # eviction can't satisfy it — evicting before checking would
            # trash the warm cache for nothing.  It only applies while
            # something is running: an idle instance may commit the whole
            # pool to one huge prompt (otherwise a prompt needing more
            # than watermark_blocks would starve forever)
            if (self.running
                    and self.bm.hard_used_blocks + need > watermark_blocks):
                for b in cached:
                    self.bm.ref_release(b)
                break
            if need > self.bm.free_blocks and self.prefix_cache is not None:
                n_ev = self.prefix_cache.evict(self.bm,
                                               need - self.bm.free_blocks)
                if n_ev and self.tracer.enabled:
                    self.tracer.emit("evict", instance_id=self.instance_id,
                                     ts=now, n=int(n_ev))
            if need > self.bm.free_blocks:
                for b in cached:          # abort: hand the refs back
                    self.bm.ref_release(b)
                break
            n_cached = len(cached) * self.bm.block_size
            if cached:
                table = self.bm.allocate_shared(req.req_id, cached,
                                                req.prompt_len + 1)
            else:
                table = self.bm.allocate(req.req_id, req.prompt_len + 1)
            if self.prefix_cache is not None:
                self.prefix_cache.note_admitted(len(cached), bool(hashes))
                if hashes and self.prefill_chunk_tokens is None:
                    # monolithic: the whole prompt is prefilled this very
                    # iteration, in admission order — later admissions may
                    # immediately share these blocks
                    self._provisional[req.req_id] = self.prefix_cache.insert(
                        hashes, table[:len(hashes)], self.bm)
                elif hashes:
                    # chunked: blocks become shareable only once written
                    self._pending_hashes[req.req_id] = list(hashes)
                    self._inserted_blocks[req.req_id] = \
                        n_cached // self.bm.block_size
            req.cached_prefix_len = n_cached
            req.prefilled_len = n_cached
            if req.exec_start_time < 0:
                req.exec_start_time = now
            req.state = RequestState.RUNNING
            self.running.append(req)
            admitted.append(req)
            self.stats.n_admitted += 1
            if self.tracer.enabled:
                self.tracer.emit("admit", req_id=req.req_id,
                                 instance_id=self.instance_id,
                                 agent=req.agent_name, msg_id=req.msg_id,
                                 ts=now, cached=n_cached)
            # prefill_tokens is charged as chunks are composed (so a
            # request preempted mid-prefill counts only executed tokens);
            # cache savings are realized here, at the match
            self.stats.prefill_tokens_saved += n_cached
        if admitted:
            gone = {r.req_id for r in admitted}
            self.waiting = [r for r in self.waiting if r.req_id not in gone]

    # -------------------------------------------------------------- preemption
    def _preempt_one(self):
        """Recompute policy: victim = ``max`` by the policy's
        ``victim_key``.  Every shipped policy inherits the default —
        latest arrival, i.e. the running request that loses the least
        decode progress to recompute."""
        self._preempt(max(self.running, key=self.policy.victim_key))

    def _preempt(self, victim: Request):
        self.running.remove(victim)
        # retract cache entries this request indexed at admission whose
        # KV was never executed: they must not outlive it as
        # matchable-but-garbage blocks
        pairs = self._provisional.pop(victim.req_id, None)
        dropped = (self.prefix_cache.retract(pairs, self.bm)
                   if pairs and self.prefix_cache is not None else [])
        self.bm.free(victim.req_id)
        self._pending_hashes.pop(victim.req_id, None)
        self._inserted_blocks.pop(victim.req_id, None)
        victim.state = RequestState.PREEMPTED
        victim.n_preemptions += 1
        if self.tracer.enabled:
            self.tracer.emit("preempt", req_id=victim.req_id,
                             instance_id=self.instance_id,
                             agent=victim.agent_name, msg_id=victim.msg_id,
                             ts=self._now,
                             lost=victim.prefilled_len + victim.output_len)
        victim.output_len = 0                      # recompute from scratch
        victim.output_tokens.clear()
        victim.prefilled_len = 0
        victim.first_token_time = -1.0             # recompute re-times TTFT
        victim.phase = RequestPhase.PREFILL        # prompt KV gone: re-prefill
        self.stranded.discard(victim.req_id)
        self.strand_attempts.pop(victim.req_id, None)
        self._strand_next.pop(victim.req_id, None)
        self.waiting.append(victim)
        self.stats.n_preempted += 1
        self.stats.recent_oom = True
        if self.on_preempt is not None:
            self.on_preempt(victim)
        if dropped:
            # cascade: a same-plan admission that matched the retracted
            # blocks holds references to KV that will never be written
            # (possible when the policy admits out of arrival order)
            garbage = set(dropped)
            for r in [r for r in self.running
                      if garbage.intersection(self.bm.block_table(r.req_id))]:
                if r in self.running:
                    self._preempt(r)

    def _ensure_growable(self):
        """The whole executing batch needs room to grow one token this
        step (cumulative blocks, not per-request).  Under pressure, cold
        cached blocks are evicted before any running request is preempted
        — recompute is far costlier than losing a cache entry."""
        def deficit():
            need = sum(
                max(self.bm.blocks_needed(r.total_len + 1)
                    - len(self.bm.block_table(r.req_id)), 0)
                for r in self.running[: self.max_batch])
            return need - self.bm.free_blocks

        while self.running and deficit() > 0:
            if self.prefix_cache is not None:
                n = self.prefix_cache.evict(self.bm, deficit())
                if n:
                    if self.tracer.enabled:
                        self.tracer.emit("evict",
                                         instance_id=self.instance_id,
                                         ts=self._now, n=int(n))
                    continue
            self._preempt_one()

    # ------------------------------------------------------------ composition
    def plan(self, now: float) -> Optional[IterationPlan]:
        """Compose one continuous-batching iteration: admit, make the
        batch growable, then hand out prefill chunks under the token
        budget and pick the decode set.  Returns None when idle."""
        budget = self.prefill_chunk_tokens
        self._now = now
        self._admit(now)
        if not self.running:
            return None
        self._ensure_growable()
        if not self.running:
            return None

        chunks: List[PrefillChunk] = []
        prefill_tokens = 0
        context_tokens = 0
        left = budget
        # budget is handed out in admission order (FIFO over the running
        # set), NOT re-sorted by policy each iteration: admission is
        # already policy-ordered, and run-to-completion finishes the
        # earliest-admitted prefill soonest — re-prioritizing mid-flight
        # processor-shares the budget across prompts, which measurably
        # delays every prefill completion (benchmarks/chunked_prefill.py
        # regresses ~17% p99 with policy-order handout)
        for r in self.running:
            rem = r.prompt_len - r.prefilled_len
            if rem <= 0:
                continue
            take = rem if left is None else min(rem, left)
            if take <= 0:
                break
            start = r.prefilled_len
            if take < rem:
                # align the chunk END (start + take) to a block boundary:
                # the engine's suffix-prefill jit cache is keyed on
                # (chunk_len, resident_len), and end-alignment makes those
                # pairs recur across requests even when leftover budget
                # spills a sub-budget first chunk into the next prompt.
                # A budget below block_size cannot align and simply pays
                # one compile per shape.
                aligned = take - (start + take) % self.bm.block_size
                if aligned > 0:
                    take = aligned
            chunks.append(PrefillChunk(r, start, start + take,
                                       is_last=start + take == r.prompt_len))
            if self.tracer.enabled:
                self.tracer.emit("prefill-chunk", req_id=r.req_id,
                                 instance_id=self.instance_id,
                                 agent=r.agent_name, msg_id=r.msg_id, ts=now,
                                 start=start, end=start + take,
                                 last=start + take == r.prompt_len)
            r.prefilled_len = start + take
            prefill_tokens += take
            context_tokens += start
            self.stats.prefill_tokens += take
            if left is not None:
                left -= take
            self._register_written_blocks(r)
            if start + take == r.prompt_len:
                # the chunk completing the prompt executes this very
                # iteration: admission-time inserts are now backed by KV
                self._provisional.pop(r.req_id, None)
                r.phase = RequestPhase.DECODE

        decode: List[Request] = []
        cow: List[Tuple[int, int]] = []
        for r in self.running[: self.max_batch]:
            if r.prefilled_len < r.prompt_len:
                continue
            if self.role == "prefill" and r.req_id not in self.stranded:
                # prefill instances never grow decode batches: this
                # request is leaving through the handoff driver (or will
                # be stranded here explicitly if no target can take it)
                continue
            self.bm.allocate(r.req_id, r.total_len + 1)
            if self.prefix_cache is not None:
                # decode writes at r.total_len: that page must be private
                pair = self.bm.copy_on_write(
                    r.req_id, r.total_len // self.bm.block_size)
                if pair is not None:
                    cow.append(pair)
            decode.append(r)
        if not chunks and not decode:
            return None
        if self.tracer.enabled:
            self.tracer.emit("iteration", instance_id=self.instance_id,
                             ts=now, n_chunks=len(chunks),
                             n_decode=len(decode),
                             n_tokens=prefill_tokens + len(decode))
        return IterationPlan(chunks, decode, cow, prefill_tokens,
                             context_tokens)

    def _register_written_blocks(self, req: Request) -> List[tuple]:
        """Chunked prefill: once a prompt block's KV is fully computed it
        may be shared — register it with the prefix cache.  (Admission
        matches run before chunk composition, so a match can never see a
        block whose KV has not been executed by the backend.)  Returns
        the ``(hash, block)`` pairs newly indexed, for callers that
        register ahead of the KV actually landing (:meth:`adopt`)."""
        hashes = self._pending_hashes.get(req.req_id)
        if hashes is None:
            return []
        done = min(req.prefilled_len // self.bm.block_size, len(hashes))
        ins = self._inserted_blocks[req.req_id]
        pairs: List[tuple] = []
        if done > ins:
            table = self.bm.block_table(req.req_id)
            pairs = self.prefix_cache.insert(hashes[ins:done],
                                             table[ins:done], self.bm)
            self._inserted_blocks[req.req_id] = done
        if req.prefilled_len >= req.prompt_len:
            self._pending_hashes.pop(req.req_id, None)
            self._inserted_blocks.pop(req.req_id, None)
        return pairs

    # ----------------------------------------------------------- disaggregation
    def handoff_ready(self) -> List[Request]:
        """Requests whose prompt KV is fully resident and that this
        instance will not decode itself — the prefill→decode handoff
        set.  Empty on non-prefill roles (general instances decode their
        own prefills; decode instances never prefill).  Stranded
        requests stay eligible: the driver retries them every step and
        migrates mid-decode once a target frees up (bit-identical)."""
        if self.role != "prefill":
            return []
        return [r for r in self.running if r.prefilled_len >= r.prompt_len]

    def handoff_offers(self, retry_cap: int) -> List[Request]:
        """:meth:`handoff_ready` filtered by strand-retry control, for
        one driver sweep (advances the sweep counter).  A stranded
        request backing off is withheld until its next-offer sweep; one
        past ``retry_cap`` failed offers is withheld permanently —
        colocated decode is its final home, so a full decode pool stops
        costing a probe per request per sweep."""
        self._handoff_sweep += 1
        out = []
        for r in self.handoff_ready():
            a = self.strand_attempts.get(r.req_id, 0)
            if a > retry_cap:
                continue
            if self._strand_next.get(r.req_id, 0) > self._handoff_sweep:
                continue
            out.append(r)
        return out

    def note_strand(self, req: Request, retry_cap: int) -> bool:
        """Book one failed handoff offer for ``req``: bump its attempt
        count and schedule its next offer exponentially later.  Returns
        True when the cap is now exceeded (the strand is permanent)."""
        a = self.strand_attempts.get(req.req_id, 0) + 1
        self.strand_attempts[req.req_id] = a
        self._strand_next[req.req_id] = self._handoff_sweep + (
            1 << min(a, 6))
        return a > retry_cap

    def allow_colocated_decode(self, req: Request) -> None:
        """Lossless fallback when no decode-capable instance can adopt
        ``req``: let this prefill instance decode it in place rather
        than stall it (or worse, preempt-and-recompute)."""
        self.stranded.add(req.req_id)

    # --------------------------------------------------------------- migration
    def release(self, req: Request) -> None:
        """Detach a live request WITHOUT resetting its progress — the
        source half of a live migration.  Unlike :meth:`_preempt`, the
        request keeps ``prefilled_len`` / ``output_len`` /
        ``output_tokens`` / ``first_token_time``: its KV is about to be
        rebuilt verbatim on another instance, not recomputed.  Blocks are
        freed here (shared/cached blocks merely lose a reference);
        provisional cache entries whose KV was never executed are
        retracted exactly as preemption would, including the cascade onto
        same-plan admissions that matched them."""
        if req in self.waiting:
            self.waiting.remove(req)
            req.state = RequestState.QUEUED
            return
        assert req in self.running, f"req {req.req_id} not on this scheduler"
        pairs = self._provisional.pop(req.req_id, None)
        dropped = (self.prefix_cache.retract(pairs, self.bm)
                   if pairs and self.prefix_cache is not None else [])
        self.bm.free(req.req_id)
        self._pending_hashes.pop(req.req_id, None)
        self._inserted_blocks.pop(req.req_id, None)
        self.stranded.discard(req.req_id)
        self.strand_attempts.pop(req.req_id, None)
        self._strand_next.pop(req.req_id, None)
        self.running.remove(req)
        req.state = RequestState.QUEUED
        self.stats.n_migrated_out += 1
        if dropped:
            garbage = set(dropped)
            for r in [r for r in self.running
                      if garbage.intersection(self.bm.block_table(r.req_id))]:
                if r in self.running:
                    self._preempt(r)

    def preempt(self, req: Request) -> None:
        """Public recompute-requeue of one running request (migration
        fallback when no instance can adopt it): progress resets, the
        request re-enters a waiting queue from scratch."""
        self._preempt(req)

    def can_adopt(self, req: Request, cached_blocks: int = 0) -> bool:
        """Whether :meth:`adopt` would succeed right now: a batch slot
        plus blocks for the request's resident KV and admission-style
        reserve (zero-ref parked cache blocks count — adopt may evict)."""
        if len(self.running) >= self.max_running:
            return False
        need = self.bm.blocks_needed(
            max(req.total_len + 1, req.prompt_len + 1)) - cached_blocks
        return need <= self.bm.free_blocks + self.bm.cached_blocks

    def adopt(self, req: Request, now: float,
              cached: Optional[List[int]] = None,
              hashes: Optional[List[int]] = None) -> List[int]:
        """Attach a migrated request to this scheduler's running set — the
        target half of a live migration — and return its block table for
        the caller to restore KV into.  ``cached`` seeds the table with
        prefix blocks already resident here (references acquired by the
        caller, e.g. ``PrefixCache.match``); ``hashes`` is the request's
        full-block hash chain, re-registered so the transferred prefix is
        shareable on this instance too (and, for a mid-prefill request,
        so later chunks keep registering as they execute).  Raises
        :class:`~repro.serving.kv_cache.NoFreeBlocks` when capacity is
        insufficient — probe :meth:`can_adopt` first."""
        cached = list(cached or [])
        reserve = max(req.total_len + 1, req.prompt_len + 1)
        need = self.bm.blocks_needed(reserve) - len(cached)
        if need > self.bm.free_blocks and self.prefix_cache is not None:
            self.prefix_cache.evict(self.bm, need - self.bm.free_blocks)
        if cached:
            table = self.bm.allocate_shared(req.req_id, cached, reserve)
        else:
            table = self.bm.allocate(req.req_id, reserve)
        req.state = RequestState.RUNNING
        if req.exec_start_time < 0:
            req.exec_start_time = now
        self.running.append(req)
        self.stats.n_migrated_in += 1
        if hashes and self.prefix_cache is not None:
            self._pending_hashes[req.req_id] = list(hashes)
            self._inserted_blocks[req.req_id] = len(cached)
            # indexed ahead of the migration's KV transfer: provisional
            # until the caller confirms the write landed, so a rolled-back
            # adoption cannot leave matchable-but-garbage blocks behind
            pairs = self._register_written_blocks(req)
            if pairs:
                self._provisional[req.req_id] = pairs
        return table

    def confirm_adoption(self, req: Request) -> None:
        """The migration's KV transfer landed: cache entries indexed by
        :meth:`adopt` are now backed by real KV and must survive a later
        release (wave-2 handoffs re-share them)."""
        self._provisional.pop(req.req_id, None)

    # ------------------------------------------------------------------ finish
    def finish(self, req: Request, t: float):
        """Backend reports a completed request: release memory + book it."""
        req.state = RequestState.FINISHED
        req.finish_time = t
        if self.tracer.enabled:
            self.tracer.emit("finish", req_id=req.req_id,
                             instance_id=self.instance_id,
                             agent=req.agent_name, msg_id=req.msg_id, ts=t,
                             out=req.output_len)
        self.bm.free(req.req_id)
        self.running.remove(req)
        self._pending_hashes.pop(req.req_id, None)
        self._inserted_blocks.pop(req.req_id, None)
        self._provisional.pop(req.req_id, None)
        self.stranded.discard(req.req_id)
        self.strand_attempts.pop(req.req_id, None)
        self._strand_next.pop(req.req_id, None)
        self.stats.n_finished += 1
