"""Live request migration: move a mid-flight request between engines.

The elastic cluster (``serving/autoscaler.py``) retires instances by
*draining them through migration*: every running request's state is
serialized into a block-granular :class:`RequestSnapshot` and rebuilt on
a surviving engine, so scale-down loses no progress and the continued
token stream is bit-identical to an unmigrated run (CI-gated exact by
``benchmarks/autoscale_burst.py``).

What a snapshot carries, and why it is sufficient:

* **Paged KV blocks** — the request's resident KV, gathered to host with
  :meth:`PagedModelRunner.read_blocks`.  Resident means positions
  ``[0, prefilled_len + output_len)``: a decoding request's pending
  (sampled-but-not-yet-fed) token has no KV yet — it is carried as a
  plain int and fed on the target, which writes its KV there.  Only the
  blocks covering resident tokens transfer; growth-reserve blocks are
  re-allocated by the target's scheduler.
* **Prefix-cache chain** — the prompt's full-block hash chain.  On
  restore, blocks the *target* already holds (hash match) are shared via
  ``allocate_shared`` instead of re-written, and the transferred prefix
  re-registers in the target's cache so later requests share it there;
  existing COW machinery keeps cache-registered blocks immutable under
  subsequent decode writes.
* **Generated tokens + scheduler position** — ``output_tokens`` /
  ``output_len`` / ``prefilled_len`` / timestamps live on the
  :class:`Request` object itself, which travels with the snapshot;
  :meth:`BatchScheduler.release` detaches it WITHOUT the progress reset
  preemption does, and :meth:`BatchScheduler.adopt` re-attaches it.

**The donated-pool address witness makes the transfer boundary explicit
and testable**: ``read_blocks`` only *reads* the source pool (its
device buffer address is unchanged — asserted here on every snapshot)
and ``write_blocks`` donates the target pool (its address is unchanged
too), so a migration moves exactly the gathered block bytes and neither
side ever materializes a second pool buffer.  Both calls must run
between synced iterations (no in-flight dispatch), which the cluster's
step loop guarantees.

**Batched transfers** (:func:`migrate_many`): N requests moving to one
target concatenate their non-cached block slices along the block axis
and land in ONE gathered donated ``write_blocks`` dispatch instead of
N — scale-down drains and multi-request prefill→decode handoffs
(``serving/handoff.py``) pay one dispatch per (source, target) pair,
not one per request.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.serving.engine import LLMEngine
from repro.serving.faults import TransferFault
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import Request


class MigrationError(RuntimeError):
    """The migration could not be performed — either refused up front
    (e.g. the target cannot adopt) or rolled back after a transfer
    failure.  Both ways the refusal is LOSSLESS: the request is running
    on its source with identical progress, and the target holds none of
    its blocks (``_rollback_adoptions`` re-homes already-adopted
    requests before this is raised)."""


@dataclasses.dataclass
class RequestSnapshot:
    """A request's transferable state, block-granular and host-resident."""
    req: Request
    kv: np.ndarray                 # (L, 2, n_blocks, block_size, n_kv, hd)
    hashes: List[int]              # full-block prompt hash chain (may be [])
    n_resident_tokens: int         # prefilled_len + output_len at snapshot
    pending_token: Optional[int]   # sampled-but-not-fed token (None mid-prefill)
    source_instance_id: int
    source_pool_address: object    # donated-pool witness at snapshot time
    n_cached_blocks: int = 0       # filled at restore: blocks served from the
    #                                target's prefix cache instead of the wire

    @property
    def n_blocks(self) -> int:
        return int(self.kv.shape[2])

    @property
    def n_bytes(self) -> int:
        """Bytes actually moved by this migration (the gathered blocks)."""
        return int(self.kv.size * self.kv.dtype.itemsize)


def snapshot_request(engine: LLMEngine, req: Request) -> RequestSnapshot:
    """Serialize a RUNNING request off ``engine`` and release its
    resources there.  Must run between synced iterations (no pending
    dispatch).  After this call the request belongs to nobody — pass the
    snapshot to :func:`restore_request` to re-home it."""
    assert not engine.has_pending, \
        "snapshot requires a synced engine (collect the iteration first)"
    assert req in engine.sched.running, \
        f"req {req.req_id} is not running on instance {engine.instance_id}"
    bm = engine.bm
    addr_before = engine.runner.pool_address()
    n_resident = req.prefilled_len + req.output_len
    table = bm.block_table(req.req_id)[:bm.blocks_needed(n_resident)]
    kv = engine.runner.read_blocks(table)
    assert isinstance(kv, np.ndarray), "snapshot KV must be host-resident"
    addr_after = engine.runner.pool_address()
    assert addr_after == addr_before, \
        "read_blocks must not disturb the donated pool buffer"
    hashes = req.prefix_hashes
    if hashes is None and req.prompt_tokens is not None:
        hashes = PrefixCache.hash_tokens(req.prompt_tokens, bm.block_size)
        req.prefix_hashes = hashes
    pending = engine.pending_token(req.req_id)
    engine.sched.release(req)
    engine.drop_pending_token(req.req_id)
    return RequestSnapshot(req=req, kv=kv, hashes=list(hashes or []),
                           n_resident_tokens=n_resident,
                           pending_token=pending,
                           source_instance_id=engine.instance_id,
                           source_pool_address=addr_before)


def restore_request(engine: LLMEngine, snap: RequestSnapshot,
                    now: Optional[float] = None) -> int:
    """Rebuild a snapshot on ``engine``: share what its prefix cache
    already holds, write the rest of the KV in one donated dispatch, and
    adopt the request into the scheduler mid-flight.  Returns the number
    of blocks served from the target's cache (not re-written)."""
    assert not engine.has_pending, \
        "restore requires a synced engine (collect the iteration first)"
    req = snap.req
    assert engine.instance_id != snap.source_instance_id or \
        req.req_id not in engine.bm.owned_seqs(), \
        "cannot restore onto the engine that still owns the request"
    now = engine.clock() if now is None else now
    bm = engine.bm
    n_res_blocks = bm.blocks_needed(snap.n_resident_tokens)
    cached: List[int] = []
    if engine.prefix_cache is not None and snap.hashes:
        # only fully-resident blocks can be served from the target cache:
        # a match beyond the transferred KV would leave holes
        matchable = min(len(snap.hashes),
                        snap.n_resident_tokens // bm.block_size)
        cached = engine.prefix_cache.match(snap.hashes[:matchable], bm)
    addr_before = engine.runner.pool_address()
    table = engine.sched.adopt(req, now, cached=cached, hashes=snap.hashes)
    if n_res_blocks > len(cached):
        engine.runner.write_blocks(snap.kv[:, :, len(cached):n_res_blocks],
                                   table[len(cached):n_res_blocks])
    addr_after = engine.runner.pool_address()
    assert addr_after == addr_before, \
        "write_blocks must donate the target pool in place"
    engine.sched.confirm_adoption(req)
    if snap.pending_token is not None:
        engine.set_pending_token(req.req_id, snap.pending_token)
    req.instance_id = engine.instance_id
    snap.n_cached_blocks = len(cached)
    return len(cached)


def _rollback_adoptions(source: LLMEngine, target: LLMEngine,
                        snaps: List[RequestSnapshot], now: float):
    """Undo a failed gathered transfer: release every adopted request's
    target-side blocks and re-home its snapshot on the source (which just
    released exactly the blocks it needs, so re-adoption cannot fail).
    After this, block accounting balances on BOTH managers and every
    request is RUNNING on the source with identical progress — the
    lossless-refusal invariant ``tests/test_migration.py`` witnesses."""
    for snap in reversed(snaps):
        req = snap.req
        if req.req_id in target.bm.owned_seqs():
            target.sched.release(req)
        target.drop_pending_token(req.req_id)
        restore_request(source, snap, now)


def migrate_many(source: LLMEngine, target: LLMEngine,
                 reqs: List[Request],
                 now: Optional[float] = None,
                 faults=None,
                 ) -> tuple:
    """Migrate every feasible request of ``reqs`` from ``source`` to
    ``target`` with ONE gathered donated ``write_blocks`` dispatch.

    Each request is probed (``can_adopt``), snapshotted, cache-matched
    and adopted individually — adoption updates the target's block
    accounting, so feasibility stays accurate as the batch grows — but
    the KV bytes of the whole batch are concatenated along the block
    axis and written in a single dispatch.  Requests the target cannot
    take are skipped untouched (still running on the source).

    Returns ``(snapshots, skipped)``: snapshots of the migrated requests
    (sum their ``n_bytes`` for transfer accounting; the whole batch cost
    at most one dispatch) and the requests left behind.

    **Partial-failure hardening**: the gathered write is the transfer's
    point of no return, and every adoption before it is provisional — if
    it raises (or ``faults`` injects a planned
    :class:`~repro.serving.faults.TransferFault` at that exact point),
    all target-side adoptions are rolled back and every snapshot is
    restored onto the source before :class:`MigrationError` surfaces.
    No block leaks on either side, no request lost."""
    if target is source:
        raise MigrationError("migration target must differ from source")
    assert not target.has_pending, \
        "migrate_many requires a synced target (collect the iteration first)"
    now = target.clock() if now is None else now
    bm = target.bm
    snaps: List[RequestSnapshot] = []
    skipped: List[Request] = []
    kv_parts: List[np.ndarray] = []
    table_parts: List[int] = []
    addr_before = target.runner.pool_address()
    for req in list(reqs):
        if not target.sched.can_adopt(req):
            skipped.append(req)
            continue
        snap = snapshot_request(source, req)
        n_res_blocks = bm.blocks_needed(snap.n_resident_tokens)
        cached: List[int] = []
        if target.prefix_cache is not None and snap.hashes:
            matchable = min(len(snap.hashes),
                            snap.n_resident_tokens // bm.block_size)
            cached = target.prefix_cache.match(snap.hashes[:matchable], bm)
        table = target.sched.adopt(req, now, cached=cached,
                                   hashes=snap.hashes)
        if n_res_blocks > len(cached):
            kv_parts.append(snap.kv[:, :, len(cached):n_res_blocks])
            table_parts.extend(table[len(cached):n_res_blocks])
        if snap.pending_token is not None:
            target.set_pending_token(req.req_id, snap.pending_token)
        req.instance_id = target.instance_id
        snap.n_cached_blocks = len(cached)
        snaps.append(snap)
    try:
        if faults is not None and snaps:
            spec = faults.transfer_fault(source.instance_id, now)
            if spec is not None:
                raise TransferFault(source.instance_id, spec.step)
        if kv_parts:
            target.runner.write_blocks(np.concatenate(kv_parts, axis=2),
                                       table_parts)
    except Exception as err:
        # transfer failed AFTER target allocation — the worst point.
        # Roll back to lossless refusal: the source re-adopts every
        # snapshot, the target's provisional blocks are released.
        _rollback_adoptions(source, target, snaps, now)
        raise MigrationError(
            f"gathered transfer {source.instance_id}->"
            f"{target.instance_id} failed and was rolled back: {err}"
        ) from err
    assert target.runner.pool_address() == addr_before, \
        "gathered write_blocks must donate the target pool in place"
    for snap in snaps:
        target.sched.confirm_adoption(snap.req)
    return snaps, skipped


def migrate(source: LLMEngine, target: LLMEngine, req: Request,
            now: Optional[float] = None,
            faults=None) -> RequestSnapshot:
    """Snapshot ``req`` off ``source`` and restore it on ``target``.

    Feasibility is probed BEFORE anything is released (a refused
    migration leaves the request untouched on the source), and a restore
    that fails mid-way — or a planned transfer fault — is rolled back to
    the source (same lossless-refusal contract as :func:`migrate_many`).
    The snapshot is returned so callers can account transfer bytes."""
    if target is source:
        raise MigrationError("migration target must differ from source")
    if not target.sched.can_adopt(req):
        raise MigrationError(
            f"instance {target.instance_id} cannot adopt req {req.req_id}")
    snap = snapshot_request(source, req)
    try:
        if faults is not None:
            spec = faults.transfer_fault(source.instance_id, now)
            if spec is not None:
                raise TransferFault(source.instance_id, spec.step)
        restore_request(target, snap, now)
    except Exception as err:
        _rollback_adoptions(source, target, [snap],
                            target.clock() if now is None else now)
        raise MigrationError(
            f"transfer {source.instance_id}->{target.instance_id} of req "
            f"{req.req_id} failed and was rolled back: {err}") from err
    return snap
