"""Pipelined multi-instance cluster runtime.

The paper's end-to-end system (§3, §6) is a *cluster*: a memory-aware
dispatcher spreads requests over many LLM instances and corrects itself
from live feedback (early finishes release future slots, a real
OOM/preemption fences the instance).  :class:`ServingCluster` is that
system on the real-engine path — it owns N :class:`LLMEngine`\\ s plus the
control plane (:class:`LoadBalancer` / :class:`TimeSlotDispatcher` /
:class:`Orchestrator`) and closes the loops the hand-rolled driver in
``agents/base.py`` used to leave open:

* **Pipelined execution** — each cluster step is breadth-first: every
  engine's fused iteration is *dispatched* first
  (:meth:`LLMEngine.dispatch_iteration`), results are *collected*
  after.  Dispatches are issued from a small worker pool, one engine
  per worker: host-side planning/flattening of engine *i+1* overlaps
  device compute of engine *i*, and — because XLA CPU runs a cheap
  computation on (or near) the calling thread with the GIL released —
  the engines' device computations themselves run concurrently, which
  a single-threaded jax-async-dispatch queue does not deliver for
  iteration-sized computations (measured: queue-depth pipelining is
  ~10% *slower* than block-each at smoke scale, while worker-thread
  dispatch is ~1.4x faster).  Each worker absorbs its own engine's
  device wait; next-token ids reach the control-plane thread as
  already-host-resident buffers, so ``collect`` never blocks (deferred
  host sync, see ``engine.TokenBuffer``).  ``pipelined=False`` keeps
  the legacy serial loop — step one engine at a time, blocking on its
  device->host transfer — as the differential baseline
  (``benchmarks/cluster_overlap.py`` measures the gap).

* **OOM feedback** (§6 adaptive) — after every collect the cluster polls
  ``engine.poll_oom()`` and fences the instance via
  ``dispatcher.on_oom``, exactly like the simulator's control plane.

* **Admission probe parity** — the dispatcher's ``admit_probe`` is
  :meth:`BatchScheduler.can_admit` (batch slot + watermarked prompt
  memory), not an ad-hoc queue-length check, so the dispatcher stops
  placing prompts that would immediately trigger preemption.

* **Completion feedback** — finished requests flow to
  ``orchestrator.on_completion`` (workflow analyzer + profiler) and
  ``dispatcher.on_finish`` (release future slots) in one place.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Optional, Sequence

from repro.obs.metrics import merge_snapshots
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.engine import LLMEngine
from repro.serving.request import CompletionRecord, Request


class ServingCluster:
    """N real engines + the Kairos control plane, stepped as one unit.

    Parameters
    ----------
    engines:
        The :class:`LLMEngine` instances (unique ``instance_id`` each).
    orchestrator:
        The :class:`~repro.core.orchestrator.Orchestrator` feeding
        priorities and memory ramps.
    scheduler:
        Load-balancer queue policy; defaults to the orchestrator-backed
        ``KairosScheduler``.
    dispatcher:
        Instance placement; defaults to a
        :class:`~repro.core.dispatcher.TimeSlotDispatcher` over the
        engines' KV capacities.  An injected dispatcher without an
        ``admit_probe`` is wired to the engines' ``can_admit``.
    pipelined:
        Breadth-first dispatch-all-then-collect-all with one worker per
        engine (default).  False = legacy serial loop (dispatch +
        blocking collect per engine, no workers).
    oom_feedback:
        Poll ``engine.poll_oom()`` and fence via ``dispatcher.on_oom``
        (default).  False reproduces the legacy driver loop, where the
        fencing hook was dead code on the real path — kept only as the
        differential baseline for benchmarks/tests.
    clock:
        Injectable time source (tests use a deterministic one).
    tracer:
        Observability sink shared by the whole cluster: control-plane
        events (submit/dispatch/oom-fence) land on ring ``-1``, each
        engine's on its own ring.  Pass the SAME tracer to the engines
        (they emit admit/first-token/decode/finish); the cluster wires
        it into the balancer and a default-constructed dispatcher.
        Defaults to disabled.
    """

    def __init__(self, engines: Sequence[LLMEngine], orchestrator, *,
                 scheduler=None, dispatcher=None, pipelined: bool = True,
                 oom_feedback: bool = True,
                 clock: Callable[[], float] = time.monotonic,
                 tracer: Tracer = NULL_TRACER):
        from repro.core.balancer import LoadBalancer
        from repro.core.dispatcher import InstanceModel, TimeSlotDispatcher
        from repro.core.scheduler import KairosScheduler

        self.engines: List[LLMEngine] = list(engines)
        assert self.engines, "a cluster needs at least one engine"
        self._by_id = {e.instance_id: e for e in self.engines}
        assert len(self._by_id) == len(self.engines), \
            "engine instance_ids must be unique"
        # donated in-place pools: each instance must own its pool buffer.
        # Two engines sharing one PagedModelRunner would have iteration A
        # donate (and overwrite, in place) the very buffer iteration B is
        # about to read — build per-instance runners with
        # ``PagedModelRunner.clone()`` instead (compiled fns stay shared;
        # pool buffers never are)
        runners = {id(e.runner) for e in self.engines}
        assert len(runners) == len(self.engines), \
            "engines must not share a PagedModelRunner (in-place donated " \
            "KV pools); use runner.clone() per instance"
        self.orch = orchestrator
        self.pipelined = pipelined
        self.oom_feedback = oom_feedback
        self.clock = clock
        self.tracer = tracer
        self._pool: Optional[ThreadPoolExecutor] = None
        if dispatcher is None:
            dispatcher = TimeSlotDispatcher(
                [InstanceModel(e.instance_id, e.kv_capacity_tokens)
                 for e in self.engines],
                admit_probe=self.can_admit, tracer=tracer)
        elif getattr(dispatcher, "admit_probe", None) is None:
            dispatcher.admit_probe = self.can_admit
        self.dispatcher = dispatcher
        self.balancer = LoadBalancer(
            scheduler or KairosScheduler(self.orch.priority_score),
            self.dispatcher, self.orch,
            lambda iid, req: self._by_id[iid].submit(req),
            tracer=tracer)

    # ---------------------------------------------------------------- factories
    @classmethod
    def on_mesh_slices(cls, model, params, orchestrator, *,
                       n_instances: int, model_parallel: int = 1,
                       devices=None, runner_kwargs: Optional[dict] = None,
                       engine_kwargs: Optional[dict] = None,
                       tracer: Tracer = NULL_TRACER, **cluster_kwargs
                       ) -> "ServingCluster":
        """Place ``n_instances`` engines on disjoint mesh slices.

        The production topology: data-parallel instances × tensor-
        parallel shards.  Carves the local devices (or ``devices``) into
        ``n_instances`` disjoint groups of ``model_parallel`` devices
        via :func:`repro.launch.mesh.make_slice_meshes` and builds one
        :class:`PagedModelRunner` per slice — each instance's KV pool
        and megatron-sharded params live only on its own devices, so
        instances never contend for a device and the donated-pool
        aliasing invariant holds per slice.  ``model_parallel=1``
        degenerates to plain single-device data parallelism (one device
        per instance), bit-identical to the unsharded engine.

        Engines get ``instance_id`` 0..N-1 and share ``tracer``; runner
        construction kwargs (``num_blocks``, ``block_size``, ...) go in
        ``runner_kwargs``, per-engine kwargs (``max_batch``,
        ``enable_prefix_cache``, ...) in ``engine_kwargs``, and the
        rest (``dispatcher``, ``pipelined``, ...) to the cluster
        constructor.  Compiled fns are NOT shared across slices (each
        slice's executables bind to its own device set) — same-slice
        scale-out still uses :meth:`PagedModelRunner.clone`.
        """
        from repro.launch.mesh import make_slice_meshes
        from repro.serving.engine import PagedModelRunner

        meshes = make_slice_meshes(n_instances, model_parallel,
                                   devices=devices)
        engines = []
        for i, mesh in enumerate(meshes):
            runner = PagedModelRunner(model, params, mesh=mesh,
                                      **(runner_kwargs or {}))
            engines.append(LLMEngine(runner, instance_id=i, tracer=tracer,
                                     **(engine_kwargs or {})))
        return cls(engines, orchestrator, tracer=tracer, **cluster_kwargs)

    # ------------------------------------------------------------------ intake
    def submit(self, req: Request):
        """Enqueue at the load balancer; the next step dispatches it."""
        self.balancer.enqueue(req)

    def can_admit(self, instance_id: int, req: Request) -> bool:
        """Dispatcher admit probe: the instance scheduler's own admission
        predicate (batch slot + watermarked prompt memory), matching the
        simulator's dispatch semantics."""
        return self._by_id[instance_id].sched.can_admit(req)

    @property
    def has_work(self) -> bool:
        return bool(self.balancer.queue) or any(
            e.sched.has_work or e.has_pending for e in self.engines)

    # ---------------------------------------------------------------- stepping
    def step(self, now: Optional[float] = None) -> List[Request]:
        """One cluster iteration: balance, then run every engine once.

        Pipelined mode issues ALL engine dispatches before the first
        collect, one worker thread per engine: while engine *i*'s fused
        iteration computes, the other workers plan/flatten/dispatch (and
        compute) theirs, and each worker absorbs its own device wait.
        Collect then runs on this thread in engine order — engine 0's
        bookkeeping overlaps engines 1..N-1 still computing — and never
        blocks (tokens arrive host-resident).  Serial mode steps engines
        one at a time with a forced host sync, reproducing the legacy
        driver loop exactly."""
        now = self.clock() if now is None else now
        self.balancer.tick(now)
        finished: List[Request] = []
        if self.pipelined and len(self.engines) > 1:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=len(self.engines),
                    thread_name_prefix="cluster-dispatch")
            futures = [self._pool.submit(self._dispatch_one, e)
                       for e in self.engines]
            for e, f in zip(self.engines, futures):
                f.result()
                finished.extend(self._collect(e, now))
        elif self.pipelined:
            # single engine: nothing to overlap across instances — skip
            # the worker handoff, keep only the deferred host sync
            e = self.engines[0]
            e.dispatch_iteration()
            finished.extend(self._collect(e, now))
        else:
            for e in self.engines:
                e.dispatch_iteration()
                finished.extend(self._collect(e, now, force_sync=True))
        return finished

    @staticmethod
    def _dispatch_one(e: LLMEngine):
        """Worker body: issue the engine's iteration and absorb its
        device wait here, off the control-plane thread.  Engine state is
        instance-local, so workers never contend."""
        e.dispatch_iteration()
        e.sync()

    def _collect(self, e: LLMEngine, now: float,
                 force_sync: bool = False) -> List[Request]:
        """Collect one engine and close the control-plane feedback loops."""
        done = e.collect(force_sync=force_sync)
        if e.poll_oom() and self.oom_feedback:
            # §6 adaptive: a real OOM/preemption fences the instance for a
            # cooldown so the dispatcher stops stacking load on it
            self.dispatcher.on_oom(e.instance_id, now)
        for r in done:
            self.orch.on_completion(CompletionRecord(
                agent_name=r.agent_name, msg_id=r.msg_id,
                upstream_name=r.upstream_name, app_name=r.app_name,
                start_time=r.arrival_time, end_time=r.finish_time,
                prompt_len=r.prompt_len, output_len=r.output_len,
                exec_start_time=r.exec_start_time,
                first_token_time=r.first_token_time))
            self.dispatcher.on_finish(r.instance_id, r.req_id)
        return done

    # ----------------------------------------------------------------- metrics
    def metrics_snapshot(self) -> dict:
        """All engines' metrics flattened under ``engine<i>.`` prefixes,
        plus cluster-level queue depth."""
        snap = merge_snapshots({f"engine{e.instance_id}": e.metrics_snapshot()
                                for e in self.engines})
        snap["queue_depth"] = float(len(self.balancer.queue))
        return snap

    # ------------------------------------------------------------------ drains
    def run_until_drained(self, max_steps: int = 100_000,
                          idle_sleep: float = 0.0) -> List[Request]:
        """Step until queue + engines are empty; returns all finishers."""
        out: List[Request] = []
        for _ in range(max_steps):
            done = self.step()
            out.extend(done)
            if not self.has_work:
                break
            if not done and idle_sleep:
                time.sleep(idle_sleep)
        return out

    def close(self):
        """Shut down the dispatch worker pool (idempotent).  Long-lived
        owners (a Workflow) keep the cluster open for its lifetime;
        benchmarks building many clusters call this between runs."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
