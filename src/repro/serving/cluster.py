"""Pipelined multi-instance cluster runtime.

The paper's end-to-end system (§3, §6) is a *cluster*: a memory-aware
dispatcher spreads requests over many LLM instances and corrects itself
from live feedback (early finishes release future slots, a real
OOM/preemption fences the instance).  :class:`ServingCluster` is that
system on the real-engine path — it owns N :class:`LLMEngine`\\ s plus the
control plane (:class:`LoadBalancer` / :class:`TimeSlotDispatcher` /
:class:`Orchestrator`) and closes the loops the hand-rolled driver in
``agents/base.py`` used to leave open:

* **Pipelined execution** — each cluster step is breadth-first: every
  engine's fused iteration is *dispatched* first
  (:meth:`LLMEngine.dispatch_iteration`), results are *collected*
  after.  Dispatches are issued from a small worker pool, one engine
  per worker: host-side planning/flattening of engine *i+1* overlaps
  device compute of engine *i*, and — because XLA CPU runs a cheap
  computation on (or near) the calling thread with the GIL released —
  the engines' device computations themselves run concurrently, which
  a single-threaded jax-async-dispatch queue does not deliver for
  iteration-sized computations (measured: queue-depth pipelining is
  ~10% *slower* than block-each at smoke scale, while worker-thread
  dispatch is ~1.4x faster).  Each worker absorbs its own engine's
  device wait; next-token ids reach the control-plane thread as
  already-host-resident buffers, so ``collect`` never blocks (deferred
  host sync, see ``engine.TokenBuffer``).  ``pipelined=False`` keeps
  the legacy serial loop — step one engine at a time, blocking on its
  device->host transfer — as the differential baseline
  (``benchmarks/cluster_overlap.py`` measures the gap).

* **OOM feedback** (§6 adaptive) — after every collect the cluster polls
  ``engine.poll_oom()`` and fences the instance via
  ``dispatcher.on_oom``, exactly like the simulator's control plane.

* **Admission probe parity** — the dispatcher's ``admit_probe`` is
  :meth:`BatchScheduler.can_admit` (batch slot + watermarked prompt
  memory), not an ad-hoc queue-length check, so the dispatcher stops
  placing prompts that would immediately trigger preemption.

* **Completion feedback** — finished requests flow to
  ``orchestrator.on_completion`` (workflow analyzer + profiler) and
  ``dispatcher.on_finish`` (release future slots) in one place.

* **Fault plane** — a :class:`~repro.serving.faults.FaultPlan` (chaos
  testing) injects crashes/stragglers/ooms at planned points; a crash
  surfaces as :class:`InstanceCrashed` from the engine's dispatch and is
  handled at the synced post-collect point by the cluster's
  :class:`~repro.serving.recovery.RecoveryManager` — the dead instance
  is fenced + removed and its in-flight requests are reconstructed with
  bit-identical replay.  An optional
  :class:`~repro.serving.recovery.LoadShedder` (``config.slo_e2e_s``)
  sheds deadline-hopeless requests under sustained overload.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Optional, Sequence

from repro.obs.metrics import merge_snapshots
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.config import ServingConfig
from repro.serving.engine import LLMEngine
from repro.serving.faults import FaultInjector, FaultPlan, InstanceCrashed
from repro.serving.recovery import LoadShedder, RecoveryManager
from repro.serving.request import CompletionRecord, Request


class ServingCluster:
    """N real engines + the Kairos control plane, stepped as one unit.

    Parameters
    ----------
    engines:
        The :class:`LLMEngine` instances (unique ``instance_id`` each).
    orchestrator:
        The :class:`~repro.core.orchestrator.Orchestrator` feeding
        priorities and memory ramps.
    scheduler:
        Load-balancer queue policy; defaults to the orchestrator-backed
        ``KairosScheduler``.
    dispatcher:
        Instance placement; defaults to a
        :class:`~repro.core.dispatcher.TimeSlotDispatcher` over the
        engines' KV capacities.  An injected dispatcher without an
        ``admit_probe`` is wired to the engines' ``can_admit``.
    pipelined:
        Breadth-first dispatch-all-then-collect-all with one worker per
        engine (default).  False = legacy serial loop (dispatch +
        blocking collect per engine, no workers).
    oom_feedback:
        Poll ``engine.poll_oom()`` and fence via ``dispatcher.on_oom``
        (default).  False reproduces the legacy driver loop, where the
        fencing hook was dead code on the real path — kept only as the
        differential baseline for benchmarks/tests.
    clock:
        Injectable time source (tests use a deterministic one).
    tracer:
        Observability sink shared by the whole cluster: control-plane
        events (submit/dispatch/oom-fence) land on ring ``-1``, each
        engine's on its own ring.  Pass the SAME tracer to the engines
        (they emit admit/first-token/decode/finish); the cluster wires
        it into the balancer and a default-constructed dispatcher.
        Defaults to disabled.
    """

    def __init__(self, engines: Sequence[LLMEngine], orchestrator, *,
                 scheduler=None, dispatcher=None, pipelined: bool = True,
                 oom_feedback: bool = True,
                 clock: Callable[[], float] = time.monotonic,
                 engine_factory: Optional[Callable[[int], LLMEngine]] = None,
                 faults: Optional[FaultPlan] = None,
                 tracer: Tracer = NULL_TRACER):
        from repro.core.balancer import LoadBalancer
        from repro.core.dispatcher import InstanceModel, TimeSlotDispatcher
        from repro.core.scheduler import KairosScheduler

        self.engines: List[LLMEngine] = list(engines)
        assert self.engines, "a cluster needs at least one engine"
        self._by_id = {e.instance_id: e for e in self.engines}
        assert len(self._by_id) == len(self.engines), \
            "engine instance_ids must be unique"
        # donated in-place pools: each instance must own its pool buffer.
        # Two engines sharing one PagedModelRunner would have iteration A
        # donate (and overwrite, in place) the very buffer iteration B is
        # about to read — build per-instance runners with
        # ``PagedModelRunner.clone()`` instead (compiled fns stay shared;
        # pool buffers never are)
        runners = {id(e.runner) for e in self.engines}
        assert len(runners) == len(self.engines), \
            "engines must not share a PagedModelRunner (in-place donated " \
            "KV pools); use runner.clone() per instance"
        self.orch = orchestrator
        self.pipelined = pipelined
        self.oom_feedback = oom_feedback
        self.clock = clock
        self.tracer = tracer
        self._pool: Optional[ThreadPoolExecutor] = None
        # elasticity: the factory mints engines for scale_up (set by
        # from_config; manual clusters may pass their own); the autoscaler
        # is attached post-construction and consulted at step start.
        self._engine_factory = engine_factory
        self.autoscaler = None
        self.config: Optional[ServingConfig] = None
        self.n_migrations = 0
        self.migrated_bytes = 0
        self.migration_dispatches = 0   # gathered write_blocks calls spent
        #                                 on migrations (batched: <= requests)
        # prefill→decode disaggregation accounting (serving/handoff.py)
        self.n_handoffs = 0
        self.handoff_bytes = 0
        self.handoff_dispatches = 0
        self.n_stranded = 0
        self.n_strand_retries = 0
        # fault plane: one injector consumes the plan across the whole
        # run (per-instance ordinals live in the injector); the recovery
        # manager is always live — crashes need no opt-in — and the
        # shedder only exists when config.slo_e2e_s arms the valve
        # (from_config replaces both with config-tuned instances)
        self.faults: Optional[FaultInjector] = (
            FaultInjector(faults, tracer) if isinstance(faults, FaultPlan)
            else faults)
        for e in self.engines:
            e.faults = self.faults
        self.recovery = RecoveryManager(tracer=tracer)
        self.shedder: Optional[LoadShedder] = None
        self._shed_at_submit: List[Request] = []
        if dispatcher is None:
            dispatcher = TimeSlotDispatcher(
                [InstanceModel(e.instance_id, e.kv_capacity_tokens,
                               role=e.role)
                 for e in self.engines],
                admit_probe=self.can_admit, tracer=tracer)
        elif getattr(dispatcher, "admit_probe", None) is None:
            dispatcher.admit_probe = self.can_admit
        self.dispatcher = dispatcher
        self.balancer = LoadBalancer(
            scheduler or KairosScheduler(self.orch.priority_score),
            self.dispatcher, self.orch,
            lambda iid, req: self._by_id[iid].submit(req),
            tracer=tracer)

    # ---------------------------------------------------------------- factories
    @classmethod
    def on_mesh_slices(cls, model, params, orchestrator, *,
                       n_instances: int, model_parallel: int = 1,
                       devices=None, runner_kwargs: Optional[dict] = None,
                       engine_kwargs: Optional[dict] = None,
                       tracer: Tracer = NULL_TRACER, **cluster_kwargs
                       ) -> "ServingCluster":
        """Place ``n_instances`` engines on disjoint mesh slices.

        The production topology: data-parallel instances × tensor-
        parallel shards.  Carves the local devices (or ``devices``) into
        ``n_instances`` disjoint groups of ``model_parallel`` devices
        via :func:`repro.launch.mesh.make_slice_meshes` and builds one
        :class:`PagedModelRunner` per slice — each instance's KV pool
        and megatron-sharded params live only on its own devices, so
        instances never contend for a device and the donated-pool
        aliasing invariant holds per slice.  ``model_parallel=1``
        degenerates to plain single-device data parallelism (one device
        per instance), bit-identical to the unsharded engine.

        Engines get ``instance_id`` 0..N-1 and share ``tracer``; runner
        construction kwargs (``num_blocks``, ``block_size``, ...) go in
        ``runner_kwargs``, per-engine kwargs (``max_batch``,
        ``enable_prefix_cache``, ...) in ``engine_kwargs``, and the
        rest (``dispatcher``, ``pipelined``, ...) to the cluster
        constructor.  Compiled fns are NOT shared across slices (each
        slice's executables bind to its own device set) — same-slice
        scale-out still uses :meth:`PagedModelRunner.clone`.
        """
        from repro.launch.mesh import make_slice_meshes
        from repro.serving.engine import PagedModelRunner

        meshes = make_slice_meshes(n_instances, model_parallel,
                                   devices=devices)
        engines = []
        for i, mesh in enumerate(meshes):
            runner = PagedModelRunner(model, params, mesh=mesh,
                                      **(runner_kwargs or {}))
            engines.append(LLMEngine(runner, instance_id=i, tracer=tracer,
                                     **(engine_kwargs or {})))
        return cls(engines, orchestrator, tracer=tracer, **cluster_kwargs)

    @classmethod
    def from_config(cls, model, params, orchestrator,
                    config: ServingConfig, *, backend=None, devices=None,
                    clock: Callable[[], float] = time.monotonic,
                    tracer: Tracer = NULL_TRACER, **cluster_kwargs
                    ) -> "ServingCluster":
        """Build the whole cluster from ONE :class:`ServingConfig`.

        The config describes "an instance like the others" — which is
        what makes elasticity possible: the returned cluster carries an
        engine factory minting identically-configured engines (shared
        compiled fns via :meth:`PagedModelRunner.clone`, private KV
        pool), so :meth:`scale_up` can add capacity at runtime.
        ``model_parallel > 1`` routes through :meth:`on_mesh_slices`
        (static topology — mesh slices are placement, fixed at launch,
        so no elastic factory there)."""
        from repro.core.scheduler import FCFSScheduler
        from repro.serving.engine import PagedModelRunner

        if config.tracing and tracer is NULL_TRACER:
            tracer = Tracer(clock=clock)
        scheduler = (cluster_kwargs.pop("scheduler", None)
                     or config.make_policy(orchestrator) or FCFSScheduler())
        if config.model_parallel > 1:
            cluster = cls.on_mesh_slices(
                model, params, orchestrator,
                n_instances=config.n_instances,
                model_parallel=config.model_parallel, devices=devices,
                runner_kwargs=config.runner_kwargs(),
                engine_kwargs=config.engine_kwargs(),
                tracer=tracer, scheduler=scheduler, clock=clock,
                **cluster_kwargs)
            cluster.config = config
            cluster._arm_fault_plane(config, tracer)
            return cluster
        runner0 = PagedModelRunner.from_config(model, params, config,
                                               backend=backend)

        def make_engine(iid: int, runner=None,
                        role: Optional[str] = None) -> LLMEngine:
            return LLMEngine.from_config(
                runner if runner is not None else runner0.clone(), config,
                instance_id=iid, clock=clock,
                policy=config.make_policy(orchestrator), tracer=tracer,
                role=role)

        engines = [make_engine(0, runner0)]
        engines += [make_engine(i) for i in range(1, config.n_instances)]
        cluster = cls(engines, orchestrator, scheduler=scheduler,
                      engine_factory=make_engine, clock=clock,
                      tracer=tracer, **cluster_kwargs)
        cluster.config = config
        cluster._arm_fault_plane(config, tracer)
        return cluster

    def _arm_fault_plane(self, config: ServingConfig, tracer: Tracer):
        """Tune recovery to the config's budgets and arm the overload
        valve when ``slo_e2e_s`` declares a deadline.  The shedder prices
        service time with the default :class:`CostModel` — the same rule
        the sim sheds by."""
        self.recovery = RecoveryManager(
            max_retries=config.recovery_retries,
            backoff_s=config.recovery_backoff_s,
            step_deadline_s=config.step_deadline_s, tracer=tracer)
        if config.slo_e2e_s is not None:
            from repro.sim.cost_model import CostModel
            self.shedder = LoadShedder(
                slo_e2e_s=config.slo_e2e_s, cost=CostModel(),
                queue_high=config.shed_queue_high,
                kv_high=config.shed_kv_high,
                patience=config.shed_patience, tracer=tracer)

    # ----------------------------------------------------------- public surface
    #
    # ``submit()`` / ``step()`` / ``drain()`` / ``metrics_snapshot()`` are
    # THE cluster contract: everything a driver (Workflow, benchmarks,
    # autoscaler policies) needs.  ``balancer`` / ``engines`` /
    # ``dispatcher`` are internals — reaching past the contract couples
    # callers to the control-plane layout and breaks under elasticity
    # (engines appear and disappear at runtime).

    def submit(self, req: Request):
        """Accept a request into the cluster.  The request is queued at
        the load balancer and placed onto an instance by a subsequent
        :meth:`step`; completion surfaces in that step's return value
        (and via ``orchestrator.on_completion``).  Valid at any time,
        including while the autoscaler is resizing the cluster.

        When the overload valve is armed AND open (sustained overload),
        a request whose deadline is already unreachable is shed at the
        door instead of queued — it surfaces, state ``SHED``, in the
        next step's finishers so drivers unblock."""
        if (self.shedder is not None and self.shedder.open
                and self.shedder.slack(req, self.clock()) < 0.0):
            self.shedder.shed(req, self.clock(), len(self.balancer.queue))
            self._shed_at_submit.append(req)
            return
        self.balancer.enqueue(req)

    def can_admit(self, instance_id: int, req: Request) -> bool:
        """Dispatcher admit probe: the instance scheduler's own admission
        predicate (batch slot + watermarked prompt memory), matching the
        simulator's dispatch semantics."""
        return self._by_id[instance_id].sched.can_admit(req)

    @property
    def has_work(self) -> bool:
        return (bool(self.balancer.queue) or self.recovery.pending > 0
                or bool(self._shed_at_submit)
                or any(e.sched.has_work or e.has_pending
                       for e in self.engines))

    # ---------------------------------------------------------------- stepping
    ROLE_STEP_ORDER = ("prefill", "general", "decode")

    def _role_groups(self) -> List[List[LLMEngine]]:
        """Engines grouped by role in step order: prefill groups first so
        their just-completed prompts hand off at this step's end, decode
        last so adopted requests decode at the earliest next step.  A
        flat cluster is exactly one "general" group — the
        pre-disaggregation step loop, unchanged."""
        groups = []
        for role in self.ROLE_STEP_ORDER:
            g = [e for e in self.engines if e.role == role]
            if g:
                groups.append(g)
        return groups

    def step(self, now: Optional[float] = None) -> List[Request]:
        """One cluster iteration: balance, then run every role group
        breadth-first, then sweep prefill→decode handoffs.

        Pipelined mode issues a whole group's engine dispatches before
        the group's first collect, one worker thread per engine: while
        engine *i*'s fused iteration computes, the other workers
        plan/flatten/dispatch (and compute) theirs, and each worker
        absorbs its own device wait.  Collect then runs on this thread
        in engine order — engine 0's bookkeeping overlaps engines
        1..N-1 still computing — and never blocks (tokens arrive
        host-resident).  Serial mode steps engines one at a time with a
        forced host sync, reproducing the legacy driver loop exactly.

        After every group has collected (all pools synced — the only
        legal transfer point), requests that completed prefill on a
        prefill-role instance are handed to decode-capable instances
        (``serving/handoff.py``), one gathered donated dispatch per
        (source, target) batch."""
        now = self.clock() if now is None else now
        finished: List[Request] = []
        if self._shed_at_submit:
            # requests shed at the submit door surface here so callers
            # waiting on step() results unblock
            finished.extend(self._shed_at_submit)
            self._shed_at_submit.clear()
        self.recovery.tick(self, now)
        if self.autoscaler is not None:
            # engines are synced between steps, which is exactly when
            # live migration (scale-down drain) is legal
            finished.extend(self.autoscaler.step(self, now))
        if self.shedder is not None:
            finished.extend(self._shed_sweep(now))
        self.balancer.tick(now)
        for group in self._role_groups():
            if self.pipelined and len(group) > 1:
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(
                        max_workers=len(self.engines),
                        thread_name_prefix="cluster-dispatch")
                futures = [self._pool.submit(self._dispatch_one, e)
                           for e in group]
                for e, f in zip(group, futures):
                    try:
                        f.result()
                    except InstanceCrashed:
                        finished.extend(self.recovery.on_crash(self, e, now))
                        continue
                    finished.extend(self._collect(e, now))
                    self.recovery.check_step_deadline(
                        self, e, e.last_step_wall, now)
            elif self.pipelined:
                # single engine: nothing to overlap across instances —
                # skip the worker handoff, keep the deferred host sync
                e = group[0]
                try:
                    t0 = time.monotonic()
                    e.dispatch_iteration()
                    e.last_step_wall = time.monotonic() - t0
                except InstanceCrashed:
                    finished.extend(self.recovery.on_crash(self, e, now))
                    continue
                finished.extend(self._collect(e, now))
                self.recovery.check_step_deadline(
                    self, e, e.last_step_wall, now)
            else:
                for e in group:
                    try:
                        t0 = time.monotonic()
                        e.dispatch_iteration()
                        e.last_step_wall = time.monotonic() - t0
                    except InstanceCrashed:
                        finished.extend(self.recovery.on_crash(self, e, now))
                        continue
                    finished.extend(self._collect(e, now, force_sync=True))
                    self.recovery.check_step_deadline(
                        self, e, e.last_step_wall, now)
        if any(e.role == "prefill" for e in self.engines):
            from repro.serving.handoff import drive_handoffs
            hs = drive_handoffs(self, now)
            self.n_handoffs += hs["n_handoffs"]
            self.handoff_bytes += hs["handoff_bytes"]
            self.handoff_dispatches += hs["handoff_dispatches"]
            self.n_stranded += hs["n_stranded"]
            self.n_strand_retries += hs["n_strand_retries"]
            for e in self.engines:
                if e.role != "decode" or not e.sched.waiting:
                    continue
                # a decode instance's waiting queue can only hold requests
                # it preempted (admission is adopt-only), and its role gate
                # would never re-admit them: recompute belongs on a
                # prefill-capable instance, so route them back through the
                # balancer (preemption already reset their phase)
                for req in list(e.sched.waiting):
                    e.sched.release(req)
                    self.dispatcher.on_finish(e.instance_id, req.req_id)
                    self.balancer.enqueue(req)
        return finished

    @staticmethod
    def _dispatch_one(e: LLMEngine):
        """Worker body: issue the engine's iteration and absorb its
        device wait here, off the control-plane thread.  Engine state is
        instance-local, so workers never contend.  The measured wall time
        (dispatch + device wait) feeds the straggler step-deadline check;
        the write is engine-local, read post-collect on the control
        plane."""
        t0 = time.monotonic()
        e.dispatch_iteration()
        e.sync()
        e.last_step_wall = time.monotonic() - t0

    def _collect(self, e: LLMEngine, now: float,
                 force_sync: bool = False) -> List[Request]:
        """Collect one engine and close the control-plane feedback loops."""
        done = e.collect(force_sync=force_sync)
        if e.poll_oom() and self.oom_feedback:
            # §6 adaptive: a real OOM/preemption fences the instance for a
            # cooldown so the dispatcher stops stacking load on it
            self.dispatcher.on_oom(e.instance_id, now)
        for r in done:
            # a recovered request's replayed prefix is re-emitted and its
            # original prompt identity restored BEFORE the completion
            # record — downstream sees it as if no crash had happened
            self.recovery.on_finish(r)
            self.orch.on_completion(CompletionRecord(
                agent_name=r.agent_name, msg_id=r.msg_id,
                upstream_name=r.upstream_name, app_name=r.app_name,
                start_time=r.arrival_time, end_time=r.finish_time,
                prompt_len=r.prompt_len, output_len=r.output_len,
                exec_start_time=r.exec_start_time,
                first_token_time=r.first_token_time))
            self.dispatcher.on_finish(r.instance_id, r.req_id)
        return done

    # -------------------------------------------------------------- elasticity
    @property
    def n_instances(self) -> int:
        return len(self.engines)

    def attach_autoscaler(self, autoscaler) -> None:
        """Let ``autoscaler`` drive :meth:`scale_up` / :meth:`scale_down`:
        its ``step(cluster, now)`` runs at the start of every cluster step
        (engines synced — the only point where migration is legal)."""
        self.autoscaler = autoscaler

    def scale_up(self, engine: Optional[LLMEngine] = None,
                 now: Optional[float] = None,
                 role: Optional[str] = None) -> int:
        """Add one instance and start routing to it.  With no ``engine``
        given, the config-derived factory mints one (fresh instance_id,
        cloned compiled fns, private KV pool); ``role`` pins the new
        instance to a disaggregation pool (the autoscaler grows each
        role pool independently).  Returns the instance id."""
        from repro.core.dispatcher import InstanceModel
        if engine is None:
            assert self._engine_factory is not None, \
                "scale_up needs an engine_factory (build the cluster via " \
                "from_config) or an explicit engine"
            if role is None:
                engine = self._engine_factory(max(self._by_id) + 1)
            else:
                engine = self._engine_factory(max(self._by_id) + 1,
                                              role=role)
        iid = engine.instance_id
        assert iid not in self._by_id, f"instance id {iid} already live"
        assert all(engine.runner is not e.runner for e in self.engines), \
            "new engine must own its runner (donated pools are per-instance)"
        self.engines.append(engine)
        self._by_id[iid] = engine
        engine.faults = self.faults
        self.dispatcher.add_instance(
            InstanceModel(iid, engine.kv_capacity_tokens, role=engine.role))
        self._resize_pool()
        if self.tracer.enabled:
            self.tracer.emit("scale-up", instance_id=iid,
                             ts=self.clock() if now is None else now,
                             n=len(self.engines), role=engine.role)
        return iid

    def scale_down(self, instance_id: int,
                   now: Optional[float] = None) -> List[Request]:
        """Retire one instance by DRAINING it through live migration —
        no request loses progress:

        1. its in-flight iteration (if any) is collected first, so
           completions are never dropped;
        2. the instance leaves the dispatcher — no new placements, and
           any OOM fence dies with it (a later :meth:`scale_up` reusing
           the id starts unfenced);
        3. waiting (not-yet-prefilled) requests requeue at the balancer;
        4. running requests live-migrate to surviving instances — every
           request bound for the same target moves in ONE gathered
           donated dispatch (:func:`~repro.serving.migration.migrate_many`;
           continued token streams are bit-identical — see
           ``serving/migration.py``); if no instance can adopt one, it
           falls back to preempt-and-requeue (recompute).

        Returns the requests the step-1 collect finished."""
        from repro.core.dispatcher import role_accepts
        from repro.serving.migration import MigrationError, migrate_many
        assert len(self.engines) > 1, "cannot scale below one instance"
        now = self.clock() if now is None else now
        e = self._by_id[instance_id]
        finished: List[Request] = []
        if e.has_pending:
            finished.extend(self._collect(e, now))
        removed = self.dispatcher.remove_instance(instance_id)
        # releasing/preempting one request can cascade-preempt COW-
        # entangled neighbours from running into waiting, so drain both
        # queues to a fixed point rather than snapshotting either once
        while e.sched.has_work:
            for req in list(e.sched.waiting):
                e.sched.release(req)
                removed.ramps.pop(req.req_id, None)
                self.balancer.enqueue(req)
            if not e.sched.running:
                continue
            req = e.sched.running[0]
            target = self._pick_migration_target(instance_id, req)
            snaps = []
            if target is not None:
                batch = [r for r in e.sched.running
                         if role_accepts(target.role, r)]
                d0 = target.runner.n_dispatches
                try:
                    snaps, _ = migrate_many(e, target, batch, now)
                except MigrationError:
                    snaps = []
                if snaps:
                    self.n_migrations += len(snaps)
                    self.migrated_bytes += sum(s.n_bytes for s in snaps)
                    self.migration_dispatches += \
                        target.runner.n_dispatches - d0
                for s in snaps:
                    self.dispatcher.adopt_ramp(
                        target.instance_id, s.req.req_id,
                        removed.ramps.pop(s.req.req_id, None))
                    if self.tracer.enabled:
                        self.tracer.emit(
                            "migrate-candidate", req_id=s.req.req_id,
                            agent=s.req.agent_name, msg_id=s.req.msg_id,
                            ts=now, to=target.instance_id,
                            reason="scale-down", n_bytes=s.n_bytes)
            if not snaps and req in e.sched.running:
                # nowhere to adopt it: recompute-requeue (progress reset)
                e.sched.preempt(req)
                e.sched.release(req)
                e.drop_pending_token(req.req_id)
                removed.ramps.pop(req.req_id, None)
                self.balancer.enqueue(req)
        assert not e.sched.has_work and not e.has_pending
        self.engines.remove(e)
        del self._by_id[instance_id]
        self._resize_pool()
        if self.tracer.enabled:
            self.tracer.emit("scale-down", instance_id=instance_id, ts=now,
                             n=len(self.engines), role=e.role)
        return finished

    def _pick_migration_target(self, exclude: int,
                               req: Request) -> Optional[LLMEngine]:
        """Best surviving adopter: most free KV blocks wins; fenced
        (recently-OOMed) instances lose ties to unfenced ones.  On a
        role-typed cluster only role-compatible instances qualify (a
        decode-phase request may not land on a prefill instance, a
        mid-prefill one never on a decode instance)."""
        from repro.core.dispatcher import role_accepts
        now = self.clock()
        best, best_key = None, None
        for e in self.engines:
            if e.instance_id == exclude or not role_accepts(e.role, req) \
                    or not e.sched.can_adopt(req):
                continue
            key = (not self.dispatcher.is_fenced(e.instance_id, now),
                   e.bm.free_blocks + e.bm.cached_blocks)
            if best_key is None or key > best_key:
                best, best_key = e, key
        return best

    def discard_engine(self, engine: LLMEngine):
        """Forget a DEAD engine (crash path, called by
        :class:`RecoveryManager`): unlike :meth:`scale_down` nothing is
        collected or migrated — the engine's pool and scheduler state are
        untrusted after a mid-dispatch death; its requests are
        reconstructed from host-side truth instead."""
        assert self.engines != [engine], \
            "every instance crashed — nothing left to recover onto"
        self.engines.remove(engine)
        self._by_id.pop(engine.instance_id, None)
        self._resize_pool()

    def _shed_sweep(self, now: float) -> List[Request]:
        """Overload valve sweep: feed the shedder the SAME queue-depth /
        KV-pressure signals the autoscaler scales on, then shed its
        victims out of the balancer queue."""
        from repro.serving.autoscaler import signals_from_cluster
        sig = signals_from_cluster(self, now)
        max_kv = max((i.kv_used_frac for i in sig.instances), default=0.0)
        if not self.shedder.observe(len(self.balancer.queue),
                                    len(self.engines), max_kv):
            return []
        victims = self.shedder.select(self.balancer.queue, now,
                                      len(self.engines))
        if not victims:
            return []
        depth = len(self.balancer.queue)
        gone = {r.req_id for r in victims}
        self.balancer.queue = [r for r in self.balancer.queue
                               if r.req_id not in gone]
        for r in victims:
            self.shedder.shed(r, now, depth)
        return victims

    def _resize_pool(self):
        """Dispatch workers are one-per-engine; rebuild the pool lazily
        after the engine set changes."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    # ----------------------------------------------------------------- metrics
    @staticmethod
    def metrics_label(e: LLMEngine) -> str:
        """Snapshot prefix for one engine: role-typed instances carry
        their role (``prefill1.*``, ``decode2.*``) so downstream
        attribution (``benchmarks/latency_breakdown.py``) can charge
        queueing to the pool that caused it; general instances keep the
        flat ``engine<i>.`` prefix every committed baseline uses."""
        return (f"engine{e.instance_id}" if e.role == "general"
                else f"{e.role}{e.instance_id}")

    def metrics_snapshot(self) -> dict:
        """The cluster's observable state, flattened to one dict: every
        engine's counters under per-role instance prefixes
        (:meth:`metrics_label`) plus cluster aggregates (``queue_depth``,
        ``n_instances``, ``n_migrations``, ``migrated_bytes``,
        ``migration_dispatches``, and the handoff counters on
        disaggregated clusters).  This is the read side of the public
        contract — autoscaler signals and benchmark reports are derived
        from this snapshot, never from cluster internals."""
        snap = merge_snapshots({self.metrics_label(e): e.metrics_snapshot()
                                for e in self.engines})
        snap["queue_depth"] = float(len(self.balancer.queue))
        snap["n_instances"] = float(len(self.engines))
        snap["n_migrations"] = float(self.n_migrations)
        snap["migrated_bytes"] = float(self.migrated_bytes)
        snap["migration_dispatches"] = float(self.migration_dispatches)
        snap["n_handoffs"] = float(self.n_handoffs)
        snap["handoff_bytes"] = float(self.handoff_bytes)
        snap["handoff_dispatches"] = float(self.handoff_dispatches)
        snap["n_stranded"] = float(self.n_stranded)
        snap["handoff_strand_retries"] = float(self.n_strand_retries)
        for k, v in self.recovery.metrics().items():
            snap[k] = float(v)
        snap["n_shed"] = float(self.shedder.n_shed
                               if self.shedder is not None else 0)
        snap["n_faults_fired"] = float(self.faults.n_fired
                                       if self.faults is not None else 0)
        return snap

    # ------------------------------------------------------------------ drains
    def drain(self, max_steps: int = 100_000,
              idle_sleep: float = 0.0) -> List[Request]:
        """Run the cluster until all submitted work has completed and
        return every finished request.  This is the public
        run-to-completion entry point (the third leg of the
        submit/drain/metrics_snapshot contract); callers that interleave
        submissions with execution use :meth:`step` directly."""
        return self.run_until_drained(max_steps, idle_sleep)

    def run_until_drained(self, max_steps: int = 100_000,
                          idle_sleep: float = 0.0) -> List[Request]:
        """Step until queue + engines are empty; returns all finishers."""
        out: List[Request] = []
        for _ in range(max_steps):
            done = self.step()
            out.extend(done)
            if not self.has_work:
                break
            if not done and idle_sleep:
                time.sleep(idle_sleep)
        return out

    def close(self):
        """Shut down the dispatch worker pool (idempotent).  Long-lived
        owners (a Workflow) keep the cluster open for its lifetime;
        benchmarks building many clusters call this between runs."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
