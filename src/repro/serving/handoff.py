"""Prefill→decode handoff: the disaggregation data path.

A role-typed cluster (``ServingConfig.roles``) splits instances into
``prefill`` engines — chunked prefill only, never growing decode batches
— and ``decode`` engines, which admit work exclusively through
:meth:`BatchScheduler.adopt`.  This module is the bridge between them:
when a prompt's last chunk completes on a prefill instance (the request
flips to :class:`RequestPhase.DECODE` and its first sampled token sits
in the engine's pending-token buffer), the driver moves its resident KV
block-granularly to a decode-capable instance through the migration
layer.

Handoff invariants (all inherited from ``serving/migration.py`` and
CI-gated by ``benchmarks/disagg.py``):

* **One gathered donated dispatch per (source, target) batch** — every
  request handed to the same target in a step shares a single
  ``write_blocks`` call (:func:`migrate_many`); both pool buffers are
  address-witnessed, so neither side ever copies its pool.
* **Token-bit-identity** — the pending first token travels as a plain
  int and the transferred prefix re-registers in the target's cache, so
  the decoded stream equals the colocated run bit for bit.
* **Lossless refusal** — when no decode-capable target can adopt a
  request, it is *stranded*: the prefill instance decodes it colocated
  (:meth:`BatchScheduler.allow_colocated_decode`) and the driver retries
  with exponential backoff, migrating mid-decode once capacity frees up.
  Past ``ServingConfig.handoff_retry_cap`` failed offers the strand is
  *permanent* — a durably full decode pool degrades to colocated decode
  instead of paying a probe per request per sweep forever.

Placement is memory-aware: the most-free decode target wins (dedicated
``decode`` instances preferred over ``general`` ones), OOM-fenced
instances are excluded.  Transfer faults (``serving/faults.py``) and
real ``write_blocks`` failures surface as :class:`MigrationError` from
the migration layer *after lossless rollback* — the sweep skips the
failed target and the requests stay intact on the source.
"""
from __future__ import annotations

from typing import List, Optional

from repro.serving.engine import LLMEngine
from repro.serving.migration import MigrationError, migrate, migrate_many
from repro.serving.request import Request, RequestPhase


class HandoffError(RuntimeError):
    """The request is not in a handoff-able state (still mid-prefill)."""


def handoff(source: LLMEngine, target: LLMEngine, req: Request,
            now: Optional[float] = None):
    """Hand one prefill-complete request from ``source`` to ``target``.

    Thin phase-checked wrapper over :func:`migrate` for callers moving a
    single request; the cluster driver batches per target via
    :func:`migrate_many` instead.  Raises :class:`HandoffError` if the
    prompt is not fully resident, :class:`MigrationError` if the target
    refuses — both before any source state is released."""
    if req.prefilled_len < req.prompt_len:
        raise HandoffError(
            f"req {req.req_id} is mid-prefill "
            f"({req.prefilled_len}/{req.prompt_len} tokens resident)")
    snap = migrate(source, target, req, now)
    req.phase = RequestPhase.DECODE
    return snap


def decode_targets(cluster, source: LLMEngine, now: float) -> List[LLMEngine]:
    """Decode-capable engines able to receive ``source``'s handoffs:
    dedicated ``decode`` instances first, then ``general`` ones, most
    free KV (free + reclaimable cached blocks) first within each class;
    OOM-fenced instances excluded."""
    out = [e for e in cluster.engines
           if e is not source and e.role != "prefill"
           and not cluster.dispatcher.is_fenced(e.instance_id, now)]
    out.sort(key=lambda e: (e.role != "decode",
                            -(e.bm.free_blocks + e.bm.cached_blocks)))
    return out


def drive_handoffs(cluster, now: float) -> dict:
    """One handoff sweep over the cluster's prefill instances.

    Called by ``ServingCluster.step`` after every engine has collected
    (all pools synced — the only legal transfer point).  For each
    prefill instance, every offerable prefill-complete request
    (:meth:`BatchScheduler.handoff_offers` — strand backoff/cap applied)
    is offered to decode-capable targets most-free-first; each (source,
    target) batch costs one gathered donated ``write_blocks`` dispatch.
    A target whose transfer fails (injected fault or real write error)
    is skipped after the migration layer's lossless rollback.  Requests
    no target can take are stranded for colocated decode and re-offered
    with exponential backoff up to ``handoff_retry_cap`` attempts, then
    permanently colocated.  Returns the sweep's accounting (handoffs,
    bytes, dispatches, strandings, strand retries) — the cluster folds
    it into its metrics."""
    stats = {"n_handoffs": 0, "handoff_bytes": 0,
             "handoff_dispatches": 0, "n_stranded": 0,
             "n_strand_retries": 0}
    tracer = cluster.tracer
    cap = (cluster.config.handoff_retry_cap
           if getattr(cluster, "config", None) is not None else 4)
    faults = getattr(cluster, "faults", None)
    for src in cluster.engines:
        if src.role != "prefill":
            continue
        remaining = src.sched.handoff_offers(cap)
        if not remaining:
            continue
        for tgt in decode_targets(cluster, src, now):
            if not remaining:
                break
            d0 = tgt.runner.n_dispatches
            try:
                snaps, remaining = migrate_many(src, tgt, remaining, now,
                                                faults=faults)
            except MigrationError:
                # transfer failed after target allocation: the migration
                # layer rolled everything back onto the source — skip
                # this target, the requests are intact and re-offerable
                continue
            stats["n_handoffs"] += len(snaps)
            stats["handoff_bytes"] += sum(s.n_bytes for s in snaps)
            stats["handoff_dispatches"] += tgt.runner.n_dispatches - d0
            if tracer.enabled:
                for s in snaps:
                    tracer.emit("handoff-start", req_id=s.req.req_id,
                                instance_id=src.instance_id,
                                agent=s.req.agent_name, msg_id=s.req.msg_id,
                                ts=now, to=tgt.instance_id,
                                n_blocks=s.n_blocks, n_bytes=s.n_bytes)
                    tracer.emit("handoff-complete", req_id=s.req.req_id,
                                instance_id=tgt.instance_id,
                                agent=s.req.agent_name, msg_id=s.req.msg_id,
                                ts=now, src=src.instance_id,
                                cached=s.n_cached_blocks)
        for req in remaining:
            # full decode pool (or every target's transfer failed):
            # decode colocated rather than stall — lossless, re-offered
            # with backoff until the retry cap makes the strand final
            fresh = req.req_id not in src.sched.stranded
            permanent = src.sched.note_strand(req, cap)
            if fresh:
                stats["n_stranded"] += 1
                src.sched.allow_colocated_decode(req)
            else:
                stats["n_strand_retries"] += 1
            if tracer.enabled:
                tracer.emit("handoff-strand", req_id=req.req_id,
                            instance_id=src.instance_id,
                            agent=req.agent_name, msg_id=req.msg_id,
                            ts=now,
                            attempts=src.sched.strand_attempts[req.req_id],
                            permanent=permanent)
    return stats


__all__ = ["HandoffError", "MigrationError", "handoff", "decode_targets",
           "drive_handoffs"]
