"""Elastic autoscaling: queue-depth / KV-pressure driven instance count.

Public-cloud serving pays per instance-second, so the paper's excess-load
story has a cost axis: a fixed fleet sized for the burst idles between
bursts, one sized for the trough melts under them (§2).  The
:class:`Autoscaler` closes that loop — it watches two pressure signals

* **queue depth** per instance at the load balancer (work the dispatcher
  could not place), and
* **KV pressure**: each instance's hard-used block fraction (parked
  prefix-cache blocks excluded — they are reclaimable, not pressure),

and adds instances when either stays high, retires one when both stay
low.  Retirement is *lossless*: :meth:`ServingCluster.scale_down` drains
the victim through live migration (``serving/migration.py``), so
scale-down never discards computed KV or generated tokens.  Victim
choice prefers OOM-fenced instances — the dispatcher is already routing
around them, so they are the cheapest capacity to give back (this turns
the long-standing ``migrate-candidate`` trace breadcrumb into real
decisions).

Hysteresis is everywhere, because elasticity that flaps is worse than no
elasticity: up/down each need ``*_patience`` consecutive pressured
decision windows, decisions are rate-limited to ``decision_period_s``,
and any action starts a ``cooldown_s`` freeze.

The decision core (:meth:`Autoscaler.decide`) is pure — it consumes a
:class:`ClusterSignals` value and returns an action — so the real
cluster and the discrete-event simulator share one policy:
:func:`signals_from_cluster` adapts a :class:`ServingCluster`, the
simulator builds its signals from :class:`SimInstance` state.

Role-typed clusters (prefill/decode disaggregation) scale **each role
pool independently**: every decision tick evaluates one
:class:`ClusterSignals` per role, built from that role's instances and
the slice of the balancer queue its role can actually serve
(:func:`repro.core.dispatcher.role_accepts`) — a decode backlog never
mints a prefill instance.  Streak counters are per pool; the policy
bounds (``min_instances``/``max_instances``) apply per pool; the
post-action cooldown freeze is global, so one pool's action cannot
immediately trigger another's.  A flat cluster is one ``general`` pool
and behaves exactly as before.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    """Scaling policy knobs.  Thresholds are per-instance averages
    (queue) / per-instance maxima (KV), so they are fleet-size
    invariant."""
    min_instances: int = 1
    max_instances: int = 4
    queue_high: float = 4.0     # queued-per-instance that signals "add"
    queue_low: float = 0.5      # queued-per-instance that allows "retire"
    kv_high: float = 0.85       # any instance's hard-used block fraction
    kv_low: float = 0.50        # every instance's hard-used block fraction
    up_patience: int = 2        # consecutive pressured windows before up
    down_patience: int = 6      # consecutive calm windows before down
    decision_period_s: float = 0.25
    cooldown_s: float = 1.0     # freeze after any action

    def __post_init__(self):
        assert 1 <= self.min_instances <= self.max_instances
        assert self.queue_low <= self.queue_high
        assert 0.0 < self.kv_low <= self.kv_high <= 1.0
        assert self.up_patience >= 1 and self.down_patience >= 1


@dataclasses.dataclass
class InstanceSignal:
    instance_id: int
    kv_used_frac: float   # hard-used blocks / total blocks
    fenced: bool          # inside its post-OOM dispatch fence
    load: float           # running + waiting requests on the instance


@dataclasses.dataclass
class ClusterSignals:
    now: float
    queue_depth: int      # balancer queue (undispatched work)
    instances: List[InstanceSignal]

    @property
    def n_instances(self) -> int:
        return len(self.instances)


def signals_from_cluster(cluster, now: float,
                         role: Optional[str] = None) -> ClusterSignals:
    """Adapt a live :class:`ServingCluster` to the decision core's
    input.  Reads control-plane state only — no device sync.

    With ``role`` set, the signals are role-split: only that role's
    instances are sampled, and queue depth counts only the queued
    requests the role could serve (``role_accepts``), so each pool
    scales from the pressure it is responsible for."""
    from repro.core.dispatcher import role_accepts
    inst = []
    for e in cluster.engines:
        if role is not None and e.role != role:
            continue
        inst.append(InstanceSignal(
            instance_id=e.instance_id,
            kv_used_frac=e.bm.hard_used_blocks / e.bm.num_blocks,
            fenced=cluster.dispatcher.is_fenced(e.instance_id, now),
            load=len(e.sched.running) + len(e.sched.waiting)))
    if role is None:
        depth = len(cluster.balancer.queue)
    else:
        depth = sum(1 for r in cluster.balancer.queue if role_accepts(role, r))
    return ClusterSignals(now=now, queue_depth=depth, instances=inst)


class Autoscaler:
    """Stateful wrapper around the pure decision core.

    ``step(cluster, now)`` is called by the cluster at the start of every
    step (see :meth:`ServingCluster.attach_autoscaler`); it samples
    signals, decides, and applies scale_up/scale_down.  Returns any
    requests finished by a scale-down's final collect so the cluster's
    step can surface them.  ``history`` records every action as
    ``(t, "up"|"down", instance_id, n_instances_after)`` for tests and
    benchmark reports.
    """

    def __init__(self, config: AutoscalerConfig = AutoscalerConfig()):
        self.cfg = config
        self._up_streaks: Dict[str, int] = {}
        self._down_streaks: Dict[str, int] = {}
        self._next_decision = float("-inf")
        self._frozen_until = float("-inf")
        self.history: List[Tuple[float, str, int, int]] = []

    # ------------------------------------------------------------- decision
    def decide(self, sig: ClusterSignals,
               role: str = "general") -> Optional[Tuple[str, int]]:
        """Pure policy: ``("up", -1)``, ``("down", victim_id)``, or None.

        Call once per decision window per role pool (the caller owns the
        cadence and passes role-split signals); the per-pool streak
        counters live here so both the real and simulated control planes
        get identical hysteresis.  Flat callers omit ``role`` and get the
        single ``general`` pool."""
        cfg = self.cfg
        n = sig.n_instances
        queue_per_inst = sig.queue_depth / max(1, n)
        kv_max = max((i.kv_used_frac for i in sig.instances), default=0.0)
        pressured = (queue_per_inst >= cfg.queue_high
                     or kv_max >= cfg.kv_high)
        calm = (queue_per_inst <= cfg.queue_low and kv_max <= cfg.kv_low)
        self._up_streaks[role] = \
            self._up_streaks.get(role, 0) + 1 if pressured else 0
        self._down_streaks[role] = \
            self._down_streaks.get(role, 0) + 1 if calm else 0
        if sig.now < self._frozen_until:
            return None
        if (pressured and n < cfg.max_instances
                and self._up_streaks[role] >= cfg.up_patience):
            return ("up", -1)
        if (calm and n > cfg.min_instances
                and self._down_streaks[role] >= cfg.down_patience):
            return ("down", self.pick_victim(sig))
        return None

    @staticmethod
    def pick_victim(sig: ClusterSignals) -> int:
        """Scale-down victim: OOM-fenced first (the dispatcher already
        routes around them), then least loaded — fewest requests to
        migrate, fewest KV bytes to move."""
        return min(sig.instances,
                   key=lambda i: (not i.fenced, i.load, i.kv_used_frac,
                                  i.instance_id)).instance_id

    # ------------------------------------------------------------ real path
    @staticmethod
    def role_pools(cluster) -> List[str]:
        """The role pools to scale, in step order.  A flat cluster is
        the single ``general`` pool."""
        roles = {e.role for e in cluster.engines}
        return [r for r in ("prefill", "decode", "general")
                if r in roles] or ["general"]

    def step(self, cluster, now: float) -> list:
        """One control-plane tick against a real cluster: each role pool
        decides from its own signals.  The global cooldown means at most
        one pool acts per tick."""
        if now < self._next_decision:
            return []
        self._next_decision = now + self.cfg.decision_period_s
        pools = self.role_pools(cluster)
        split = pools != ["general"]   # role-typed topology present
        finished: list = []
        for role in pools:
            sig = signals_from_cluster(cluster, now,
                                       role=role if split else None)
            action = self.decide(sig, role=role)
            if action is None:
                continue
            kind, victim = action
            if kind == "up":
                iid = cluster.scale_up(now=now,
                                       role=role if split else None)
                self.history.append((now, "up", iid, cluster.n_instances))
            else:
                finished.extend(cluster.scale_down(victim, now))
                self.history.append((now, "down", victim,
                                     cluster.n_instances))
            self._frozen_until = now + self.cfg.cooldown_s
            self._up_streaks.clear()
            self._down_streaks.clear()
        return finished

    def note_action(self, now: float, kind: str, instance_id: int,
                    n_after: int):
        """Record an externally-applied action (the simulator applies
        decisions itself) and start the cooldown, keeping hysteresis
        identical across both control planes."""
        self.history.append((now, kind, instance_id, n_after))
        self._frozen_until = now + self.cfg.cooldown_s
        self._up_streaks.clear()
        self._down_streaks.clear()
