"""Deterministic fault injection: the chaos half of the fault plane.

Kairos serves from the *public cloud* (§2), where instances stall, OOM,
and die mid-decode.  This module turns those failure modes into a
seeded, replayable :class:`FaultPlan`: a list of :class:`FaultSpec`
events pinned to **(instance, per-instance iteration ordinal)** points,
so the same plan fires at the same logical moment in the real
:class:`~repro.serving.cluster.ServingCluster` and in the discrete-event
:class:`~repro.sim.simulator.Simulation` — and twice in a row in either.

Fault kinds:

``crash``     the instance dies mid-``dispatch_iteration`` (worker-thread
              exception).  Scheduler state may be half-mutated; the pool
              is untrusted.  Recovery (``recovery.py``) must reconstruct
              every in-flight request from prompt + already-emitted
              tokens.
``straggle``  one step runs slow: the real path sleeps ``delay_s`` inside
              the dispatch, the sim multiplies the step's ``dt`` by
              ``factor``.  Step-deadline detection fences the instance.
``oom``       a forced allocation-pressure signal: ``recent_oom`` is set
              so the existing ``poll_oom`` -> dispatcher fence path fires
              without any real allocation failing.  Plans can emit runs
              of consecutive ooms (a "storm").
``transfer``  the Nth KV transfer *out of* an instance fails after the
              target has allocated (the worst point): ``migrate_many`` /
              ``handoff`` must refuse losslessly (satellite: rollback).

A :class:`FaultInjector` consumes one plan for one run; it owns the
per-instance ordinal counters so engines and sim instances only need to
call :meth:`FaultInjector.on_dispatch` / :meth:`transfer_fault`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.trace import NULL_TRACER, Tracer

FAULT_KINDS = ("crash", "straggle", "oom", "transfer")


class InstanceCrashed(RuntimeError):
    """An injected (or real) worker death surfaced from
    ``dispatch_iteration``.  The cluster's step loop catches this and
    hands the engine to :class:`~repro.serving.recovery.RecoveryManager`."""

    def __init__(self, instance_id: int, step: int):
        super().__init__(
            f"instance {instance_id} crashed at iteration {step}")
        self.instance_id = instance_id
        self.step = step


class TransferFault(RuntimeError):
    """An injected KV-transfer failure.  Raised *inside* the guarded
    region of ``migrate_many``/``restore_request`` — i.e. after target
    allocation — so the rollback path is what gets exercised."""

    def __init__(self, source_id: int, ordinal: int):
        super().__init__(
            f"transfer {ordinal} out of instance {source_id} failed")
        self.source_id = source_id
        self.ordinal = ordinal


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault.  ``step`` is the per-instance *dispatch ordinal*
    (0-based count of composed iterations) for crash/straggle/oom, and
    the per-instance *outbound-transfer ordinal* for transfer faults —
    both deterministic under deterministic scheduling, which is what
    makes a plan replayable."""
    kind: str
    instance_id: int
    step: int
    delay_s: float = 0.0    # straggle: real-path sleep inside the dispatch
    factor: float = 1.0     # straggle: sim step-time multiplier

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, f"unknown fault kind {self.kind!r}"
        assert self.step >= 0


@dataclasses.dataclass(frozen=True)
class DispatchEffects:
    """What :meth:`FaultInjector.on_dispatch` resolved for one iteration.
    The caller applies them (the injector stays side-effect-free towards
    engine state): set ``recent_oom``, sleep/stretch, then raise
    :class:`InstanceCrashed` last so the other effects land first."""
    crash: Optional[FaultSpec] = None
    delay_s: float = 0.0
    factor: float = 1.0
    oom: bool = False


_NO_EFFECTS = DispatchEffects()


class FaultPlan:
    """An immutable, ordered set of :class:`FaultSpec`\\ s.

    Either hand-built (``FaultPlan([FaultSpec(...), ...])``) for targeted
    tests, or sampled with :meth:`generate` from a seed — the generator
    is pure ``numpy.random.default_rng(seed)``, so a (seed, shape) pair
    names the same chaos everywhere.
    """

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        for s in self.specs:
            assert isinstance(s, FaultSpec)

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)

    def crashes(self) -> List[FaultSpec]:
        return [s for s in self.specs if s.kind == "crash"]

    @classmethod
    def generate(cls, seed: int, instance_ids: Sequence[int], *,
                 horizon: int = 32,
                 n_crashes: int = 1,
                 n_straggles: int = 0,
                 n_ooms: int = 0,
                 n_transfer_faults: int = 0,
                 spare: Sequence[int] = (),
                 straggle_delay_s: float = 0.05,
                 straggle_factor: float = 4.0) -> "FaultPlan":
        """Sample a plan.  ``spare`` instances are exempt from crashes
        (a chaos drain that kills *every* instance has no survivors to
        recover onto); stragglers/ooms/transfer faults may hit anyone.
        At most one crash per instance — dead instances don't die twice.
        """
        rng = np.random.default_rng(seed)
        ids = list(instance_ids)
        crashable = [i for i in ids if i not in set(spare)]
        specs: List[FaultSpec] = []
        n_crashes = min(n_crashes, len(crashable))
        victims = rng.choice(len(crashable), size=n_crashes,
                             replace=False) if n_crashes else []
        for v in victims:
            specs.append(FaultSpec("crash", crashable[int(v)],
                                   int(rng.integers(1, max(2, horizon)))))
        for _ in range(n_straggles):
            specs.append(FaultSpec("straggle", ids[int(rng.integers(len(ids)))],
                                   int(rng.integers(0, max(1, horizon))),
                                   delay_s=straggle_delay_s,
                                   factor=straggle_factor))
        for _ in range(n_ooms):
            specs.append(FaultSpec("oom", ids[int(rng.integers(len(ids)))],
                                   int(rng.integers(0, max(1, horizon)))))
        for _ in range(n_transfer_faults):
            specs.append(FaultSpec("transfer",
                                   ids[int(rng.integers(len(ids)))],
                                   int(rng.integers(0, 4))))
        return cls(specs)


class FaultInjector:
    """Consumes one :class:`FaultPlan` over one run.

    Owns the deterministic per-instance ordinal counters so call sites
    stay one-liners.  A fresh injector over the same plan replays the
    same faults — construct one per run, never share across runs.
    """

    def __init__(self, plan: FaultPlan, tracer: Tracer = NULL_TRACER):
        self.plan = plan
        self.tracer = tracer
        self._dispatch_ord: Dict[int, int] = {}
        self._transfer_ord: Dict[int, int] = {}
        # (kind, instance, step) -> list of yet-unfired specs
        self._pending: Dict[Tuple[str, int, int], List[FaultSpec]] = {}
        for s in plan:
            self._pending.setdefault(
                (s.kind, s.instance_id, s.step), []).append(s)
        self.n_fired = 0

    # ------------------------------------------------------------ helpers
    def _take(self, kind: str, instance_id: int, step: int,
              now: Optional[float]) -> List[FaultSpec]:
        fired = self._pending.pop((kind, instance_id, step), [])
        for s in fired:
            self.n_fired += 1
            if self.tracer.enabled:
                self.tracer.emit("fault-injected", instance_id=instance_id,
                                 ts=now, fault=s.kind, step=s.step)
        return fired

    # ----------------------------------------------------------- surfaces
    def on_dispatch(self, instance_id: int,
                    now: Optional[float] = None) -> DispatchEffects:
        """Advance this instance's dispatch ordinal and resolve any
        faults planned for it.  Called once per *composed* iteration
        (idle steps don't count — they don't exist in the sim)."""
        step = self._dispatch_ord.get(instance_id, 0)
        self._dispatch_ord[instance_id] = step + 1
        if not self._pending:
            return _NO_EFFECTS
        crash = self._take("crash", instance_id, step, now)
        straggles = self._take("straggle", instance_id, step, now)
        ooms = self._take("oom", instance_id, step, now)
        if not (crash or straggles or ooms):
            return _NO_EFFECTS
        return DispatchEffects(
            crash=crash[0] if crash else None,
            delay_s=sum(s.delay_s for s in straggles),
            factor=float(np.prod([s.factor for s in straggles]))
            if straggles else 1.0,
            oom=bool(ooms))

    def transfer_fault(self, source_id: int,
                       now: Optional[float] = None) -> Optional[FaultSpec]:
        """Advance the outbound-transfer ordinal for ``source_id`` and
        return the planned fault, if any.  The *caller* raises
        :class:`TransferFault` from inside its guarded region."""
        ordinal = self._transfer_ord.get(source_id, 0)
        self._transfer_ord[source_id] = ordinal + 1
        fired = self._take("transfer", source_id, ordinal, now)
        return fired[0] if fired else None
