"""Instance-failure recovery and SLO-aware load shedding.

The lossless half of the fault plane (``faults.py`` is the chaos half).
Three pieces:

**Detection** — a crash surfaces as :class:`InstanceCrashed` from the
engine's dispatch worker; the cluster's step loop catches it at the
synced post-collect point (the only moment pools are legal to touch) and
hands the engine here.  Stragglers are caught by a per-engine *step
deadline*: an engine whose dispatch+sync wall time exceeds
``step_deadline_s`` is fenced through the existing dispatcher OOM-fence
machinery (routed around for a cooldown, not killed).

**Reconstruction with bit-identical replay** — a dead engine's pool is
untrusted, so its RUNNING/WAITING requests cannot be migrated out; they
are *reconstructed*: progress is reset (as recompute-preemption already
does) and the request re-queued with **prompt + already-emitted tokens**
as its new prompt.  Because decoding is argmax-only and prefill(prompt +
emitted) builds the same KV state as the original decode path, the
continuation tokens are bit-identical; the emitted prefix is re-emitted
verbatim at finish.  Where the original prompt's block hashes survive in
a surviving instance's prefix cache, the re-prefill is served from cache
(the hash chain of an unchanged prefix is unchanged).  Every crash a
request survives burns one unit of its retry budget; past the budget it
surfaces as ``RequestState.FAILED`` (after exponential backoff between
attempts) instead of looping forever.

**Graceful degradation** — :class:`LoadShedder` is the overload valve:
under *sustained* overload (queue-depth + KV-pressure, the same signals
the autoscaler reads) it sheds the queued requests least likely to meet
their deadline instead of letting p99 collapse for everyone.  Service
time is priced by the :class:`~repro.sim.cost_model.CostModel`, so the
real cluster and the sim shed by the same rule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.request import Request, RequestPhase, RequestState


@dataclasses.dataclass
class RecoveryRecord:
    """Original identity of a reconstructed request, kept until finish so
    the extended prompt can be unwound and the replayed tokens re-emitted.
    ``replay`` accumulates across repeated crashes (a request that dies
    twice replays everything it had ever emitted)."""
    orig_prompt_tokens: object
    orig_prompt_len: int
    orig_max_new: int
    replay: List[int] = dataclasses.field(default_factory=list)
    retries: int = 0
    ready_at: float = 0.0


class RecoveryManager:
    """Failure detection + lossless request reconstruction for one
    :class:`~repro.serving.cluster.ServingCluster`."""

    def __init__(self, *, max_retries: int = 3, backoff_s: float = 0.0,
                 step_deadline_s: Optional[float] = None,
                 tracer: Tracer = NULL_TRACER):
        assert max_retries >= 0
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.step_deadline_s = step_deadline_s
        self.tracer = tracer
        self._records: Dict[int, RecoveryRecord] = {}
        self._backoff: List[Tuple[float, Request]] = []
        # counters (surfaced via metrics())
        self.n_crashes = 0
        self.n_reconstructed = 0
        self.n_failed = 0
        self.n_replayed_tokens = 0
        self.n_straggler_fences = 0

    # ------------------------------------------------------------- detection
    def check_step_deadline(self, cluster, engine, elapsed_s: float,
                            now: float) -> bool:
        """Post-collect heartbeat: fence an engine whose step blew the
        deadline (straggler).  Fencing reuses the dispatcher's OOM-fence
        — the balancer routes around it until the cooldown expires."""
        if self.step_deadline_s is None or elapsed_s <= self.step_deadline_s:
            return False
        if self.tracer.enabled:
            self.tracer.emit("failure-detected",
                             instance_id=engine.instance_id, ts=now,
                             reason="straggler", elapsed_s=elapsed_s)
        cluster.dispatcher.on_oom(engine.instance_id, now)
        self.n_straggler_fences += 1
        return True

    # -------------------------------------------------------------- recovery
    def on_crash(self, cluster, engine, now: float) -> List[Request]:
        """Handle a dead engine: permanently fence + remove it through
        the dispatcher machinery, reconstruct its in-flight requests,
        and return the ones whose retry budget is spent (surfaced as
        FAILED so drivers unblock)."""
        iid = engine.instance_id
        victims = list(engine.sched.waiting) + list(engine.sched.running)
        self.n_crashes += 1
        if self.tracer.enabled:
            self.tracer.emit("failure-detected", instance_id=iid, ts=now,
                             reason="crash", n_lost=len(victims))
        # Fence first (emits the standard oom-fence event), then remove:
        # removal is what makes the fence permanent — the instance model
        # is gone from every dispatcher map, so nothing routes to it.
        try:
            cluster.dispatcher.on_oom(iid, now)
        except KeyError:  # pragma: no cover - already removed
            pass
        removed = cluster.dispatcher.remove_instance(iid)
        removed.fenced_until = float("inf")
        cluster.discard_engine(engine)
        failed: List[Request] = []
        for req in victims:
            rec = self._records.get(req.req_id)
            if rec is None:
                rec = RecoveryRecord(req.prompt_tokens, req.prompt_len,
                                     req.max_new_tokens)
                self._records[req.req_id] = rec
            rec.retries += 1
            if rec.retries > self.max_retries:
                self._records.pop(req.req_id, None)
                req.state = RequestState.FAILED
                req.finish_time = now
                req.instance_id = -1
                self.n_failed += 1
                failed.append(req)
                continue
            self._reconstruct(req, rec, now)
            delay = self.backoff_s * (2.0 ** (rec.retries - 1))
            if delay > 0.0:
                rec.ready_at = now + delay
                self._backoff.append((rec.ready_at, req))
            else:
                cluster.balancer.enqueue(req)
        return failed

    def _reconstruct(self, req: Request, rec: RecoveryRecord, now: float):
        """Reset progress (recompute-preemption semantics) and extend the
        prompt with everything emitted so far; the argmax decode path
        then replays the stream bit-identically."""
        emitted = [int(t) for t in req.output_tokens]
        rec.replay.extend(emitted)
        self.n_replayed_tokens += len(emitted)
        self.n_reconstructed += 1
        req.output_tokens.clear()
        req.output_len = 0
        req.prefilled_len = 0
        req.cached_prefix_len = 0
        req.phase = RequestPhase.PREFILL
        req.first_token_time = -1.0
        req.state = RequestState.QUEUED
        req.instance_id = -1
        if rec.replay:
            base = np.asarray(rec.orig_prompt_tokens)
            req.prompt_tokens = np.concatenate(
                [base, np.asarray(rec.replay, dtype=base.dtype)])
            req.prompt_len = rec.orig_prompt_len + len(rec.replay)
            req.max_new_tokens = rec.orig_max_new - len(rec.replay)
            assert req.max_new_tokens >= 1
            # the prompt changed past orig_prompt_len: the memoized hash
            # chain is stale, but the *shared* original-prefix hashes are
            # unchanged — surviving caches serve them on re-prefill
            req.prefix_hashes = None
        if self.tracer.enabled:
            self.tracer.emit("recovery-replay", req_id=req.req_id, ts=now,
                             agent=req.agent_name, msg_id=req.msg_id,
                             replayed=len(rec.replay), retry=rec.retries)

    # ------------------------------------------------------------- lifecycle
    def tick(self, cluster, now: float):
        """Release backed-off reconstructions whose timers expired."""
        if not self._backoff:
            return
        due = [r for t, r in self._backoff if t <= now]
        if not due:
            return
        self._backoff = [(t, r) for t, r in self._backoff if t > now]
        for req in due:
            cluster.balancer.enqueue(req)

    def on_finish(self, req: Request):
        """Unwind a recovered request at finish: re-emit the replayed
        prefix verbatim and restore the original prompt identity (the
        CompletionRecord and every downstream consumer see the request
        exactly as if no crash had happened)."""
        rec = self._records.pop(req.req_id, None)
        if rec is None or not rec.replay:
            return
        req.output_tokens[:0] = rec.replay
        req.output_len = len(req.output_tokens)
        req.prompt_tokens = rec.orig_prompt_tokens
        req.prompt_len = rec.orig_prompt_len
        req.max_new_tokens = rec.orig_max_new
        req.prefix_hashes = None

    @property
    def pending(self) -> int:
        """Reconstructed requests still waiting out their backoff —
        drain loops must not exit while these exist."""
        return len(self._backoff)

    @property
    def backoff_deadlines(self) -> List[float]:
        """When each backed-off reconstruction becomes re-queueable
        (event-driven callers arm a timer per deadline)."""
        return [t for t, _ in self._backoff]

    def metrics(self) -> Dict[str, float]:
        return {
            "n_crashes": self.n_crashes,
            "n_reconstructed": self.n_reconstructed,
            "n_recovery_failed": self.n_failed,
            "n_replayed_tokens": self.n_replayed_tokens,
            "n_straggler_fences": self.n_straggler_fences,
            "recovery_backlog": len(self._backoff),
        }


class LoadShedder:
    """The overload valve (graceful degradation).

    Opens only under *sustained* overload — ``patience`` consecutive
    sweeps where balancer queue depth exceeds ``queue_high`` per instance
    or KV pressure exceeds ``kv_high`` with a non-empty queue (the same
    queue-depth/KV signals the autoscaler scales on).  Once open it
    sheds, deterministically:

    1. every queued request whose deadline is unreachable even if
       dispatched immediately (``now + service_time > arrival + slo``) —
       these can only waste capacity others could use;
    2. if the queue still overflows the valve line, the lowest-slack
       requests down to the line — the ones least likely to make it.

    ``service_time`` is priced by the :class:`CostModel`'s steady-state
    decode rate, so sim and real shed by one rule.
    """

    def __init__(self, *, slo_e2e_s: float, cost,
                 queue_high: float = 8.0, kv_high: float = 0.97,
                 patience: int = 3, tracer: Tracer = NULL_TRACER):
        assert slo_e2e_s > 0 and patience >= 1
        self.slo_e2e_s = slo_e2e_s
        self.cost = cost
        self.queue_high = queue_high
        self.kv_high = kv_high
        self.patience = patience
        self.tracer = tracer
        self._streak = 0
        self.n_shed = 0

    # ------------------------------------------------------------- estimates
    def service_time(self, req: Request) -> float:
        """Best-case remaining service time if dispatched right now:
        one prefill pass + steady-state decode of the full budget."""
        rate = self.cost.decode_tok_per_s()
        prefill = self.cost.iteration_time(
            n_decode=0, prefill_tokens=max(0, req.prompt_len),
            cached_tokens=0, n_prefill_seqs=1)
        return prefill + req.max_new_tokens / rate

    def slack(self, req: Request, now: float) -> float:
        return (req.arrival_time + self.slo_e2e_s) - (
            now + self.service_time(req))

    @property
    def open(self) -> bool:
        return self._streak >= self.patience

    # ----------------------------------------------------------------- sweep
    def observe(self, queue_depth: int, n_instances: int,
                max_kv_frac: float) -> bool:
        """Advance the sustained-overload streak; returns valve state."""
        line = self.queue_high * max(1, n_instances)
        overloaded = queue_depth > line or (
            queue_depth > 0 and max_kv_frac >= self.kv_high)
        self._streak = self._streak + 1 if overloaded else 0
        return self.open

    def select(self, queue: List[Request], now: float,
               n_instances: int) -> List[Request]:
        """Pick victims from an open valve's queue (pure; the caller
        removes them, marks them SHED, and surfaces them)."""
        if not self.open or not queue:
            return []
        victims = [r for r in queue if self.slack(r, now) < 0.0]
        chosen = {r.req_id for r in victims}
        line = int(self.queue_high * max(1, n_instances))
        rest = [r for r in queue if r.req_id not in chosen]
        overflow = len(rest) - line
        if overflow > 0:
            rest.sort(key=lambda r: (self.slack(r, now), r.req_id))
            victims.extend(rest[:overflow])
        return victims

    def shed(self, req: Request, now: float, queue_depth: int):
        """Book one shed request (state flip + trace + counter)."""
        req.state = RequestState.SHED
        req.finish_time = now
        self.n_shed += 1
        if self.tracer.enabled:
            self.tracer.emit("shed", req_id=req.req_id, ts=now,
                             agent=req.agent_name, msg_id=req.msg_id,
                             slack=self.slack(req, now), queued=queue_depth)
