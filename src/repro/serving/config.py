"""Unified serving configuration: ONE dataclass for every serving layer.

Before this module, the serving knobs (blocks, batch, prefix caching,
chunked prefill, fused iteration, pool donation, ragged backend, policy,
tracing, tensor parallelism) threaded through five drifting constructor
kwarg lists — ``PagedModelRunner``, ``LLMEngine``, ``ServingCluster``,
``Workflow``, and the simulator's ``SimConfig`` each re-declared a
subset, and an elastic cluster (instances created at runtime by the
autoscaler) had no single description of "an instance like the others"
to build from.

:class:`ServingConfig` is that single source of truth:

* ``PagedModelRunner.from_config`` / ``LLMEngine.from_config`` /
  ``ServingCluster.from_config`` consume it on the real path;
* ``SimConfig.from_serving_config`` maps it onto the discrete-event
  simulator (``SIM_FIELD_MAP`` below documents the field-for-field
  correspondence; ``tests/test_serving_config.py`` asserts the map is
  total, so a knob added to one side cannot silently not exist on the
  other);
* legacy per-class kwargs are gone: ``Workflow(num_blocks=...)`` raises
  ``TypeError`` pointing here (the one-release deprecation shim was
  removed after PR 8).

Role-typed topology (prefill/decode disaggregation) lives here too:
``roles`` assigns each instance a role — ``"prefill"`` instances run
chunked prefill only and hand finished prompts off, ``"decode"``
instances admit only handed-off requests, ``"general"`` instances do
both (the pre-disaggregation behaviour, and the default).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


# ServingConfig field -> how the simulator consumes it.  Either the name
# of the SimConfig field it maps onto, or a "->field" note for derived
# values.  tests/test_serving_config.py asserts this map covers EVERY
# ServingConfig field and that every plain target is a real SimConfig
# field — real<->sim parity is enforced, not aspirational.
SIM_FIELD_MAP = {
    "num_blocks": "->kv_capacity_tokens",   # num_blocks * block_size
    "block_size": "block_size",
    "max_batch": "max_batch",
    "prefix_caching": "prefix_caching",
    "prefill_chunk_tokens": "prefill_chunk_tokens",
    "fused_iteration": "fused_iteration",
    "donate_pool": "donate_pool",
    "ragged_backend": "->ragged_native",    # native unless a flat lowering
    "policy": "policy",                     # "fcfs" -> "w/o-priority"
    "tracing": "tracing",
    "model_parallel": "tp_degree",
    "n_instances": "n_instances",
    "roles": "roles",
    # -- fault plane (faults.py / recovery.py) --
    "llm_retries": "llm_retries",           # driver-level; carried for parity
    "llm_backoff_s": "llm_backoff_s",       # (sim virtual time can't stall)
    "recovery_retries": "recovery_retries",
    "recovery_backoff_s": "recovery_backoff_s",
    "step_deadline_s": "step_deadline_s",   # real wall-clock; sim carries it
    "slo_e2e_s": "slo_e2e_s",
    "shed_queue_high": "shed_queue_high",
    "shed_kv_high": "shed_kv_high",
    "shed_patience": "shed_patience",
    "handoff_retry_cap": "handoff_retry_cap",
}

ROLES = ("prefill", "decode", "general")


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Every serving-layer knob, in one place.

    ``policy`` names the scheduling policy: ``"kairos"`` (orchestrator-
    backed priorities at the balancer AND inside each instance) or
    ``"fcfs"`` (arrival order everywhere).  The simulator additionally
    accepts its baseline policy strings (``"parrot"``, ``"ayo"``, ...)
    passed through verbatim by :meth:`SimConfig.from_serving_config`.

    ``model_parallel`` is the tensor-parallel degree of each instance
    (1 = unsharded); the mesh itself is built by the launcher
    (``ServingCluster.from_config`` / ``on_mesh_slices``), not stored
    here — a config must stay picklable and device-free.
    """

    # -- KV memory ----------------------------------------------------------
    num_blocks: int = 128
    block_size: int = 8
    # -- batching -----------------------------------------------------------
    max_batch: int = 8
    prefill_chunk_tokens: Optional[int] = None
    # -- features -----------------------------------------------------------
    prefix_caching: bool = False
    fused_iteration: bool = True
    donate_pool: bool = True
    ragged_backend: Optional[str] = None   # None = runner backend default
    # -- policy / observability --------------------------------------------
    policy: str = "kairos"
    tracing: bool = False
    # -- topology -----------------------------------------------------------
    model_parallel: int = 1
    n_instances: int = 1
    # roles[i] is instance i's role ("prefill"/"decode"/"general"); None
    # means every instance is "general" — the flat, pre-disaggregation
    # cluster.  A topology with any "prefill" instance must contain a
    # decode-capable one ("decode" or "general") to hand off to.
    roles: Optional[tuple] = None
    # -- fault tolerance (serving/faults.py, serving/recovery.py) -----------
    llm_retries: int = 0                 # Workflow._llm_call TimeoutError
    llm_backoff_s: float = 0.5           # retries + capped exp. backoff
    recovery_retries: int = 3            # crashes a request may survive
    recovery_backoff_s: float = 0.0      # exp. backoff between replays (s)
    step_deadline_s: Optional[float] = None  # straggler fence threshold (s)
    # -- overload shedding (recovery.LoadShedder; None = valve disabled) ----
    slo_e2e_s: Optional[float] = None    # per-request e2e deadline
    shed_queue_high: float = 8.0         # queued-per-instance overload line
    shed_kv_high: float = 0.97           # KV-pressure overload line
    shed_patience: int = 3               # sustained sweeps before valve opens
    # -- disaggregation strand control --------------------------------------
    handoff_retry_cap: int = 4           # probes before permanent colocation

    def __post_init__(self):
        assert self.num_blocks > 0 and self.block_size > 0
        assert self.max_batch > 0 and self.n_instances > 0
        assert self.model_parallel >= 1
        assert (self.prefill_chunk_tokens is None
                or self.prefill_chunk_tokens > 0)
        assert self.llm_retries >= 0 and self.llm_backoff_s >= 0.0
        assert self.recovery_retries >= 0 and self.recovery_backoff_s >= 0.0
        assert self.step_deadline_s is None or self.step_deadline_s > 0.0
        assert self.slo_e2e_s is None or self.slo_e2e_s > 0.0
        assert self.shed_queue_high > 0 and self.shed_patience >= 1
        assert 0.0 < self.shed_kv_high <= 1.0
        assert self.handoff_retry_cap >= 0
        if self.roles is not None:
            # normalize list -> tuple so the frozen config stays hashable
            object.__setattr__(self, "roles", tuple(self.roles))
            assert len(self.roles) == self.n_instances, \
                f"roles {self.roles} must name all {self.n_instances} instances"
            bad = [r for r in self.roles if r not in ROLES]
            assert not bad, f"unknown roles {bad}; choose from {ROLES}"
            if "prefill" in self.roles:
                assert any(r in ("decode", "general") for r in self.roles), \
                    "prefill instances need a decode-capable handoff target"

    # ------------------------------------------------------------- derived
    @property
    def kv_capacity_tokens(self) -> int:
        return self.num_blocks * self.block_size

    def role_of(self, instance_id: int) -> str:
        """Role of instance ``instance_id`` ("general" on flat clusters,
        and for autoscaled instances minted past the declared topology)."""
        if self.roles is None or instance_id >= len(self.roles):
            return "general"
        return self.roles[instance_id]

    @property
    def disaggregated(self) -> bool:
        return self.roles is not None and "prefill" in self.roles

    @property
    def ragged_native(self) -> bool:
        """Whether the configured ragged lowering is the native
        segment-tiled kernel (the flat lowerings re-gather padded
        context; the sim prices the difference)."""
        return not str(self.ragged_backend or "").startswith("flat")

    # ----------------------------------------------------- consumer kwargs
    def runner_kwargs(self) -> dict:
        """Constructor kwargs for :class:`PagedModelRunner` (the mesh, if
        any, is supplied by the caller — it is placement, not config)."""
        return dict(num_blocks=self.num_blocks, block_size=self.block_size,
                    max_batch=self.max_batch,
                    ragged_backend=self.ragged_backend,
                    donate_pool=self.donate_pool)

    def engine_kwargs(self) -> dict:
        """Constructor kwargs for :class:`LLMEngine` (identity, clock,
        policy object, and tracer are per-engine runtime wiring)."""
        return dict(max_batch=self.max_batch,
                    enable_prefix_cache=self.prefix_caching,
                    prefill_chunk_tokens=self.prefill_chunk_tokens,
                    fused_iteration=self.fused_iteration)

    def make_policy(self, orchestrator):
        """Instantiate the scheduling policy object for the real path
        (None = FCFS default for non-kairos policies; the sim's baseline
        policies are constructed by ``Simulation._make_policy``)."""
        from repro.core.scheduler import KairosScheduler
        if self.policy == "kairos":
            return KairosScheduler(orchestrator.priority_score)
        return None

    @property
    def sim_policy(self) -> str:
        """The simulator's name for this policy: the real path's "fcfs"
        (FCFS queue + memory-aware dispatch) is the sim's
        "w/o-priority"; everything else passes through."""
        return "w/o-priority" if self.policy == "fcfs" else self.policy
