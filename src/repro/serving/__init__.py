from repro.serving.autoscaler import (
    Autoscaler,
    AutoscalerConfig,
    ClusterSignals,
    InstanceSignal,
    signals_from_cluster,
)
from repro.serving.batch_scheduler import (
    BatchScheduler,
    IterationBatch,
    IterationPlan,
    KeyPrefixMatcher,
    PrefillChunk,
    SchedStats,
    Segment,
    TokenPrefixMatcher,
    flatten_plan,
    pad_bucket,
)
from repro.serving.config import SIM_FIELD_MAP, ServingConfig
from repro.serving.engine import (
    LLMEngine,
    PagedModelRunner,
    TokenBuffer,
    TokenRef,
)
from repro.serving.cluster import ServingCluster
from repro.serving.kv_cache import BlockManager, NoFreeBlocks
from repro.serving.migration import (
    MigrationError,
    RequestSnapshot,
    migrate,
    restore_request,
    snapshot_request,
)
from repro.serving.prefix_cache import PrefixCache, PrefixCacheStats
from repro.serving.request import (
    CompletionRecord,
    Request,
    RequestState,
    reset_request_ids,
)

__all__ = ["BatchScheduler", "IterationBatch", "IterationPlan",
           "KeyPrefixMatcher", "PrefillChunk", "SchedStats", "Segment",
           "TokenPrefixMatcher", "flatten_plan", "pad_bucket",
           "LLMEngine", "PagedModelRunner", "ServingCluster",
           "TokenBuffer", "TokenRef", "BlockManager", "NoFreeBlocks",
           "PrefixCache", "PrefixCacheStats",
           "CompletionRecord", "Request", "RequestState",
           "reset_request_ids",
           "ServingConfig", "SIM_FIELD_MAP",
           "Autoscaler", "AutoscalerConfig", "ClusterSignals",
           "InstanceSignal", "signals_from_cluster",
           "MigrationError", "RequestSnapshot", "migrate",
           "restore_request", "snapshot_request"]
