from repro.serving.batch_scheduler import (
    BatchScheduler,
    IterationPlan,
    KeyPrefixMatcher,
    PrefillChunk,
    SchedStats,
    TokenPrefixMatcher,
)
from repro.serving.engine import LLMEngine, PagedModelRunner
from repro.serving.kv_cache import BlockManager, NoFreeBlocks
from repro.serving.prefix_cache import PrefixCache, PrefixCacheStats
from repro.serving.request import (
    CompletionRecord,
    Request,
    RequestState,
    reset_request_ids,
)

__all__ = ["BatchScheduler", "IterationPlan", "KeyPrefixMatcher",
           "PrefillChunk", "SchedStats", "TokenPrefixMatcher",
           "LLMEngine", "PagedModelRunner", "BlockManager", "NoFreeBlocks",
           "PrefixCache", "PrefixCacheStats",
           "CompletionRecord", "Request", "RequestState",
           "reset_request_ids"]
