from repro.serving.autoscaler import (
    Autoscaler,
    AutoscalerConfig,
    ClusterSignals,
    InstanceSignal,
    signals_from_cluster,
)
from repro.serving.batch_scheduler import (
    BatchScheduler,
    IterationBatch,
    IterationPlan,
    KeyPrefixMatcher,
    PrefillChunk,
    SchedStats,
    Segment,
    TokenPrefixMatcher,
    flatten_plan,
    pad_bucket,
)
from repro.serving.config import ROLES, SIM_FIELD_MAP, ServingConfig
from repro.serving.engine import (
    LLMEngine,
    PagedModelRunner,
    TokenBuffer,
    TokenRef,
)
from repro.serving.cluster import ServingCluster
from repro.serving.faults import (
    FAULT_KINDS,
    DispatchEffects,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InstanceCrashed,
    TransferFault,
)
from repro.serving.handoff import (
    HandoffError,
    decode_targets,
    drive_handoffs,
    handoff,
)
from repro.serving.kv_cache import BlockManager, NoFreeBlocks
from repro.serving.migration import (
    MigrationError,
    RequestSnapshot,
    migrate,
    migrate_many,
    restore_request,
    snapshot_request,
)
from repro.serving.prefix_cache import PrefixCache, PrefixCacheStats
from repro.serving.recovery import LoadShedder, RecoveryManager, RecoveryRecord
from repro.serving.request import (
    CompletionRecord,
    Request,
    RequestPhase,
    RequestState,
    reset_request_ids,
)

__all__ = ["BatchScheduler", "IterationBatch", "IterationPlan",
           "KeyPrefixMatcher", "PrefillChunk", "SchedStats", "Segment",
           "TokenPrefixMatcher", "flatten_plan", "pad_bucket",
           "LLMEngine", "PagedModelRunner", "ServingCluster",
           "TokenBuffer", "TokenRef", "BlockManager", "NoFreeBlocks",
           "PrefixCache", "PrefixCacheStats",
           "CompletionRecord", "Request", "RequestPhase", "RequestState",
           "reset_request_ids",
           "ServingConfig", "SIM_FIELD_MAP", "ROLES",
           "Autoscaler", "AutoscalerConfig", "ClusterSignals",
           "InstanceSignal", "signals_from_cluster",
           "MigrationError", "RequestSnapshot", "migrate", "migrate_many",
           "restore_request", "snapshot_request",
           "HandoffError", "handoff", "decode_targets", "drive_handoffs",
           "FAULT_KINDS", "DispatchEffects", "FaultInjector", "FaultPlan",
           "FaultSpec", "InstanceCrashed", "TransferFault",
           "LoadShedder", "RecoveryManager", "RecoveryRecord"]
