from repro.serving.batch_scheduler import (
    BatchScheduler,
    IterationBatch,
    IterationPlan,
    KeyPrefixMatcher,
    PrefillChunk,
    SchedStats,
    Segment,
    TokenPrefixMatcher,
    flatten_plan,
    pad_bucket,
)
from repro.serving.engine import LLMEngine, PagedModelRunner
from repro.serving.kv_cache import BlockManager, NoFreeBlocks
from repro.serving.prefix_cache import PrefixCache, PrefixCacheStats
from repro.serving.request import (
    CompletionRecord,
    Request,
    RequestState,
    reset_request_ids,
)

__all__ = ["BatchScheduler", "IterationBatch", "IterationPlan",
           "KeyPrefixMatcher", "PrefillChunk", "SchedStats", "Segment",
           "TokenPrefixMatcher", "flatten_plan", "pad_bucket",
           "LLMEngine", "PagedModelRunner", "BlockManager", "NoFreeBlocks",
           "PrefixCache", "PrefixCacheStats",
           "CompletionRecord", "Request", "RequestState",
           "reset_request_ids"]
