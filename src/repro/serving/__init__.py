from repro.serving.engine import LLMEngine, PagedModelRunner
from repro.serving.kv_cache import BlockManager, NoFreeBlocks
from repro.serving.prefix_cache import PrefixCache, PrefixCacheStats
from repro.serving.request import CompletionRecord, Request, RequestState

__all__ = ["LLMEngine", "PagedModelRunner", "BlockManager", "NoFreeBlocks",
           "PrefixCache", "PrefixCacheStats",
           "CompletionRecord", "Request", "RequestState"]
