"""Request objects shared by the load balancer, engines, and simulator.

A request carries the Kairos **system identifiers** (§4.1): agent name,
globally unique message id, upstream agent name, and execution timestamps.
``app_start_time`` is the application-level start time used by the
intra-agent scheduling mechanism (§5.2).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Optional

_req_counter = itertools.count()


def reset_request_ids():
    """Restart the process-global request-id counter at zero.

    Request ids are tie-breakers in scheduling sort keys and preemption
    victim choice, so harnesses that need reproducible trajectories (the
    discrete-event simulator, property tests) call this instead of
    reaching into the private counter."""
    global _req_counter
    _req_counter = itertools.count()


class RequestState(enum.Enum):
    QUEUED = "queued"          # waiting at the load balancer
    WAITING = "waiting"        # dispatched to an instance, not yet admitted
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"
    FAILED = "failed"          # recovery retry budget exhausted
    SHED = "shed"              # dropped by the overload valve (never ran)


class RequestPhase(enum.Enum):
    """Where the request sits in its compute lifecycle — orthogonal to
    :class:`RequestState` (a PREFILL request can be queued, waiting, or
    mid-chunked-prefill).  Role-typed dispatch routes on this: PREFILL
    work may only land on ``prefill``/``general`` instances, DECODE work
    on ``decode``/``general`` ones.  The scheduler flips PREFILL→DECODE
    when the last prompt chunk is composed, and back on
    recompute-preemption (resident KV is dropped, the prompt must be
    re-prefilled)."""
    PREFILL = "prefill"        # prompt KV not yet fully resident
    DECODE = "decode"          # prompt resident; generating tokens


@dataclasses.dataclass
class Request:
    # --- identity / Kairos system identifiers (§4.1) ------------------------
    agent_name: str
    msg_id: str
    upstream_name: Optional[str] = None
    app_name: str = ""
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))

    # --- workload ------------------------------------------------------------
    prompt_len: int = 0
    prompt_tokens: Optional[object] = None      # jnp array for the real engine
    max_new_tokens: int = 512
    true_output_len: int = 0                    # sim: hidden until executed

    # --- shared-prefix KV reuse (prefix_cache.py) ----------------------------
    shared_prefix_len: int = 0      # declared shareable prefix (agent system
    #                                 prompt) — the dispatcher discounts these
    #                                 tokens so shared KV isn't double-counted
    cache_key: Optional[str] = None  # sim: identity of the shared prefix
    cached_prefix_len: int = 0      # observed at admission: tokens served
    #                                 from cache (prefill skipped)
    prefix_hashes: Optional[list] = None  # memoized block-hash chain of the
    #                                 (immutable) prompt — a stalled request
    #                                 retries admission every engine step

    # --- timestamps (§4.1 Execution Timestamps) ------------------------------
    app_start_time: float = 0.0                 # arrival at the frontend
    arrival_time: float = 0.0                   # arrival at this LLM stage
    exec_start_time: float = -1.0               # LLM execution start
    first_token_time: float = -1.0              # first generated token (TTFT)
    finish_time: float = -1.0

    # --- observability -------------------------------------------------------
    trace: Optional[object] = None              # obs.TraceContext when traced

    # --- runtime state --------------------------------------------------------
    state: RequestState = RequestState.QUEUED
    phase: RequestPhase = RequestPhase.PREFILL
    prefilled_len: int = 0          # prompt tokens whose KV is resident
    #                                 (cached prefix + executed prefill
    #                                 chunks); == prompt_len once decodable
    output_len: int = 0
    n_preemptions: int = 0
    instance_id: int = -1
    output_tokens: list = dataclasses.field(default_factory=list)

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.output_len

    @property
    def exec_latency(self) -> float:
        if self.exec_start_time < 0 or self.finish_time < 0:
            return float("nan")
        return self.finish_time - self.exec_start_time

    @property
    def e2e_latency(self) -> float:
        return self.finish_time - self.arrival_time

    def queueing_time(self) -> float:
        if self.exec_start_time < 0:
            return float("nan")
        return self.exec_start_time - self.arrival_time


@dataclasses.dataclass
class CompletionRecord:
    """What the orchestrator collects when a request finishes (§4).

    ``start_time`` is the stage arrival (used for *remaining* end-to-end
    latency, which legitimately includes queueing); ``exec_start_time`` is
    the LLM execution start (used for the single-request execution latency
    distribution that feeds the memory ramps, Eq. 2)."""
    agent_name: str
    msg_id: str
    upstream_name: Optional[str]
    app_name: str
    start_time: float
    end_time: float
    prompt_len: int
    output_len: int
    exec_start_time: float = -1.0
    first_token_time: float = -1.0

    @property
    def latency(self) -> float:
        return self.end_time - self.start_time

    @property
    def exec_latency(self) -> float:
        t0 = self.exec_start_time if self.exec_start_time >= 0 else self.start_time
        return self.end_time - t0
