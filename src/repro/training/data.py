"""Synthetic tokenized data pipeline: deterministic, shardable, epochless.

Stands in for a tokenized corpus: documents are variable-length Zipf token
spans packed into fixed-length rows (standard document-packing), generated
on the fly from the (seed, step, row) key so every data shard is
reproducible and no host I/O is needed.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    mean_doc_len: int = 96
    eos_token: int = 1


class PackedStream:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _row(self, step: int, row: int) -> np.ndarray:
        c = self.cfg
        rng = np.random.default_rng((c.seed * 1_000_003 + step) * 4099 + row)
        toks = np.empty(0, np.int32)
        while len(toks) < c.seq_len + 1:
            n = max(2, int(rng.exponential(c.mean_doc_len)))
            doc = rng.zipf(c.zipf_a, n).astype(np.int32) % (c.vocab_size - 2) + 2
            toks = np.concatenate([toks, doc, [c.eos_token]])
        return toks[: c.seq_len + 1]

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> dict:
        """Return the shard's slice of the global batch for `step`."""
        c = self.cfg
        rows_per_shard = c.global_batch // num_shards
        rows = [self._row(step, shard * rows_per_shard + r)
                for r in range(rows_per_shard)]
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}
