"""Optimizers: Adam (<=34B models) and Adafactor (trillion-param MoE).

Pure-pytree implementations; optimizer states mirror the parameter tree so
the sharding rules (models/sharding.py) apply uniformly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    name: str = "adam"


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    name: str = "adafactor"


def adam_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def adam_update(cfg: AdamConfig, params, grads, state, step):
    t = (step + 1).astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m2 / (1 - cfg.b1 ** t)
        vh = v2 / (1 - cfg.b2 ** t)
        delta = cfg.lr * mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v}


def adafactor_init(params):
    """Factored second moment: (row, col) factors for >=2D leaves, full for
    vectors.  Stored as parallel trees keyed identically to params."""
    def vr(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2 else jnp.zeros((1,), jnp.float32)

    def vc(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if p.ndim >= 2 else jnp.zeros(p.shape, jnp.float32))

    return {"vr": jax.tree.map(vr, params), "vc": jax.tree.map(vc, params)}


def adafactor_update(cfg: AdafactorConfig, params, grads, state, step):
    t = (step + 1).astype(jnp.float32)
    beta = 1.0 - t ** (-cfg.decay)

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + cfg.eps
        if p.ndim >= 2:
            vr2 = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
            vc2 = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr2, axis=-1, keepdims=True), cfg.eps)
            vhat = vr2[..., :, None] * vc2[..., None, :] / denom[..., None]
        else:
            vr2, vc2 = vr, beta * vc + (1 - beta) * g2
            vhat = vc2
        update = g / jnp.sqrt(vhat + cfg.eps)
        norm = jnp.sqrt(jnp.mean(jnp.square(update)))
        update = update / jnp.maximum(1.0, norm / cfg.clip_threshold)
        return (p.astype(jnp.float32) - cfg.lr * update).astype(p.dtype), vr2, vc2

    out = jax.tree.map(upd, params, grads, state["vr"], state["vc"])
    is_t = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda o: o[0], out, is_leaf=is_t),
            {"vr": jax.tree.map(lambda o: o[1], out, is_leaf=is_t),
             "vc": jax.tree.map(lambda o: o[2], out, is_leaf=is_t)})


def make_optimizer(kind: str):
    if kind == "adam":
        cfg = AdamConfig()
        return cfg, adam_init, lambda p, g, s, t: adam_update(cfg, p, g, s, t)
    cfg = AdafactorConfig()
    return cfg, adafactor_init, lambda p, g, s, t: adafactor_update(cfg, p, g, s, t)


def make_train_step(model, opt_kind: str = "adam"):
    """Returns (init_state(key), train_step(state, batch) -> (state, metrics))."""
    _, opt_init, opt_update = make_optimizer(opt_kind)

    def init_state(key):
        params = model.init_params(key)
        return {"params": params, "opt": opt_init(params),
                "step": jnp.zeros((), jnp.int32)}

    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state["params"], batch)
        new_params, new_opt = opt_update(state["params"], grads, state["opt"], state["step"])
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, {"loss": loss, **metrics}

    return init_state, train_step
