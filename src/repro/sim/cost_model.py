"""Iteration-level LLM engine cost model for the discrete-event simulator.

Calibrated to an 8B-class dense decoder.  The paper profiles Llama3-8B on
an A40; our target hardware is a v5e-class accelerator (DESIGN.md §3) —
the *relative* agent behaviour (Figs 3–6) is hardware-independent, and
only these constants set the absolute scale.

One continuous-batching iteration with `n_decode` decoding sequences,
`prefill_tokens` newly computed prompt tokens, `cached_tokens` resident
tokens that prefill work attends over without recomputing (shared-prefix
cache hits and, under chunked prefill, the already-prefilled context of
later chunks), and `n_prefill_seqs` prompt segments in the batch costs

    t = t_base + beta * n_decode + gamma * prefill_tokens
             + gamma_cached * cached_tokens
             + beta_prefill * n_prefill_seqs
             + hbm_bytes / hbm_bandwidth                       [seconds]

which reproduces the paper's two key observations: decode dominates
(>96.6% of latency for typical output lengths) and per-request decode
speed is roughly constant (Eq. 1's slope `k`).  An attended-but-resident
token costs only the page-table plumbing and the extra attention context
— roughly 5% of recomputing it (`gamma_cached`) — which is exactly the
re-read overhead chunked prefill trades for not head-of-line-blocking the
decode batch.

Per-segment overhead depends on the execution model.  The legacy
per-chunk engine path issues one jitted dispatch per prefill chunk plus a
blocking argmax sync, so mixing K prompt chunks into an iteration costs
K+1 dispatches: `beta_prefill` prices that per-segment launch + sync +
pipeline bubble.  The fused single-dispatch path
(`LLMEngine(fused_iteration=True)`, the default) executes the whole
ragged batch in ONE dispatch — the per-iteration fixed overhead `t_base`
is paid once and amortized across every segment, and only a small ragged
mask / metadata cost `beta_seg_fused` remains per segment
(``iteration_time(..., fused=True)``).

Two memory-system effects of the zero-copy engine hot path (PR 5) are
priced explicitly:

* **Pool-copy traffic** — a jitted step without buffer donation
  materializes a second full-size KV-pool buffer per dispatch (a
  read+write of `pool_bytes` through HBM at `hbm_gbps`); donated
  in-place pools price those bytes at 0.
* **Segment-bounded attention** — the native ragged kernel gathers each
  page of a chunk's (bounded) context exactly once per chunk, while the
  flatten-and-repeat lowering re-gathers the batch-padded table width
  once per query *token* (S·L decode-style rows), so its extra traffic
  scales with chunk length × padded context.

Both are genuine HBM traffic, so both flow through one term: the
simulator sums them into ``hbm_bytes`` (copies count read+write, gathers
read-only) and ``iteration_time`` prices it at ``hbm_gbps``.  With the
default knobs (``donate_pool=True``, ``ragged_native=True``) the term is
0 and the trajectory is unchanged.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CostModel:
    name: str = "llama3-8b"
    t_base: float = 0.008          # fixed per-iteration overhead (s)
    beta: float = 0.0012           # per decoding sequence (s)
    gamma: float = 0.00015         # per prefill token (s)
    gamma_cached: float = 0.0000075  # per attended resident token (s)
    beta_prefill: float = 0.0004   # per prefill segment, per-chunk path:
    #                                extra dispatch + blocking argmax sync (s)
    beta_seg_fused: float = 0.00008  # per segment, fused single-dispatch
    #                                path: ragged mask / metadata only (s)
    kv_bytes_per_token: int = 131072  # fp16 KV per token (8B-class:
    #                                32 layers x 2 x 8 kv heads x 128 hd x 2B)
    hbm_gbps: float = 800.0        # device memory bandwidth (GB/s) pricing
    #                                non-donated pool-copy traffic
    # --- tensor parallelism (sharded instances) ---------------------------
    # The sharded engine's only per-layer collectives are the two megatron
    # all-reduces, each moving one activation row (d_model x dtype bytes)
    # per token per layer over the interconnect.  A ring all-reduce over
    # tp shards moves 2*(tp-1)/tp of the payload per link.
    num_layers: int = 32
    allreduce_bytes_per_token_layer: int = 16384  # 2 psums x d_model=4096 x 2B
    ici_gbps: float = 100.0        # per-link interconnect bandwidth (GB/s)

    def iteration_time(self, n_decode: int, prefill_tokens: int,
                       cached_tokens: int = 0,
                       n_prefill_seqs: int = 0,
                       fused: bool = False,
                       hbm_bytes: int = 0,
                       tp_degree: int = 1) -> float:
        """``tp_degree`` > 1 models a megatron-sharded instance: the
        compute terms divide across shards (each holds 1/tp of heads and
        d_ff) while ``t_base`` — dispatch/launch overhead — does not,
        and a per-token-per-layer ring all-reduce term is added.  At the
        default ``tp_degree=1`` the collective term is exactly 0 and
        every compute term divides by 1, so all pre-sharding trajectories
        and committed BENCH baselines are numerically unchanged."""
        seg = (self.beta_seg_fused if fused else self.beta_prefill) \
            * n_prefill_seqs
        tp = max(1, tp_degree)
        coll = 0.0
        if tp > 1:
            tokens = n_decode + prefill_tokens
            coll = (tokens * self.num_layers
                    * self.allreduce_bytes_per_token_layer
                    * 2 * (tp - 1) / tp) / (self.ici_gbps * 1e9)
        return (self.t_base
                + (self.beta * n_decode
                   + self.gamma * prefill_tokens
                   + self.gamma_cached * cached_tokens
                   + seg) / tp
                + coll + hbm_bytes / (self.hbm_gbps * 1e9))

    def pool_bytes(self, kv_capacity_tokens: int) -> int:
        """Resident KV-pool size of an instance with the given capacity —
        a non-donated dispatch moves 2x this (read + write) just to
        thread the pool through."""
        return kv_capacity_tokens * self.kv_bytes_per_token

    def decode_tok_per_s(self, typical_batch: int = 8) -> float:
        """Per-request decode speed at a typical batch (Eq. 1 `k`)."""
        return 1.0 / self.iteration_time(typical_batch, 0)

    def transfer_time(self, n_tokens: int) -> float:
        """Prefill→decode handoff wire time for a request with
        ``n_tokens`` resident KV: a gathered pool-to-pool block copy
        (intra-host disaggregation), so the bytes move at HBM rate —
        read on the source + write on the target."""
        return 2 * n_tokens * self.kv_bytes_per_token / (self.hbm_gbps * 1e9)


LLAMA3_8B = CostModel("llama3-8b")
# 13B-class: ~1.7x per-token cost, same structure (§7.5 scalability study);
# llama2-13b is MHA, so its KV rows are much fatter than the 8B's GQA
# (40 layers x 2 x 40 heads x 128 hd x 2B)
LLAMA2_13B = CostModel("llama2-13b", t_base=0.013, beta=0.0021, gamma=0.00026,
                       gamma_cached=0.000013, beta_prefill=0.0007,
                       beta_seg_fused=0.00014, kv_bytes_per_token=1638400,
                       hbm_gbps=800.0, num_layers=40,
                       allreduce_bytes_per_token_layer=20480)

COST_MODELS = {m.name: m for m in (LLAMA3_8B, LLAMA2_13B)}
