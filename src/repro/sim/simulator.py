"""Discrete-event cluster simulator.

Reproduces the paper's end-to-end experiments (Figs 14–18) at production
scale.  **Every scheduling/dispatching decision is made by the production
Kairos code** (`repro.core.*` — orchestrator, Wasserstein+MDS priorities,
time-slot dispatcher, baselines); only LLM execution is replaced by the
calibrated iteration cost model and sampled output lengths.  Instances
run real continuous batching with the real `BlockManager`, including
preemption-by-recompute.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (
    BestFitOracleDispatcher,
    FCFSScheduler,
    InstanceModel,
    KairosScheduler,
    LoadBalancer,
    Orchestrator,
    OracleScheduler,
    RoundRobinDispatcher,
    SchedulerPolicy,
    TimeSlotDispatcher,
    TopoScheduler,
)
from repro.core.dispatcher import role_accepts
from repro.core.orchestrator import HardwareProfile
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.autoscaler import (
    Autoscaler,
    AutoscalerConfig,
    ClusterSignals,
    InstanceSignal,
)
from repro.serving.batch_scheduler import (
    TABLE_BUCKET_FLOOR,
    BatchScheduler,
    KeyPrefixMatcher,
    pad_bucket,
)
from repro.serving.config import ServingConfig
from repro.serving.faults import FaultInjector, FaultPlan, InstanceCrashed
from repro.serving.kv_cache import BlockManager
from repro.serving.prefix_cache import PrefixCache
from repro.serving.recovery import LoadShedder, RecoveryManager
from repro.serving.request import CompletionRecord, Request, reset_request_ids
from repro.sim.cost_model import LLAMA3_8B, CostModel
from repro.sim.workload import AppSpec, arrival_times

AGENT_OVERHEAD = 0.02       # local (non-LLM) agent compute between stages (s)
BALANCER_PERIOD = 0.05      # retry period when requests sit in the queue (s)


# =============================================================================
# simulated instance (continuous batching at iteration granularity)
# =============================================================================


class SimInstance:
    """One simulated LLM instance: the shared
    :class:`~repro.serving.batch_scheduler.BatchScheduler` makes every
    admission / eviction / preemption / batch-composition decision
    (identical code to the real :class:`~repro.serving.LLMEngine`); this
    class only prices each composed iteration with the calibrated
    :class:`CostModel` and advances sampled output lengths."""

    def __init__(self, instance_id: int, cost: CostModel,
                 kv_capacity_tokens: int, block_size: int = 16,
                 max_batch: int = 16, prefix_caching: bool = False,
                 policy: Optional[SchedulerPolicy] = None,
                 prefill_chunk_tokens: Optional[int] = None,
                 fused_iteration: bool = True,
                 donate_pool: bool = True,
                 ragged_native: bool = True,
                 tp_degree: int = 1,
                 role: str = "general",
                 tracer: Tracer = NULL_TRACER):
        self.instance_id = instance_id
        self.cost = cost
        self.fused_iteration = fused_iteration
        self.donate_pool = donate_pool
        self.ragged_native = ragged_native
        self.tp_degree = tp_degree
        # the KV pool (and thus any pool-copy / re-gather HBM traffic) is
        # sharded over kv heads: each shard moves 1/tp of the bytes
        self.pool_bytes = cost.pool_bytes(kv_capacity_tokens) // max(1, tp_degree)
        self.bm = BlockManager(kv_capacity_tokens // block_size, block_size)
        self.cache = PrefixCache(block_size) if prefix_caching else None
        self.busy = False
        self.tracer = tracer
        # fault plane: the Simulation threads its FaultInjector here (one
        # injector per run, per-instance dispatch ordinals inside it) —
        # same consultation point as LLMEngine.dispatch_iteration
        self.faults: Optional[FaultInjector] = None
        self.sched = BatchScheduler(
            self.bm, policy=policy, prefix_cache=self.cache,
            matcher=KeyPrefixMatcher(), max_running=max_batch,
            prefill_chunk_tokens=prefill_chunk_tokens,
            tracer=tracer, instance_id=instance_id, role=role)

    @property
    def role(self) -> str:
        """Disaggregation role — lives on the shared scheduler, exactly
        like the real engine's."""
        return self.sched.role

    # ------------------------------------------------------------------ intake
    def submit(self, req: Request):
        req.instance_id = self.instance_id
        self.sched.submit(req)

    def can_admit(self, req: Request,
                  watermark: Optional[float] = None) -> bool:
        return self.sched.can_admit(req, watermark)

    # ----------------------------------------------------------------- monitor
    @property
    def max_batch(self) -> int:
        return self.sched.max_batch

    @property
    def waiting(self) -> List[Request]:
        return self.sched.waiting

    @property
    def running(self) -> List[Request]:
        return self.sched.running

    @property
    def n_preempted(self) -> int:
        return self.sched.stats.n_preempted

    @property
    def recent_oom(self) -> bool:
        return self.sched.stats.recent_oom

    @recent_oom.setter
    def recent_oom(self, value: bool):
        self.sched.stats.recent_oom = value

    @property
    def prefill_tokens_total(self) -> int:
        s = self.sched.stats
        return s.prefill_tokens + s.prefill_tokens_saved

    @property
    def prefill_tokens_saved(self) -> int:
        return self.sched.stats.prefill_tokens_saved

    @property
    def has_work(self) -> bool:
        return self.sched.has_work

    # ------------------------------------------------------------------ step
    def step(self, now: float) -> Tuple[List[Request], Optional[float]]:
        """Run one continuous-batching iteration starting at `now`.
        Returns (requests finished at now+dt, dt) or ([], None) if idle."""
        plan = self.sched.plan(now)
        if plan is None:
            return [], None
        eff = None
        if self.faults is not None:
            # same point as the real engine: AFTER plan() — the scheduler
            # has already composed (and mutated state for) this iteration
            eff = self.faults.on_dispatch(self.instance_id, now)
            if eff.oom:
                self.sched.stats.recent_oom = True
            if eff.crash is not None:
                raise InstanceCrashed(self.instance_id, eff.crash.step)
        hbm_bytes = 0
        if self.fused_iteration and not self.ragged_native and plan.chunks:
            # flatten-and-repeat attention lowers each chunk onto S·L
            # decode-style query rows, and every row re-gathers the
            # batch-padded table width — page traffic scales with chunk
            # length × padded context, where the native segment-tiled
            # kernel gathers each (bounded) page once per chunk.  Only
            # the fused path uses the ragged lowering; the per-chunk
            # path gathers exactly its resident context either way.
            bs = self.bm.block_size
            nbp = pad_bucket(max(self.bm.blocks_needed(c.end)
                                 for c in plan.chunks), TABLE_BUCKET_FLOOR)
            extra_rows = sum(
                (c.end - c.start) * nbp * bs - c.end for c in plan.chunks)
            hbm_bytes += extra_rows * self.cost.kv_bytes_per_token \
                // max(1, self.tp_degree)
        if not self.donate_pool:
            # every pool-threading dispatch materializes a second pool
            # buffer (full read + write): 1 for the fused path, one per
            # chunk + one decode dispatch for the per-chunk path
            n_disp = 1 if self.fused_iteration else \
                len(plan.chunks) + (1 if plan.decode else 0)
            hbm_bytes += 2 * n_disp * self.pool_bytes
        dt = self.cost.iteration_time(
            len(plan.decode), plan.prefill_tokens, plan.context_tokens,
            n_prefill_seqs=len(plan.chunks), fused=self.fused_iteration,
            hbm_bytes=hbm_bytes, tp_degree=self.tp_degree)
        if eff is not None:
            # straggler: the real path sleeps on the worker; the sim
            # stretches virtual time by the same slowdown
            dt = dt * eff.factor + eff.delay_s
        finished = []
        traced = self.tracer.enabled
        for r in plan.decode:
            r.output_len += 1
            # same event schema as the real engine, stamped with SIM time:
            # the first decode step books the first generated token
            if r.output_len == 1 and r.first_token_time < 0:
                r.first_token_time = now + dt
                if traced:
                    self.tracer.emit("first-token", req_id=r.req_id,
                                     instance_id=self.instance_id,
                                     agent=r.agent_name, msg_id=r.msg_id,
                                     ts=now + dt)
            elif traced:
                self.tracer.emit("decode", req_id=r.req_id,
                                 instance_id=self.instance_id,
                                 agent=r.agent_name, msg_id=r.msg_id,
                                 ts=now + dt)
            if r.output_len >= r.true_output_len:
                self.sched.finish(r, now + dt)
                finished.append(r)
        return finished, dt


# =============================================================================
# simulation
# =============================================================================


@dataclasses.dataclass
class SimConfig:
    apps: List[AppSpec]
    policy: str = "kairos"            # kairos|parrot|ayo|w/o-priority|w/o-packing|oracle
    rate: float = 6.0                 # workflows/s across all apps
    duration: float = 120.0
    n_instances: int = 4
    kv_capacity_tokens: int = 12288   # per instance (pressure regime, §2.2.3)
    block_size: int = 16              # KV page granularity per instance
    max_batch: int = 48               # memory-bound like the paper's vLLM setup
    cost: CostModel = LLAMA3_8B
    seed: int = 0
    warmup_frac: float = 0.1          # excluded from metrics (online learning)
    prefix_caching: bool = False      # shared-prefix KV reuse on instances
    # instance-level scheduling (batch_scheduler.py): when True, each
    # instance's waiting queue is ordered by the same policy that orders
    # the cluster queue (Kairos priorities carry into the serving
    # iteration); False keeps FCFS instance queues for every policy
    # (pre-refactor behaviour)
    instance_priority: bool = True
    # per-iteration prefill token budget (Sarathi-style chunked prefill);
    # None = monolithic prefill: a prompt stalls the whole batch for one
    # iteration, exactly the §2.2 head-of-line pathology
    prefill_chunk_tokens: Optional[int] = None
    # price each iteration as ONE fused ragged dispatch (the engine's
    # default execution model) instead of one dispatch per prefill chunk
    # plus a decode dispatch; False reproduces the per-chunk pricing
    fused_iteration: bool = True
    # donated in-place KV pool (the engine's default): pool-copy bytes
    # cost 0; False prices one full pool read+write per dispatch, the
    # pre-donation engine behaviour
    donate_pool: bool = True
    # native segment-bounded ragged attention (each chunk re-reads only
    # its own context); False prices the flatten-and-repeat lowering,
    # which re-reads the batch-padded table width per chunk
    ragged_native: bool = True
    # tensor-parallel degree of each instance: compute terms and KV/HBM
    # traffic divide across shards, plus the per-layer ring all-reduce
    # term (CostModel).  Default 1 = unsharded, collective term exactly
    # 0 — every pre-sharding trajectory and BENCH baseline is unchanged
    tp_degree: int = 1
    # observability: thread one obs.Tracer through the whole sim control
    # plane + instances, emitting the SAME event schema as the real
    # engine path with simulated timestamps (sim-vs-real breakdowns
    # diff).  The trace lands on Simulation.tracer after run().
    tracing: bool = False
    # explicit arrival trace: [(t, app_idx)] replayed verbatim instead of
    # the homogeneous-Poisson `rate`/`duration` sampler — the bursty
    # traces from repro.workloads.traces replay through here (and
    # through the real cluster, same list)
    arrivals: Optional[List[Tuple[float, int]]] = None
    # elastic instance count: when set, an Autoscaler (shared decision
    # core with the real cluster's control plane) adds/retires
    # SimInstances at decision_period_s cadence; retirement drains via
    # the scheduler-level release/adopt migration (progress preserved)
    autoscale: Optional[AutoscalerConfig] = None
    # role topology (prefill/decode disaggregation), one role per
    # instance id; None = every instance "general" (flat cluster).
    # Mirrors ServingConfig.roles: prefill instances run chunked prefill
    # only and hand completed prompts to decode-capable instances via
    # the scheduler-level release/adopt (the sim analogue of the
    # block-granular KV handoff), priced by CostModel.transfer_time
    roles: Optional[tuple] = None
    # -- fault plane (mirrors ServingConfig; serving/faults.py,
    # serving/recovery.py — SAME classes run in both paths) ------------------
    # driver-level LLM retry knobs: carried for SIM_FIELD_MAP parity
    # (the sim's virtual clock never blocks a driver thread)
    llm_retries: int = 0
    llm_backoff_s: float = 0.5
    recovery_retries: int = 3            # crashes a request may survive
    recovery_backoff_s: float = 0.0      # exp. backoff before re-queue (s)
    # straggler fence threshold: real wall-clock — the sim carries the
    # knob for parity but its injected straggles stretch virtual time
    step_deadline_s: Optional[float] = None
    slo_e2e_s: Optional[float] = None    # arms the LoadShedder valve
    shed_queue_high: float = 8.0
    shed_kv_high: float = 0.97
    shed_patience: int = 3
    handoff_retry_cap: int = 4           # probes before permanent strand
    # sim-only: deterministic chaos schedule (None = fault-free).  The
    # SAME FaultPlan object drives a real ServingCluster identically.
    faults: Optional[FaultPlan] = None

    def role_of(self, instance_id: int) -> str:
        """Role of an instance id; ids past the declared topology
        (autoscaled pool instances) default to ``general`` — same rule
        as ``ServingConfig.role_of``."""
        if self.roles is None or instance_id >= len(self.roles):
            return "general"
        return self.roles[instance_id]

    @classmethod
    def from_serving_config(cls, serving: ServingConfig, apps: List[AppSpec],
                            **overrides) -> "SimConfig":
        """Map a real-path :class:`ServingConfig` onto the simulator —
        the executable form of ``serving.config.SIM_FIELD_MAP`` (the
        parity test drives both).  ``overrides`` set the sim-only knobs
        (rate, duration, cost, seed, arrivals, autoscale, ...)."""
        base = dict(
            apps=apps,
            policy=serving.sim_policy,
            n_instances=serving.n_instances,
            kv_capacity_tokens=serving.kv_capacity_tokens,
            block_size=serving.block_size,
            max_batch=serving.max_batch,
            prefix_caching=serving.prefix_caching,
            prefill_chunk_tokens=serving.prefill_chunk_tokens,
            fused_iteration=serving.fused_iteration,
            donate_pool=serving.donate_pool,
            ragged_native=serving.ragged_native,
            tp_degree=serving.model_parallel,
            tracing=serving.tracing,
            roles=serving.roles,
            llm_retries=serving.llm_retries,
            llm_backoff_s=serving.llm_backoff_s,
            recovery_retries=serving.recovery_retries,
            recovery_backoff_s=serving.recovery_backoff_s,
            step_deadline_s=serving.step_deadline_s,
            slo_e2e_s=serving.slo_e2e_s,
            shed_queue_high=serving.shed_queue_high,
            shed_kv_high=serving.shed_kv_high,
            shed_patience=serving.shed_patience,
            handoff_retry_cap=serving.handoff_retry_cap,
        )
        base.update(overrides)
        return cls(**base)


@dataclasses.dataclass
class WorkflowState:
    msg_id: str
    app: AppSpec
    start_time: float
    outstanding: int = 0
    hops: int = 0
    total_tokens: int = 0
    done_time: float = -1.0
    requests: List[Request] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SimResults:
    workflows: List[WorkflowState]
    requests: List[Request]
    n_preempted: int
    queueing_ratio: float
    policy: str
    prefill_tokens_total: int = 0
    prefill_tokens_saved: int = 0
    n_migrated: int = 0               # live migrations during elastic drains
    instance_seconds: float = 0.0     # capacity actually paid for
    n_handoffs: int = 0               # prefill→decode transfers completed
    n_stranded: int = 0               # handoffs refused -> colocated decode
    n_strand_retries: int = 0         # re-offers of already-stranded reqs
    n_crashes: int = 0                # injected instance crashes handled
    n_reconstructed: int = 0          # requests replay-reconstructed
    n_shed: int = 0                   # requests dropped by the overload valve
    n_lost: int = 0                   # recovery budget exhausted (FAILED)
    n_workflows_total: int = 0        # post-warmup workflows STARTED (the
    #                                   goodput denominator: shed/lost
    #                                   workflows never reach `workflows`)
    scale_history: List[Tuple[float, str, int, int]] = \
        dataclasses.field(default_factory=list)

    @property
    def prefill_savings(self) -> float:
        return self.prefill_tokens_saved / max(self.prefill_tokens_total, 1)

    def token_latencies(self) -> np.ndarray:
        """Program-level token latency [37]: e2e response time / tokens."""
        vals = [(w.done_time - w.start_time) / max(w.total_tokens, 1)
                for w in self.workflows if w.done_time >= 0]
        return np.asarray(vals)

    def summary(self) -> Dict[str, float]:
        tl = self.token_latencies()
        if len(tl) == 0:
            return {"avg": float("nan")}
        return {
            "avg": float(np.mean(tl)),
            "p50": float(np.percentile(tl, 50)),
            "p90": float(np.percentile(tl, 90)),
            "p95": float(np.percentile(tl, 95)),
            "p99": float(np.percentile(tl, 99)),
            "n_workflows": float(len(tl)),
            "preempted": float(self.n_preempted),
            "queueing_ratio": self.queueing_ratio,
            "prefill_savings": self.prefill_savings,
            "n_migrated": float(self.n_migrated),
            "instance_seconds": self.instance_seconds,
            "n_handoffs": float(self.n_handoffs),
            "n_stranded": float(self.n_stranded),
            "n_strand_retries": float(self.n_strand_retries),
            "n_crashes": float(self.n_crashes),
            "n_reconstructed": float(self.n_reconstructed),
            "n_shed": float(self.n_shed),
            "n_lost": float(self.n_lost),
            "n_workflows_total": float(self.n_workflows_total),
        }

    def goodput(self, slo_e2e_s: Optional[float]) -> float:
        """Fraction of post-warmup workflows that completed end-to-end
        within ``slo_e2e_s`` — over every workflow STARTED, so shed and
        lost workflows count against it (the honest denominator)."""
        total = max(self.n_workflows_total, 1)
        if slo_e2e_s is None:
            return len(self.workflows) / total
        good = sum(1 for w in self.workflows
                   if w.done_time - w.start_time <= slo_e2e_s)
        return good / total


class Simulation:
    # policies that carry their ordering into the serving iteration; the
    # baselines (Parrot/Ayo/ablations) schedule only at the cluster queue
    # and their instances stay FCFS, faithful to the systems they model
    INSTANCE_LEVEL_POLICIES = ("kairos", "w/o-packing", "oracle")

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        # reset the global request-id counter so trajectories (tie-breaks
        # in victim selection / sort stability) are reproducible no matter
        # how many requests earlier simulations in this process created
        reset_request_ids()
        self.rng = np.random.default_rng(cfg.seed)
        hw = HardwareProfile(
            decode_tok_per_s=cfg.cost.decode_tok_per_s(typical_batch=cfg.max_batch // 2),
            kv_capacity_tokens=cfg.kv_capacity_tokens)
        self.tracer: Tracer = Tracer() if cfg.tracing else NULL_TRACER
        self.orch = Orchestrator(hardware=hw, prefix_caching=cfg.prefix_caching,
                                 tracer=self.tracer)
        models = [InstanceModel(i, cfg.kv_capacity_tokens,
                                role=cfg.role_of(i))
                  for i in range(cfg.n_instances)]
        self.scheduler, self.dispatcher, strict = self._make_policy(cfg.policy, models)
        self._inst_policy = (self.scheduler
                             if cfg.instance_priority
                             and cfg.policy in self.INSTANCE_LEVEL_POLICIES
                             else None)
        # keyed by instance_id: the autoscaler adds/retires instances at
        # runtime, so ids are stable names, not list positions
        self.instances: Dict[int, SimInstance] = {
            i: self._make_instance(i) for i in range(cfg.n_instances)}
        # every instance that ever lived, for end-of-run stats (a retired
        # instance's preemption/prefill counters still count)
        self._all_instances: List[SimInstance] = list(self.instances.values())
        self._spawn_time: Dict[int, float] = dict.fromkeys(self.instances, 0.0)
        self.instance_seconds = 0.0
        self.autoscaler = Autoscaler(cfg.autoscale) if cfg.autoscale else None
        self.balancer = LoadBalancer(
            self.scheduler, self.dispatcher, self.orch, self._submit,
            strict_head=strict, tracer=self.tracer)
        self.workflows: Dict[str, WorkflowState] = {}
        self.finished_requests: List[Request] = []
        self.n_handoffs = 0
        self.n_stranded = 0
        self.n_strand_retries = 0
        # fault plane: SAME classes as ServingCluster — the injector
        # consumes cfg.faults at the same per-instance dispatch ordinals,
        # the RecoveryManager reconstructs crash victims through this
        # Simulation's dispatcher/balancer/discard_engine surface, and
        # the shedder (armed by slo_e2e_s) prices slack with cfg.cost
        self.faults = (FaultInjector(cfg.faults, self.tracer)
                       if cfg.faults is not None else None)
        for inst in self.instances.values():
            inst.faults = self.faults
        self.recovery = RecoveryManager(
            max_retries=cfg.recovery_retries,
            backoff_s=cfg.recovery_backoff_s, tracer=self.tracer)
        self.shedder = (LoadShedder(
            slo_e2e_s=cfg.slo_e2e_s, cost=cfg.cost,
            queue_high=cfg.shed_queue_high, kv_high=cfg.shed_kv_high,
            patience=cfg.shed_patience, tracer=self.tracer)
            if cfg.slo_e2e_s is not None else None)
        self.lost_requests: List[Request] = []   # FAILED + SHED
        self._events: List[Tuple[float, int, str, object]] = []
        self._eseq = itertools.count()
        self._msg_counter = itertools.count()
        self._balancer_armed = False

    def _make_instance(self, iid: int,
                       role: Optional[str] = None) -> SimInstance:
        cfg = self.cfg
        return SimInstance(
            iid, cfg.cost, cfg.kv_capacity_tokens, block_size=cfg.block_size,
            max_batch=cfg.max_batch, prefix_caching=cfg.prefix_caching,
            policy=self._inst_policy,
            prefill_chunk_tokens=cfg.prefill_chunk_tokens,
            fused_iteration=cfg.fused_iteration,
            donate_pool=cfg.donate_pool, ragged_native=cfg.ragged_native,
            tp_degree=cfg.tp_degree,
            role=cfg.role_of(iid) if role is None else role,
            tracer=self.tracer)

    # ------------------------------------------------------------------ policy
    def _make_policy(self, policy: str, models):
        probe = lambda iid, req: self.instances[iid].can_admit(req)
        if policy == "parrot":
            # true Parrot: blind rotation, requests queue FIFO at instances
            return FCFSScheduler(), RoundRobinDispatcher(models), False
        if policy == "ayo":
            return (TopoScheduler(self.orch.remaining_stages),
                    RoundRobinDispatcher(models, probe), True)
        if policy == "kairos":
            return (KairosScheduler(self.orch.priority_score),
                    TimeSlotDispatcher(models, admit_probe=probe,
                                       tracer=self.tracer), True)
        if policy == "w/o-priority":
            return FCFSScheduler(), TimeSlotDispatcher(
                models, admit_probe=probe, tracer=self.tracer), True
        if policy == "w/o-packing":
            # packing removed -> admission-gated rotation (priority retained)
            return (KairosScheduler(self.orch.priority_score),
                    RoundRobinDispatcher(models, probe), True)
        if policy == "oracle":
            def true_remaining(req: Request) -> float:
                return req.true_output_len * self.cfg.cost.iteration_time(
                    self.cfg.max_batch // 2, 0)
            return (OracleScheduler(true_remaining),
                    BestFitOracleDispatcher(models, probe), False)
        raise ValueError(f"unknown policy {policy!r}")

    # ------------------------------------------------------------------ events
    def _push(self, t: float, kind: str, payload=None):
        heapq.heappush(self._events, (t, next(self._eseq), kind, payload))

    def _submit(self, iid: int, req: Request):
        inst = self.instances[iid]
        was_idle = not inst.has_work
        inst.submit(req)
        if was_idle or not inst.busy:
            self._push(self._now, "instance_step", iid)
            inst.busy = True

    def _arm_balancer(self, t: float):
        if not self._balancer_armed:
            self._balancer_armed = True
            self._push(t, "balancer", None)

    # -------------------------------------------------------------- elasticity
    def _signals(self, now: float,
                 role: Optional[str] = None) -> ClusterSignals:
        inst = [InstanceSignal(
            instance_id=i.instance_id,
            kv_used_frac=i.bm.hard_used_blocks / i.bm.num_blocks,
            fenced=now < self.dispatcher.instances[i.instance_id].fenced_until,
            load=len(i.running) + len(i.waiting))
            for i in self.instances.values()
            if role is None or i.role == role]
        if role is None:
            depth = self.balancer.queued
        else:
            depth = sum(1 for r in self.balancer.queue
                        if role_accepts(role, r))
        return ClusterSignals(now=now, queue_depth=depth, instances=inst)

    def _scale_up(self, now: float, role: Optional[str] = None):
        iid = max(self.instances) + 1
        inst = self._make_instance(iid, role=role)
        inst.faults = self.faults
        self.instances[iid] = inst
        self._all_instances.append(inst)
        self._spawn_time[iid] = now
        self.dispatcher.add_instance(
            InstanceModel(iid, self.cfg.kv_capacity_tokens, role=inst.role))
        self.autoscaler.note_action(now, "up", iid, len(self.instances))
        if self.tracer.enabled:
            self.tracer.emit("scale-up", instance_id=iid, ts=now,
                             n=len(self.instances), role=inst.role)

    def _scale_down(self, victim: int, now: float):
        """Retire a SimInstance by draining it through migration: the sim
        analogue of the real cluster's KV-carrying path — same
        scheduler-level release/adopt (progress preserved, no recompute),
        minus the block bytes (the cost model has no KV contents)."""
        removed = self.dispatcher.remove_instance(victim)
        inst = self.instances.pop(victim)
        self.instance_seconds += now - self._spawn_time.pop(victim)
        while inst.sched.has_work:
            for req in list(inst.sched.waiting):
                inst.sched.release(req)
                removed.ramps.pop(req.req_id, None)
                self.balancer.enqueue(req)
            if not inst.sched.running:
                continue
            req = inst.sched.running[0]
            target = min(
                (i for i in self.instances.values()
                 if role_accepts(i.role, req) and i.sched.can_adopt(req)),
                key=lambda i: i.bm.hard_used_blocks, default=None)
            if target is not None:
                inst.sched.release(req)
                target.sched.adopt(req, now)
                req.instance_id = target.instance_id
                self.dispatcher.adopt_ramp(
                    target.instance_id, req.req_id,
                    removed.ramps.pop(req.req_id, None))
                if not target.busy:
                    self._push(now, "instance_step", target.instance_id)
                    target.busy = True
                if self.tracer.enabled:
                    self.tracer.emit("migrate-candidate", req_id=req.req_id,
                                     agent=req.agent_name, msg_id=req.msg_id,
                                     ts=now, to=target.instance_id,
                                     reason="scale-down")
            else:
                inst.sched.preempt(req)
                inst.sched.release(req)
                removed.ramps.pop(req.req_id, None)
                self.balancer.enqueue(req)
        self.autoscaler.note_action(now, "down", victim, len(self.instances))
        if self.tracer.enabled:
            self.tracer.emit("scale-down", instance_id=victim, ts=now,
                             n=len(self.instances), role=removed.role)
        self._arm_balancer(now)

    # ------------------------------------------------------------- fault plane
    def discard_engine(self, inst: SimInstance):
        """Drop a crashed instance (RecoveryManager callback — same name
        as the real cluster's).  Its BlockManager dies with it; victims
        were already captured off its scheduler by the caller."""
        assert len(self.instances) > 1, \
            "every instance crashed — nothing left to recover onto"
        iid = inst.instance_id
        self.instances.pop(iid, None)
        self.instance_seconds += self._now - self._spawn_time.pop(iid,
                                                                  self._now)

    def _book_lost(self, req: Request, now: float):
        """Account a request that will never finish (SHED by the valve or
        FAILED past its recovery budget): unblock its workflow without
        spawning downstream — the workflow stays incomplete and counts
        against goodput."""
        self.lost_requests.append(req)
        wf = self.workflows.get(req.msg_id)
        if wf is not None:
            wf.outstanding -= 1

    def _on_crash(self, inst: SimInstance, now: float):
        """An injected crash surfaced from ``SimInstance.step``: hand the
        dead instance to the shared RecoveryManager (fence + remove +
        reconstruct), book budget-exhausted victims as lost, and arm the
        events that resume the survivors."""
        for req in self.recovery.on_crash(self, inst, now):
            self._book_lost(req, now)
        for t_ready in self.recovery.backoff_deadlines:
            self._push(t_ready, "recovery", None)
        self._arm_balancer(now)

    def _shed_sweep(self, now: float):
        """Overload valve at the balancer tick — same signals the
        autoscaler reads, same LoadShedder rule as the real cluster."""
        sig = self._signals(now)
        max_kv = max((i.kv_used_frac for i in sig.instances), default=0.0)
        self.shedder.observe(self.balancer.queued,
                             max(1, len(self.instances)), max_kv)
        victims = self.shedder.select(self.balancer.queue, now,
                                      max(1, len(self.instances)))
        if not victims:
            return
        vids = {r.req_id for r in victims}
        self.balancer.queue = [r for r in self.balancer.queue
                               if r.req_id not in vids]
        depth = self.balancer.queued
        for r in victims:
            self.shedder.shed(r, now, depth)
            self._book_lost(r, now)

    def _autoscale_tick(self, now: float):
        """Mirror of ``Autoscaler.step``: one decision per role pool,
        each from role-split signals (a flat sim is one general pool)."""
        roles = {i.role for i in self.instances.values()}
        pools = [r for r in ("prefill", "decode", "general")
                 if r in roles] or ["general"]
        split = pools != ["general"]
        for role in pools:
            action = self.autoscaler.decide(
                self._signals(now, role=role if split else None), role=role)
            if action is None:
                continue
            kind, victim = action
            if kind == "up":
                self._scale_up(now, role=role if split else None)
            elif sum(1 for i in self.instances.values()
                     if not split or i.role == role) > 1:
                self._scale_down(victim, now)

    def _sim_handoffs(self, src: SimInstance, now: float):
        """Prefill→decode handoff, sim analogue of
        ``serving.handoff.drive_handoffs``: scheduler-level
        release/adopt (same progress-preserving path as ``_scale_down``,
        no KV bytes to move) with the wire time priced by
        ``CostModel.transfer_time``; refused requests are stranded for
        colocated decode and re-offered with exponential backoff up to
        ``handoff_retry_cap`` attempts (then permanently colocated) —
        the same :meth:`BatchScheduler.handoff_offers` /
        :meth:`~BatchScheduler.note_strand` control as the real driver.
        An injected transfer fault fails the sweep's gathered transfer
        losslessly: every offer strands, nothing moves."""
        cap = self.cfg.handoff_retry_cap
        ready = src.sched.handoff_offers(cap)
        if not ready:
            return
        faulted = (self.faults is not None
                   and self.faults.transfer_fault(src.instance_id, now)
                   is not None)
        targets = [] if faulted else sorted(
            (i for i in self.instances.values()
             if i is not src and i.role != "prefill"
             and not (now < self.dispatcher.instances[
                 i.instance_id].fenced_until)),
            key=lambda i: (i.role != "decode",
                           -(i.bm.free_blocks + i.bm.cached_blocks)))
        for req in ready:
            tgt = next((t for t in targets if t.sched.can_adopt(req)), None)
            if tgt is None:
                fresh = req.req_id not in src.sched.stranded
                permanent = src.sched.note_strand(req, cap)
                if fresh:
                    self.n_stranded += 1
                    src.sched.allow_colocated_decode(req)
                else:
                    self.n_strand_retries += 1
                if self.tracer.enabled:
                    self.tracer.emit(
                        "handoff-strand", req_id=req.req_id,
                        instance_id=src.instance_id, agent=req.agent_name,
                        msg_id=req.msg_id, ts=now,
                        attempts=src.sched.strand_attempts[req.req_id],
                        permanent=permanent)
                continue
            n_resident = req.prefilled_len + req.output_len
            dt = self.cfg.cost.transfer_time(n_resident)
            src.sched.release(req)
            tgt.sched.adopt(req, now + dt)
            req.instance_id = tgt.instance_id
            self.dispatcher.adopt_ramp(
                tgt.instance_id, req.req_id,
                self.dispatcher.instances[src.instance_id].ramps.pop(
                    req.req_id, None))
            self.n_handoffs += 1
            if not tgt.busy:
                self._push(now + dt, "instance_step", tgt.instance_id)
                tgt.busy = True
            if self.tracer.enabled:
                self.tracer.emit(
                    "handoff-start", req_id=req.req_id,
                    instance_id=src.instance_id, agent=req.agent_name,
                    msg_id=req.msg_id, ts=now, to=tgt.instance_id,
                    n_blocks=src.bm.blocks_needed(n_resident),
                    n_bytes=n_resident * self.cfg.cost.kv_bytes_per_token)
                self.tracer.emit(
                    "handoff-complete", req_id=req.req_id,
                    instance_id=tgt.instance_id, agent=req.agent_name,
                    msg_id=req.msg_id, ts=now + dt, src=src.instance_id,
                    cached=0)

    # ------------------------------------------------------------------ agents
    def _request_rng(self, wf: WorkflowState, agent: str) -> np.random.Generator:
        """Deterministic per-(workflow, agent, hop) RNG so the sampled
        workload is IDENTICAL across policies and across processes
        (zlib.crc32 — python str hash() is salted per process)."""
        key = zlib.crc32(
            f"{self.cfg.seed}|{wf.msg_id}|{agent}|{wf.hops}".encode())
        return np.random.default_rng(key)

    def _spawn_request(self, wf: WorkflowState, agent: str,
                       upstream: Optional[str], now: float):
        prof = wf.app.agents[agent]
        rng = self._request_rng(wf, agent)
        req = Request(
            agent_name=agent, msg_id=wf.msg_id, upstream_name=upstream,
            app_name=wf.app.name,
            prompt_len=prof.sample_prompt_len(rng),
            true_output_len=prof.sample_output_len(rng),
            max_new_tokens=10 ** 9,
            shared_prefix_len=prof.system_prompt_len,
            cache_key=f"{wf.app.name}|{agent}",
            arrival_time=now, app_start_time=wf.start_time)
        wf.outstanding += 1
        wf.hops += 1
        wf.requests.append(req)
        self.balancer.enqueue(req)
        self._arm_balancer(now)

    def _on_request_finished(self, req: Request, now: float):
        # unwind any crash-recovery identity BEFORE booking (no replayed
        # tokens exist in the sim, but the record must retire)
        self.recovery.on_finish(req)
        wf = self.workflows[req.msg_id]
        wf.outstanding -= 1
        wf.total_tokens += req.output_len
        self.finished_requests.append(req)
        self.dispatcher.on_finish(req.instance_id, req.req_id)
        self.orch.on_completion(CompletionRecord(
            agent_name=req.agent_name, msg_id=req.msg_id,
            upstream_name=req.upstream_name, app_name=req.app_name,
            start_time=req.arrival_time, end_time=now,
            prompt_len=req.prompt_len, output_len=req.output_len,
            exec_start_time=req.exec_start_time,
            first_token_time=req.first_token_time))
        downstream = wf.app.route(req.agent_name, self._request_rng(wf, req.agent_name), wf.hops)
        for agent in downstream:
            self._spawn_request(wf, agent, req.agent_name, now + AGENT_OVERHEAD)
        if not downstream and wf.outstanding == 0:
            wf.done_time = now
            self.orch.on_workflow_complete(wf.msg_id)

    # ------------------------------------------------------------------ run
    def run(self) -> SimResults:
        cfg = self.cfg
        if cfg.arrivals is not None:
            # explicit trace replay: (t, app_idx) pairs, verbatim
            for t, app_idx in cfg.arrivals:
                self._push(float(t), "workflow_arrival", int(app_idx))
        else:
            # workflow arrivals, interleaving apps uniformly
            arrivals = arrival_times(self.rng, cfg.rate, cfg.duration)
            for t in arrivals:
                self._push(float(t), "workflow_arrival", None)
        self._now = 0.0
        if self.autoscaler is not None:
            self._push(cfg.autoscale.decision_period_s, "autoscale", None)

        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            self._now = t
            if kind == "workflow_arrival":
                wf_idx = next(self._msg_counter)
                app = cfg.apps[(payload if payload is not None else wf_idx)
                               % len(cfg.apps)]
                msg_id = f"wf-{wf_idx}"
                wf = WorkflowState(msg_id, app, t)
                self.workflows[msg_id] = wf
                self._spawn_request(wf, app.entry, None, t)
            elif kind == "balancer":
                self._balancer_armed = False
                # OOM feedback from instances (§6 adaptive measure)
                for inst in self.instances.values():
                    if inst.recent_oom:
                        inst.recent_oom = False
                        self.dispatcher.on_oom(inst.instance_id, t)
                if self.shedder is not None:
                    self._shed_sweep(t)
                self.balancer.tick(t)
                if self.balancer.queued:
                    self._arm_balancer(t + BALANCER_PERIOD)
            elif kind == "autoscale":
                self._autoscale_tick(t)
                # keep deciding while the system is live; stop re-arming
                # once all work has drained so the event loop terminates
                if (self._events or self.balancer.queued
                        or self.recovery.pending
                        or any(i.has_work for i in self.instances.values())):
                    self._push(t + cfg.autoscale.decision_period_s,
                               "autoscale", None)
            elif kind == "recovery":
                # a reconstructed request's backoff expired: re-queue it
                self.recovery.tick(self, t)
                self._arm_balancer(t)
            elif kind == "instance_step":
                inst = self.instances.get(payload)
                if inst is None:
                    continue   # instance was scaled away; its work moved
                if inst.role == "prefill":
                    # between iterations — the only legal transfer point,
                    # same as the real cluster's post-collect sweep
                    self._sim_handoffs(inst, t)
                elif inst.role == "decode" and inst.sched.waiting:
                    # decode-side preemptions re-enter via the balancer
                    # (phase reset by the preemption) — the role gate
                    # would never re-admit them locally
                    for req in list(inst.sched.waiting):
                        inst.sched.release(req)
                        self.dispatcher.instances[
                            inst.instance_id].ramps.pop(req.req_id, None)
                        self.balancer.enqueue(req)
                    self._arm_balancer(t)
                try:
                    finished, dt = inst.step(t)
                except InstanceCrashed:
                    # injected crash mid-iteration: the pool is gone with
                    # the instance; reconstruct victims from host truth
                    self._on_crash(inst, t)
                    continue
                if dt is None:
                    inst.busy = False
                else:
                    for r in finished:
                        self._on_request_finished(r, t + dt)
                    self._push(t + dt, "instance_step", payload)
                    if finished and self.balancer.queued:
                        self._arm_balancer(t + dt)
        for iid, t0 in self._spawn_time.items():
            self.instance_seconds += self._now - t0

        # ---- metrics ---------------------------------------------------------
        warm_t = cfg.duration * cfg.warmup_frac
        wfs = [w for w in self.workflows.values()
               if w.done_time >= 0 and w.start_time >= warm_t]
        n_total = sum(1 for w in self.workflows.values()
                      if w.start_time >= warm_t)
        reqs = [r for r in self.finished_requests if r.arrival_time >= warm_t]
        qsum = sum(max(r.queueing_time(), 0.0) for r in reqs if not math.isnan(r.queueing_time()))
        esum = sum(r.e2e_latency for r in reqs if r.finish_time >= 0)
        return SimResults(
            workflows=wfs,
            requests=reqs,
            n_preempted=sum(i.n_preempted for i in self._all_instances),
            queueing_ratio=qsum / max(esum, 1e-9),
            policy=cfg.policy,
            prefill_tokens_total=sum(i.prefill_tokens_total
                                     for i in self._all_instances),
            prefill_tokens_saved=sum(i.prefill_tokens_saved
                                     for i in self._all_instances),
            n_migrated=sum(i.sched.stats.n_migrated_in
                           for i in self._all_instances),
            instance_seconds=self.instance_seconds,
            n_handoffs=self.n_handoffs,
            n_stranded=self.n_stranded,
            n_strand_retries=self.n_strand_retries,
            n_crashes=self.recovery.n_crashes,
            n_reconstructed=self.recovery.n_reconstructed,
            n_shed=self.shedder.n_shed if self.shedder else 0,
            n_lost=self.recovery.n_failed,
            n_workflows_total=n_total,
            scale_history=(list(self.autoscaler.history)
                           if self.autoscaler else []),
        )


def run_policy(apps, policy: str, **kw) -> SimResults:
    cfg = SimConfig(apps=apps, policy=policy, **kw)
    return Simulation(cfg).run()
