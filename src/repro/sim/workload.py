"""Multi-agent application workloads (§2.1) + arrival trace generation.

The three benchmark applications (Fig. 2) are encoded declaratively; the
per-agent output-length distributions are lognormals whose parameters are
matched to the inter-agent ratios reported in Figs. 3 & 5 (e.g. the QA
Router's ~20-token routing decisions vs. the Humanities agent's long-form
answers — up to ~25x latency spread).  Dataset "groups" (G+M / M+W / S+S
etc.) perturb those parameters the way the paper's datasets do (§7.2,
e.g. SocialIQA shortens HumanitiesAgent outputs).

Arrivals follow a Gamma-renewal process with CV > 1 (bursty), matching
the shape of the production trace the paper samples [Splitwise, ISCA'24],
scaled to a target request rate.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List

import numpy as np


@dataclasses.dataclass(frozen=True)
class AgentProfile:
    name: str
    out_mu: float                  # lognormal params of output length
    out_sigma: float
    prompt_mu: float = 5.0         # lognormal of prompt length (~150 tok)
    prompt_sigma: float = 0.4
    system_prompt_len: int = 0     # tokens of the agent's fixed preamble —
    #                                identical across calls, so engines with
    #                                prefix caching serve them from shared KV

    def sample_output_len(self, rng: np.random.Generator) -> int:
        return max(2, int(rng.lognormal(self.out_mu, self.out_sigma)))

    def sample_prompt_len(self, rng: np.random.Generator) -> int:
        unique = max(8, int(rng.lognormal(self.prompt_mu, self.prompt_sigma)))
        return self.system_prompt_len + unique


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """Declarative workflow: route(agent, rng, hops) -> downstream agents."""
    name: str
    agents: Dict[str, AgentProfile]
    entry: str
    route: Callable[[str, np.random.Generator, int], List[str]]
    kind: str = ""                                 # branching|sequential|feedback


# --------------------------------------------------------------------------- #
# Question Answer — dynamic branching (Fig. 2a)
# --------------------------------------------------------------------------- #
def _qa(group: str) -> AppSpec:
    # group tweaks: S+S -> shorter humanities outputs (SocialIQA, §7.2)
    hum_mu = {"G+M": math.log(380), "M+W": math.log(340), "S+S": math.log(150)}[group]
    math_mu = {"G+M": math.log(230), "M+W": math.log(260), "S+S": math.log(200)}[group]
    agents = {
        "Router": AgentProfile("Router", math.log(16), 0.35),
        "MathAgent": AgentProfile("MathAgent", math_mu, 0.55),
        "HumanitiesAgent": AgentProfile("HumanitiesAgent", hum_mu, 0.6),
    }

    def route(agent, rng, hops):
        if agent == "Router":
            return ["MathAgent"] if rng.random() < 0.5 else ["HumanitiesAgent"]
        return []

    return AppSpec(f"QA[{group}]", agents, "Router", route, "branching")


# --------------------------------------------------------------------------- #
# Report Generate — sequential (Fig. 2b)
# --------------------------------------------------------------------------- #
def _rg(group: str) -> AppSpec:
    res_mu = {"TQ": math.log(420), "NCD": math.log(330), "NQ": math.log(300)}[group]
    wri_mu = {"TQ": math.log(540), "NCD": math.log(460), "NQ": math.log(420)}[group]
    agents = {
        "ResearchAgent": AgentProfile("ResearchAgent", res_mu, 0.45),
        "WriterAgent": AgentProfile("WriterAgent", wri_mu, 0.4, prompt_mu=6.0),
    }

    def route(agent, rng, hops):
        return ["WriterAgent"] if agent == "ResearchAgent" else []

    return AppSpec(f"RG[{group}]", agents, "ResearchAgent", route, "sequential")


# --------------------------------------------------------------------------- #
# Code Generate — dynamic feedback (Fig. 2c)
# --------------------------------------------------------------------------- #
def _cg(group: str) -> AppSpec:
    eng_mu = {"HE": math.log(520), "MBPP": math.log(380), "APPS": math.log(640)}[group]
    retry_p = {"HE": 0.30, "MBPP": 0.25, "APPS": 0.45}[group]
    agents = {
        "ProductManager": AgentProfile("ProductManager", math.log(260), 0.4),
        "Architect": AgentProfile("Architect", math.log(340), 0.45),
        "ProjectManager": AgentProfile("ProjectManager", math.log(170), 0.4),
        "Engineer": AgentProfile("Engineer", eng_mu, 0.5, prompt_mu=6.2),
        "QAEngineer": AgentProfile("QAEngineer", math.log(290), 0.45, prompt_mu=6.0),
    }
    chain = {"ProductManager": "Architect", "Architect": "ProjectManager",
             "ProjectManager": "Engineer", "Engineer": "QAEngineer"}

    def route(agent, rng, hops):
        if agent in chain:
            return [chain[agent]]
        if agent == "QAEngineer":
            # evaluation failed -> feed back to the Engineer (bounded loop)
            if hops < 12 and rng.random() < retry_p:
                return ["Engineer"]
        return []

    return AppSpec(f"CG[{group}]", agents, "ProductManager", route, "feedback")


# dataset groups per the paper (§2.1.3): Group1/2/3 per app
QA_GROUPS = ("G+M", "M+W", "S+S")
RG_GROUPS = ("TQ", "NCD", "NQ")
CG_GROUPS = ("HE", "MBPP", "APPS")


def make_app(app: str, group: str) -> AppSpec:
    return {"QA": _qa, "RG": _rg, "CG": _cg}[app](group)


def with_shared_prefixes(app: AppSpec, system_prompt_len: int) -> AppSpec:
    """Variant of ``app`` whose every agent carries a fixed
    ``system_prompt_len``-token preamble (the shared-prefix reuse
    scenario: same agent prompt resent on every call)."""
    agents = {n: dataclasses.replace(p, system_prompt_len=system_prompt_len)
              for n, p in app.agents.items()}
    return dataclasses.replace(app, agents=agents)


def colocated_apps() -> List[AppSpec]:
    """§7.3 co-location workload: QA[G+M] + RG[TQ] + CG[HE]."""
    return [make_app("QA", "G+M"), make_app("RG", "TQ"), make_app("CG", "HE")]


# --------------------------------------------------------------------------- #
# arrivals
# --------------------------------------------------------------------------- #
def arrival_times(rng: np.random.Generator, rate: float, duration: float,
                  cv: float = 1.6) -> np.ndarray:
    """Bursty Gamma-renewal arrivals at `rate` req/s for `duration` s.

    cv > 1 mimics the heavy-tailed inter-arrival distribution of the
    production trace [41] that the paper proportionally samples."""
    shape = 1.0 / (cv ** 2)
    scale = 1.0 / (rate * shape)
    n = int(rate * duration * 2) + 16
    gaps = rng.gamma(shape, scale, n)
    t = np.cumsum(gaps)
    return t[t < duration]
