from repro.sim.cost_model import COST_MODELS, LLAMA2_13B, LLAMA3_8B, CostModel
from repro.sim.simulator import SimConfig, SimInstance, SimResults, Simulation, run_policy
from repro.sim.workload import (
    AgentProfile,
    AppSpec,
    arrival_times,
    colocated_apps,
    make_app,
    with_shared_prefixes,
)

__all__ = ["COST_MODELS", "LLAMA2_13B", "LLAMA3_8B", "CostModel", "SimConfig",
           "SimInstance", "SimResults", "Simulation", "run_policy",
           "AgentProfile", "AppSpec", "arrival_times", "colocated_apps", "make_app",
           "with_shared_prefixes"]
