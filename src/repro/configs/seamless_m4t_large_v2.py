"""SeamlessM4T-large-v2 — [audio] enc-dec, 24L decoder + 24L encoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206. [arXiv:2308.11596]

The w2v-BERT speech frontend (mel-spectrogram + conv feature extractor)
is the sanctioned stub: ``input_specs()`` provides precomputed frame
embeddings of shape (batch, enc_len, d_model).  train/prefill shapes
split seq_len as enc_len = dec_len = seq_len // 2 (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,             # decoder
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    frontend="audio_frames",
    source="arXiv:2308.11596",
)
