"""Gemma3-27B — [dense] 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144; 5:1 local(sliding 1024):global attention, 128k context.
head_dim fixed at 128 (gemma3 decouples it from d_model).
[hf:google/gemma-3-1b-pt family card]

long_500k applies: 5/6 of layers have window-bounded KV; global layers
keep full-context KV (see models/attention.py).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21_504,
    vocab_size=262_144,
    qk_norm=True,
    sliding_window=1024,
    global_attn_every=6,   # layers with index % 6 == 5 are global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
