"""Qwen1.5-MoE-A2.7B — [moe] 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]

Sharding note (DESIGN.md §5): 60 experts % 16 != 0 -> experts are
tensor-parallel (d_expert 1408 = 16*88) instead of expert-parallel.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151_936,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        d_expert=1408,
        num_shared_experts=4,
        d_shared=5632,
        moe_layer_period=1,
    ),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
