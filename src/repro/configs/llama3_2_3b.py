"""Llama-3.2-3B — [dense] 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256, rope_theta=500k. [hf:meta-llama/Llama-3.2-1B family]

Sharding note: 24 heads % 16 != 0 -> GSPMD pads the head dim on the
model axis; KV heads (8) are replicated across model shards.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128_256,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-1B",
)
