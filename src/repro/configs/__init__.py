from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    all_configs,
    get_config,
    shape_applicable,
)

__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "ModelConfig",
    "MoEConfig",
    "ShapeConfig",
    "all_configs",
    "get_config",
    "shape_applicable",
]
