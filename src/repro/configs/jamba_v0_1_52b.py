"""Jamba-v0.1-52B — [hybrid] 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
Mamba:attention 7:1 interleave (attn at index 4 of each 8-layer block),
MoE 16 experts top-2 on every second layer. [arXiv:2403.19887]

long_500k applies: 28/32 layers carry O(1) Mamba state; the 4 attention
layers keep full KV (batch=1, seq sharded over `data`).
"""
from repro.configs.base import ModelConfig, MoEConfig

_PATTERN = tuple("attn" if (i % 8) == 4 else "mamba" for i in range(32))

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=65_536,
    layer_pattern=_PATTERN,
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        d_expert=14_336,
        moe_layer_period=2,    # MoE on odd layers
    ),
    ssm_state_dim=16,
    ssm_expand=2,
    ssm_conv_dim=4,
    source="arXiv:2403.19887",
)
