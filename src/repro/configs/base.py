"""Config system: model architecture configs + input-shape configs.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` module
exporting ``CONFIG``; they register themselves here.  The FULL configs are
only ever lowered via the dry-run (ShapeDtypeStruct, no allocation); smoke
tests use ``reduced()`` variants.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # ffn hidden dim of each routed expert
    num_shared_experts: int = 0   # always-on experts (qwen2-moe style)
    d_shared: int = 0             # ffn hidden of the shared expert block
    moe_layer_period: int = 1     # apply MoE every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # attention variants -----------------------------------------------------
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # window size for local layers
    global_attn_every: int = 0             # gemma3: 1 global per k+1 layers (5 local : 1 global -> 6)
    rope_theta: float = 10_000.0
    # layer pattern ----------------------------------------------------------
    # per-layer block kind; None -> all "attn" (or all "rwkv" for ssm family)
    layer_pattern: Optional[Tuple[str, ...]] = None  # entries: attn|mamba|rwkv
    # moe --------------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    # ssm --------------------------------------------------------------------
    ssm_state_dim: int = 16       # mamba N
    ssm_expand: int = 2           # mamba d_inner = expand * d_model
    ssm_conv_dim: int = 4
    rwkv_head_dim: int = 64
    # enc-dec ----------------------------------------------------------------
    num_encoder_layers: int = 0
    # modality frontend stub -------------------------------------------------
    frontend: str = "none"        # none | audio_frames | vision_patches
    # numerics ---------------------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # provenance
    source: str = ""

    # --- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        if self.layer_pattern is not None:
            assert len(self.layer_pattern) == self.num_layers
            return self.layer_pattern
        if self.family == "ssm":
            return tuple("rwkv" for _ in range(self.num_layers))
        return tuple("attn" for _ in range(self.num_layers))

    @property
    def attn_layer_indices(self) -> Tuple[int, ...]:
        return tuple(i for i, k in enumerate(self.layer_kinds) if k == "attn")

    @property
    def is_encdec(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can decode at 500k context (O(1)/windowed state)."""
        kinds = set(self.layer_kinds)
        if kinds <= {"rwkv", "mamba"}:
            return True
        if "mamba" in kinds or "rwkv" in kinds:
            return True  # hybrid: attention layers are the minority; still runnable
        if self.sliding_window is not None:
            return True  # windowed KV bounds the cache (global layers capped, see models/attention.py)
        return False

    def moe_layer_indices(self) -> Tuple[int, ...]:
        if self.moe is None:
            return ()
        p = self.moe.moe_layer_period
        return tuple(i for i in range(self.num_layers) if (i % p) == (p - 1))

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        moe_layers = set(self.moe_layer_indices())
        for i, kind in enumerate(self.layer_kinds):
            if kind == "attn":
                n += d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            elif kind == "mamba":
                di = self.ssm_expand * d
                n += 2 * d * di + di * d + di * (2 * self.ssm_state_dim + self.ssm_conv_dim + 2)
            elif kind == "rwkv":
                n += 4 * d * d + d * d  # r,k,v,g,o projections (~5 d^2) + decay params
            if self.moe is not None and i in moe_layers:
                n += self.moe.num_experts * 3 * d * self.moe.d_expert
                n += self.moe.num_shared_experts * 3 * d * max(self.moe.d_shared, self.moe.d_expert)
                n += d * self.moe.num_experts
            elif kind != "mamba":
                n += 3 * d * self.d_ff
        if self.is_encdec:
            # encoder blocks (self-attn + ffn) + decoder cross-attn
            enc = self.num_encoder_layers * (4 * d * hd * self.num_heads + 3 * d * self.d_ff)
            xattn = self.num_layers * (d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d)
            n += enc + xattn
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        moe_layers = len(self.moe_layer_indices())
        d = self.d_model
        inactive = (self.moe.num_experts - self.moe.top_k) * 3 * d * self.moe.d_expert * moe_layers
        return full - inactive

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """Per-token KV growth — the slope `k` of the paper's Eq. 1 (per seq)."""
        n_attn = len(self.attn_layer_indices)
        return 2 * n_attn * self.num_kv_heads * self.resolved_head_dim * bytes_per_el

    def state_bytes(self, bytes_per_el: int = 4) -> int:
        """Constant recurrent-state footprint per sequence (SSM/hybrid)."""
        d = self.d_model
        total = 0
        for kind in self.layer_kinds:
            if kind == "mamba":
                di = self.ssm_expand * d
                total += di * self.ssm_state_dim + di * self.ssm_conv_dim
            elif kind == "rwkv":
                heads = d // self.rwkv_head_dim
                total += heads * self.rwkv_head_dim * self.rwkv_head_dim + 2 * d
        return total * bytes_per_el

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests (<=2 layers, d<=512)."""
        kinds = self.layer_kinds
        # keep at most 2 layers but preserve the kind diversity (hybrid!)
        if len(set(kinds)) > 1:
            order = []
            for k in ("mamba", "attn", "rwkv"):
                if k in kinds:
                    order.append(k)
            pat: Tuple[str, ...] = tuple(order[:2]) if len(order) >= 2 else (kinds[0],) * 2
        else:
            pat = (kinds[0],) * 2
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2),
                d_expert=128, d_shared=128 if self.moe.num_shared_experts else 0,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                moe_layer_period=1)
        n_heads = min(self.num_heads, 4) if self.num_heads else 0
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            d_model=256,
            num_heads=n_heads,
            num_kv_heads=min(self.num_kv_heads, max(n_heads // 2, 1)) if n_heads else 0,
            head_dim=64 if n_heads else 0,
            d_ff=512,
            vocab_size=512,
            layer_pattern=pat,
            moe=moe,
            sliding_window=64 if self.sliding_window else None,
            global_attn_every=min(self.global_attn_every, 2) if self.global_attn_every else 0,
            num_encoder_layers=2 if self.is_encdec else 0,
            rwkv_head_dim=64,
        )


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "qwen2-moe-a2.7b",
    "chameleon-34b",
    "gemma3-27b",
    "seamless-m4t-large-v2",
    "rwkv6-3b",
    "stablelm-3b",
    "llama3.2-3b",
    "jamba-v0.1-52b",
    "kimi-k2-1t-a32b",
    "qwen3-1.7b",
)

_MODULE_FOR = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(_MODULE_FOR[arch_id])
    return mod.CONFIG


def all_configs() -> Sequence[ModelConfig]:
    return [get_config(a) for a in ARCH_IDS]


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Whether (arch, shape) is a supported dry-run combination (see DESIGN.md §4)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True
