"""RWKV6-3B (Finch) — [ssm] 32L d_model=2560 attention-free d_ff=8960
vocab=65536; data-dependent per-channel decay, matrix-valued WKV state.
[arXiv:2404.05892]

O(1) decode state -> long_500k applies; the paper's Eq.1 KV ramp
degenerates to a constant (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=8960,
    vocab_size=65_536,
    rwkv_head_dim=64,          # 40 heads of 64
    source="arXiv:2404.05892",
)
