"""Chameleon-34B — [vlm] 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early-fusion: images are discrete VQ tokens inside the same
65536 vocab, so the backbone consumes plain token ids. The VQ-GAN image
tokenizer is the sanctioned frontend stub. [arXiv:2405.09818]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_016,
    vocab_size=65_536,
    qk_norm=True,  # chameleon uses QK-norm for training stability
    frontend="vision_patches",
    source="arXiv:2405.09818",
)
