"""StableLM-3B — [dense] 32L d_model=2560 32H (GQA kv=32, i.e. MHA)
d_ff=6912 vocab=50304. [hf:stabilityai/stablelm-2-1_6b family]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50_304,
    source="hf:stabilityai/stablelm-2-1_6b",
)
