"""Kimi-K2 1T-A32B — [moe] 61L d_model=7168 64H (GQA kv=8) routed-expert
d_ff=2048 vocab=163840, MoE 384 experts top-8 + 1 shared expert.
Trillion-param paper-table config. [arXiv:2501.kimi2]

Sharding note: 384 experts % 16 == 0 -> expert-parallel over the `model`
axis (24 experts/shard); training uses Adafactor + FSDP over `data`
(DESIGN.md §5) — honest memory numbers in EXPERIMENTS.md §Dry-run.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163_840,
    moe=MoEConfig(
        num_experts=384,
        top_k=8,
        d_expert=2048,
        num_shared_experts=1,
        d_shared=2048,
        moe_layer_period=1,
    ),
    source="arXiv:2501.kimi2",
)
