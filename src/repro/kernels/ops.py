"""jit'd public wrappers around the Pallas kernels.

On TPU the Pallas path compiles natively; in this CPU container the kernel
body executes under ``interpret=True``.  ``backend="ref"`` selects the
pure-jnp oracle (used by the serving engine on CPU for speed — interpret
mode is a correctness tool, not fast).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.paged_attention import paged_attention as _paged_pallas


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_attention(q, k_pool, v_pool, block_tables, context_lens, backend: str = "ref"):
    """Decode attention over a paged KV pool.  See kernels/ref.py for shapes."""
    if backend == "pallas":
        return _paged_pallas(q, k_pool, v_pool, block_tables, context_lens)
    if backend == "interpret":
        return _paged_pallas(q, k_pool, v_pool, block_tables, context_lens, interpret=True)
    return _ref.paged_attention_ref(q, k_pool, v_pool, block_tables, context_lens)
