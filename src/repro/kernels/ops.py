"""jit'd public wrappers around the Pallas kernels.

On TPU the Pallas path compiles natively; in this CPU container the kernel
body executes under ``interpret=True``.  ``backend="ref"`` selects the
pure-jnp oracle (used by the serving engine on CPU for speed — interpret
mode is a correctness tool, not fast).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.paged_attention import paged_attention as _paged_pallas
from repro.kernels.ragged_attention import (
    ragged_segment_attention as _ragged_pallas,
)


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_attention(q, k_pool, v_pool, block_tables, context_lens, backend: str = "ref"):
    """Decode attention over a paged KV pool.  See kernels/ref.py for shapes."""
    if backend == "pallas":
        return _paged_pallas(q, k_pool, v_pool, block_tables, context_lens)
    if backend == "interpret":
        return _paged_pallas(q, k_pool, v_pool, block_tables, context_lens, interpret=True)
    if backend != "ref":
        raise ValueError(f"unknown paged attention backend {backend!r}")
    return _ref.paged_attention_ref(q, k_pool, v_pool, block_tables, context_lens)


@functools.partial(jax.jit, static_argnames=("backend",))
def ragged_segment_attention(q, k_pool, v_pool, block_tables, positions,
                             backend: str = "ref"):
    """Segment-blocked causal attention over a paged pool for the prefill
    part of a fused :class:`~repro.serving.batch_scheduler.IterationBatch`
    — every chunk's tokens tiled to (S, L).  See ``kernels/ref.py`` for
    shapes and mask semantics.

    Backends
    --------
    ``"pallas"`` / ``"interpret"``
        The native segment-tiled kernel (``kernels/ragged_attention.py``):
        grid (segment, kv_head, kv_page), scalar-prefetched per-segment
        block tables, (L, hd) query tiles with online-softmax scratch,
        and per-segment page bounds so a segment only visits pages up to
        ``max(positions) // bs``.
    ``"flat"`` / ``"flat_interpret"`` / ``"flat_ref"``
        The PR 3 flatten-and-repeat lowering onto the single-query paged
        *decode* path — S·L query rows, block tables repeated per row,
        each row's context length set to ``position + 1`` — kept as the
        differential-testing reference for the native kernel (the suffix
        picks which decode backend executes it).
    ``"ref"``
        Pure-jnp oracle with the same segment-bounded page gather as the
        native kernel.
    """
    if q.size == 0:        # absent prefill part (decode-only iteration):
        return q           # every backend must no-op, not trace 0 rows
    if backend in ("pallas", "interpret"):
        return _ragged_pallas(q, k_pool, v_pool, block_tables, positions,
                              interpret=backend == "interpret")
    if backend in ("flat", "flat_interpret", "flat_ref"):
        s, lq, kv, g, hd = q.shape
        flat_q = q.reshape(s * lq, kv, g, hd)
        flat_bt = jnp.repeat(block_tables, lq, axis=0)
        flat_cl = positions.reshape(-1) + 1
        if backend == "flat_ref":
            out = _ref.paged_attention_ref(flat_q, k_pool, v_pool,
                                           flat_bt, flat_cl)
        else:
            out = _paged_pallas(flat_q, k_pool, v_pool, flat_bt, flat_cl,
                                interpret=backend == "flat_interpret")
        return out.reshape(s, lq, kv, g, hd)
    if backend != "ref":
        # a typo'd backend must not silently compile the dense jnp oracle
        # into a device hot loop (token-identical, so nothing else catches it)
        raise ValueError(f"unknown ragged attention backend {backend!r}")
    return _ref.ragged_segment_attention_ref(
        q, k_pool, v_pool, block_tables, positions)
