"""jit'd public wrappers around the Pallas kernels.

On TPU the Pallas path compiles natively; in this CPU container the kernel
body executes under ``interpret=True``.  ``backend="ref"`` selects the
pure-jnp oracle (used by the serving engine on CPU for speed — interpret
mode is a correctness tool, not fast).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.paged_attention import paged_attention as _paged_pallas


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_attention(q, k_pool, v_pool, block_tables, context_lens, backend: str = "ref"):
    """Decode attention over a paged KV pool.  See kernels/ref.py for shapes."""
    if backend == "pallas":
        return _paged_pallas(q, k_pool, v_pool, block_tables, context_lens)
    if backend == "interpret":
        return _paged_pallas(q, k_pool, v_pool, block_tables, context_lens, interpret=True)
    return _ref.paged_attention_ref(q, k_pool, v_pool, block_tables, context_lens)


@functools.partial(jax.jit, static_argnames=("backend",))
def ragged_segment_attention(q, k_pool, v_pool, block_tables, positions,
                             backend: str = "ref"):
    """Segment-blocked causal attention over a paged pool for the prefill
    part of a fused :class:`~repro.serving.batch_scheduler.IterationBatch`
    — every chunk's tokens tiled to (S, L).  See ``kernels/ref.py`` for
    shapes and mask semantics.

    The ragged mask lowers exactly onto the paged *decode* kernel:
    flattening the (S, L) tile to S*L query rows, repeating each
    segment's block table per row, and setting each row's context length
    to ``position + 1`` turns the segment-blocked causal mask into the
    kernel's ordinary context-length mask — so the same Pallas kernel
    serves single-token decode and fused mixed iterations, with no
    second kernel to maintain.
    """
    if backend in ("pallas", "interpret"):
        s, lq, kv, g, hd = q.shape
        out = _paged_pallas(q.reshape(s * lq, kv, g, hd), k_pool, v_pool,
                            jnp.repeat(block_tables, lq, axis=0),
                            positions.reshape(-1) + 1,
                            interpret=backend == "interpret")
        return out.reshape(s, lq, kv, g, hd)
    return _ref.ragged_segment_attention_ref(
        q, k_pool, v_pool, block_tables, positions)
