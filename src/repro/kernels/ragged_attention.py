"""Pallas TPU ragged segment-attention kernel (native, segment-tiled).

The prefill half of a fused :class:`~repro.serving.batch_scheduler.
IterationBatch`: each prompt chunk ("segment") is a dense (L,) tile of
queries at arbitrary absolute positions, attending its own sequence's
pool-resident KV through a per-segment block table under a
segment-blocked causal mask.

PR 3 lowered this by flatten-and-repeat onto the single-query *decode*
kernel: S·L grid rows, the segment's block table repeated per query row,
and one (1, 1, G, hd) query tile per MXU step — every page of a chunk's
context re-gathered once per query token, and the MXU fed single-token
tiles exactly where Sarathi-style chunked prefill concentrates work.
This kernel is the native formulation:

* **grid (segment, kv_head, kv_page)** — one online-softmax pass per
  (segment, head) pair, pages innermost so each page of a segment's
  context is DMA'd into VMEM exactly ONCE and reduced against the whole
  (L, G, hd) query tile (an (L·G, bs) MXU step instead of L separate
  (G, bs) steps);
* **scalar-prefetched block tables** — like the decode kernel, the
  per-segment table and page bound live in SMEM and feed the BlockSpec
  index maps, so Pallas double-buffers the HBM→VMEM page copies;
* **per-segment page bounds** — a segment only *visits* pages up to
  ``max(positions) // bs``: beyond its bound the k/v index maps clamp to
  the bound page, and consecutive grid steps with an unchanged block
  index issue no new copy (the standard Pallas revisit trick), while
  ``pl.when`` skips the compute.  Short chunks in a batch padded to a
  long table width stop paying bandwidth or MXU time for pages they can
  never attend;
* **online softmax** in fp32 VMEM scratch (running max / denominator),
  identical accumulation scheme to the decode kernel.

Padding query rows (j >= the chunk's real length) carry position 0,
attend token 0 of the (clamped) first page, and produce garbage the
caller discards — they can never NaN (token 0 is always unmasked).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ragged_attn_kernel(block_tables_ref,   # (S, nb) SMEM (scalar prefetch)
                        page_bounds_ref,    # (S,)    SMEM (scalar prefetch)
                        q_ref,              # (1, L, 1, G, hd) VMEM
                        pos_ref,            # (1, L, 1) VMEM
                        k_ref,              # (1, bs, 1, hd) VMEM (gathered page)
                        v_ref,              # (1, bs, 1, hd) VMEM
                        o_ref,              # (1, L, 1, G, hd) VMEM
                        acc_ref,            # (L, G, hd) f32 scratch
                        m_ref,              # (L, G, 1) f32 scratch
                        l_ref,              # (L, G, 1) f32 scratch
                        *, bs: int, nb: int, scale: float):
    s = pl.program_id(0)
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # pages past the segment's bound are never gathered (the index map
    # clamps to the bound page — no new DMA) and never reduced
    @pl.when(n <= page_bounds_ref[s])
    def _compute():
        lq, g = q_ref.shape[1], q_ref.shape[3]
        hd = q_ref.shape[4]
        q = q_ref[0, :, 0].reshape(lq * g, hd).astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bs, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)

        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (L*G, bs)
        scores = scores.reshape(lq, g, bs)
        # segment-blocked causal mask: query (s, j) at absolute position
        # pos[j] sees pool tokens of its own table at indices <= pos[j]
        token_idx = n * bs + jax.lax.broadcasted_iota(
            jnp.int32, (lq, g, bs), 2)
        pos = pos_ref[0]                                      # (L, 1)
        scores = jnp.where(pos[:, :, None] >= token_idx, scores, NEG_INF)

        m_prev = m_ref[...]                                   # (L, G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=2, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)                           # (L, G, bs)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=2, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.reshape(lq * g, bs), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(lq, g, hd)
        m_ref[...] = m_new

    @pl.when(n == nb - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, :, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ragged_segment_attention(q: jnp.ndarray,
                             k_pool: jnp.ndarray,
                             v_pool: jnp.ndarray,
                             block_tables: jnp.ndarray,
                             positions: jnp.ndarray,
                             interpret: bool = False) -> jnp.ndarray:
    """q (S, L, KV, G, hd); pools (N, bs, KV, hd); tables (S, nb);
    positions (S, L) absolute position per query token.  Returns
    (S, L, KV, G, hd).  See ``kernels/ref.py`` for mask semantics."""
    s, lq, kv, g, hd = q.shape
    if q.size == 0:        # absent prefill part (decode-only iteration)
        return q
    _, bs, _, _ = k_pool.shape
    nb = block_tables.shape[1]
    scale = hd ** -0.5
    # last page each segment can attend: max position // bs (padding rows
    # sit at position 0 and never raise the bound)
    page_bounds = jnp.max(positions, axis=1) // bs            # (S,)
    pos3 = positions.reshape(s, lq, 1)

    kernel = functools.partial(_ragged_attn_kernel, bs=bs, nb=nb, scale=scale)
    grid = (s, kv, nb)

    def page_map(ss, h, n, bt, bounds):
        return (bt[ss, jnp.minimum(n, bounds[ss])], 0, h, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, lq, 1, g, hd),
                             lambda ss, h, n, bt, bounds: (ss, 0, h, 0, 0)),
                pl.BlockSpec((1, lq, 1),
                             lambda ss, h, n, bt, bounds: (ss, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd), page_map),
                pl.BlockSpec((1, bs, 1, hd), page_map),
            ],
            out_specs=pl.BlockSpec(
                (1, lq, 1, g, hd),
                lambda ss, h, n, bt, bounds: (ss, 0, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((lq, g, hd), jnp.float32),
                pltpu.VMEM((lq, g, 1), jnp.float32),
                pltpu.VMEM((lq, g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((s, lq, kv, g, hd), q.dtype),
        interpret=interpret,
    )(block_tables, page_bounds, q, pos3, k_pool, v_pool)
