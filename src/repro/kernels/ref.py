"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every kernel test sweeps shapes and
dtypes and asserts allclose against these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q: jnp.ndarray,
                        k_pool: jnp.ndarray,
                        v_pool: jnp.ndarray,
                        block_tables: jnp.ndarray,
                        context_lens: jnp.ndarray) -> jnp.ndarray:
    """Decode attention over a paged KV cache.

    q:            (B, KV, G, hd)   — grouped queries (H = KV*G)
    k_pool/v_pool:(N_blocks, bs, KV, hd)
    block_tables: (B, max_blocks)  int32 physical block ids
    context_lens: (B,)             int32 valid tokens per sequence
    returns:      (B, KV, G, hd)
    """
    b, kv, g, hd = q.shape
    bs = k_pool.shape[1]
    max_blocks = block_tables.shape[1]
    s_max = max_blocks * bs

    # gather pages -> (B, S_max, KV, hd)
    k = k_pool[block_tables].reshape(b, s_max, kv, hd)
    v = v_pool[block_tables].reshape(b, s_max, kv, hd)

    scores = jnp.einsum("bkgd,btkd->bkgt", q, k).astype(jnp.float32) / (hd ** 0.5)
    valid = jnp.arange(s_max)[None, :] < context_lens[:, None]          # (B, S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def chunked_prefill_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                                  window: int | None = None) -> jnp.ndarray:
    """Causal (optionally sliding-window) attention oracle.

    q (B,S,KV,G,hd); k/v (B,S,KV,hd) -> (B,S,KV,G,hd)
    """
    s = q.shape[1]
    hd = q.shape[-1]
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) / (hd ** 0.5)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v).astype(q.dtype)
