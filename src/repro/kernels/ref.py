"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every kernel test sweeps shapes and
dtypes and asserts allclose against these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q: jnp.ndarray,
                        k_pool: jnp.ndarray,
                        v_pool: jnp.ndarray,
                        block_tables: jnp.ndarray,
                        context_lens: jnp.ndarray) -> jnp.ndarray:
    """Decode attention over a paged KV cache.

    q:            (B, KV, G, hd)   — grouped queries (H = KV*G)
    k_pool/v_pool:(N_blocks, bs, KV, hd)
    block_tables: (B, max_blocks)  int32 physical block ids
    context_lens: (B,)             int32 valid tokens per sequence
    returns:      (B, KV, G, hd)
    """
    b, kv, g, hd = q.shape
    bs = k_pool.shape[1]
    max_blocks = block_tables.shape[1]
    s_max = max_blocks * bs

    # gather pages -> (B, S_max, KV, hd)
    k = k_pool[block_tables].reshape(b, s_max, kv, hd)
    v = v_pool[block_tables].reshape(b, s_max, kv, hd)

    scores = jnp.einsum("bkgd,btkd->bkgt", q, k).astype(jnp.float32) / (hd ** 0.5)
    valid = jnp.arange(s_max)[None, :] < context_lens[:, None]          # (B, S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def ragged_segment_attention_ref(q: jnp.ndarray,
                                 k_pool: jnp.ndarray,
                                 v_pool: jnp.ndarray,
                                 block_tables: jnp.ndarray,
                                 positions: jnp.ndarray) -> jnp.ndarray:
    """Segment-blocked causal attention for a fused ragged iteration batch.

    A fused iteration's prefill chunks ("segments") are tiled into a
    dense (S, L) layout — L is the padded chunk length — so each
    segment's KV pages are gathered ONCE, not once per query token.
    Query (s, j) sits at absolute position ``positions[s, j]`` of its
    sequence and attends the pool-resident KV of *its own* sequence at
    positions ``<= positions[s, j]`` through its segment's block table —
    never across segments.  Fresh KV (this iteration's chunk tokens) must
    already be scattered into the pool: the fused runner writes before
    attending within each layer, so intra-chunk causality and
    same-iteration shared-prefix reads both resolve through the pool.
    Padding rows (j >= the chunk's real length) produce garbage that the
    caller discards.

    q:            (S, L, KV, G, hd) — grouped queries, tiled per segment
    k_pool/v_pool:(N_blocks, bs, KV, hd)
    block_tables: (S, max_blocks)   int32 — one table per segment
    positions:    (S, L)            int32 absolute position per token
    returns:      (S, L, KV, G, hd)

    The gather is *segment-bounded*, mirroring the native Pallas kernel
    (``kernels/ragged_attention.py``): pages past a segment's last
    attendable page (``max(positions) // bs``) are clamped to that bound
    page instead of dereferencing the table's padding entries, so a
    short chunk in a batch padded to a long table width re-reads one
    already-hot page rather than touching cold pool blocks it can never
    attend.  Bounded pages are fully masked either way — the output is
    bit-identical to an unbounded gather.
    """
    s, _, kv, g, hd = q.shape
    if q.size == 0:        # absent prefill part (decode-only iteration)
        return q
    bs = k_pool.shape[1]
    nb = block_tables.shape[1]
    s_max = nb * bs
    bounds = jnp.max(positions, axis=1) // bs                    # (S,)
    page_idx = jnp.minimum(jnp.arange(nb)[None, :], bounds[:, None])
    bt = jnp.take_along_axis(block_tables, page_idx, axis=1)
    k = k_pool[bt].reshape(s, s_max, kv, hd)
    v = v_pool[bt].reshape(s, s_max, kv, hd)
    scores = jnp.einsum("slkgd,stkd->skglt", q, k).astype(jnp.float32) / (hd ** 0.5)
    keep = positions[:, None, None, :, None] >= \
        jnp.arange(s_max)[None, None, None, None, :]
    scores = jnp.where(keep, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("skglt,stkd->slkgd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def chunked_prefill_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                                  window: int | None = None) -> jnp.ndarray:
    """Causal (optionally sliding-window) attention oracle.

    q (B,S,KV,G,hd); k/v (B,S,KV,hd) -> (B,S,KV,G,hd)
    """
    s = q.shape[1]
    hd = q.shape[-1]
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) / (hd ** 0.5)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v).astype(q.dtype)
