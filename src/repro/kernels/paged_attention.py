"""Pallas TPU paged-attention decode kernel (flash-decoding style).

The serving engine's hot loop: one query token per sequence attends to a
paged KV cache (vLLM-style block pool).  TPU adaptation (DESIGN.md §3):
instead of CUDA warp-level gathers, the block table is *scalar-prefetched*
into SMEM and fed to the BlockSpec index maps, so Pallas pipelines the
HBM->VMEM page copies double-buffered while the MXU reduces the previous
page.  Accumulation is the standard running-max/denominator (flash)
reduction in fp32 VMEM scratch.

Grid: (B, KV_heads, num_pages).  Page k/v tiles are (block_size, head_dim)
with head_dim padded/aligned to 128 by the caller (all assigned configs
have head_dim in {64, 112, 128}; 112 is padded by Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(block_tables_ref,   # (B, nb) SMEM (scalar prefetch)
                       context_lens_ref,   # (B,)   SMEM (scalar prefetch)
                       q_ref,              # (1, 1, G, hd) VMEM
                       k_ref,              # (1, bs, 1, hd) VMEM (gathered page)
                       v_ref,              # (1, bs, 1, hd) VMEM
                       o_ref,              # (1, 1, G, hd) VMEM
                       acc_ref,            # (G, hd) f32 scratch
                       m_ref,              # (G, 1) f32 scratch
                       l_ref,              # (G, 1) f32 scratch
                       *, bs: int, nb: int, scale: float):
    b = pl.program_id(0)
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cl = context_lens_ref[b]

    # a page whose first token is already past the context is fully
    # masked: it would contribute alpha=1, p=0 — skipping the dot and
    # accumulate is bit-identical, and short-context rows stop paying
    # MXU time for the padded max-blocks grid
    @pl.when(n * bs < cl)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bs, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)

        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (G, bs)
        token_idx = n * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        scores = jnp.where(token_idx < cl, scores, NEG_INF)

        m_prev = m_ref[...]                                  # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)                          # (G, bs)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(n == nb - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jnp.ndarray,
                    k_pool: jnp.ndarray,
                    v_pool: jnp.ndarray,
                    block_tables: jnp.ndarray,
                    context_lens: jnp.ndarray,
                    interpret: bool = False) -> jnp.ndarray:
    """q (B,KV,G,hd); pools (N, bs, KV, hd); tables (B, nb); lens (B,)."""
    b, kv, g, hd = q.shape
    _, bs, _, _ = k_pool.shape
    nb = block_tables.shape[1]
    scale = hd ** -0.5

    kernel = functools.partial(_paged_attn_kernel, bs=bs, nb=nb, scale=scale)
    grid = (b, kv, nb)

    # page index map: clamp past the sequence's last in-context page
    # ((cl-1)//bs — exactly the pages the kernel's pl.when computes), so
    # grid steps over fully-masked pages revisit the bound page and issue
    # no new HBM->VMEM copy (same trick as the ragged kernel): short-
    # context rows stop paying bandwidth for the padded max-blocks grid,
    # and table padding entries are never dereferenced.  The outer
    # maximum makes the clamp total: cl=0 (every in-repo caller clamps
    # cl>=1, but this is a public entry point) pins page 0 instead of
    # feeding a negative SMEM index to the table
    def page_map(bb, h, n, bt, cl):
        return (bt[bb, jnp.minimum(n, jnp.maximum(cl[bb] - 1, 0) // bs)],
                0, h, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), lambda bb, h, n, bt, cl: (bb, h, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd), page_map),
                pl.BlockSpec((1, bs, 1, hd), page_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd), lambda bb, h, n, bt, cl: (bb, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, hd), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, q, k_pool, v_pool)
