"""Workload generators: trace synthesis shared by sim and real cluster."""
from repro.workloads.traces import Trace, TraceConfig, generate_trace

__all__ = ["Trace", "TraceConfig", "generate_trace"]
