"""Seeded multi-tenant arrival traces: bursty, diurnal, heavy-tailed.

The paper's excess-load experiments drive the cluster with production
arrival traces [41] whose defining features are (a) a diurnal baseline,
(b) superimposed short bursts whose intensity is heavy-tailed (most
bursts are mild, a few are brutal), and (c) several tenants (agent apps)
sharing the fleet with skewed popularity.  This module synthesizes such
traces deterministically from a seed, as an explicit event list
``[(t, app_idx)]`` — the SAME list replays through the discrete-event
simulator (``SimConfig(arrivals=...)``) and through the real cluster
(submit each workflow at its timestamp relative to the run clock), so
elastic-vs-fixed comparisons run the identical workload on both paths.

Generation is non-homogeneous Poisson via Lewis-Shedler thinning: the
intensity is

    rate(t) = base_rate * diurnal(t) * burst(t)

with ``diurnal`` a sinusoid (period scaled into the trace duration — a
"day" compressed to minutes, as in trace-replay papers) and ``burst`` a
piecewise-constant elevation: burst windows arrive as a Poisson process,
each lasting ``burst_duration`` and multiplying the rate by a
Pareto-distributed factor (heavy tail, truncated so thinning stays
exact).  Within-window inter-arrivals further jitter with a Gamma
renewal of coefficient-of-variation ``cv`` like the existing
:func:`repro.sim.workload.arrival_times` sampler.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.sim.workload import AppSpec, make_app


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Knobs for one synthetic multi-tenant arrival trace."""
    seed: int = 0
    duration: float = 120.0
    base_rate: float = 4.0          # workflows/s at diurnal midpoint
    # diurnal: rate swings base_rate * (1 +- amplitude) over one period
    diurnal_amplitude: float = 0.5
    diurnal_period: float = 60.0
    # bursts: windows arrive at burst_rate/s, each burst_duration long,
    # multiplying intensity by 1 + Pareto(alpha) (truncated at max_mult)
    burst_rate: float = 0.05
    burst_duration: float = 5.0
    pareto_alpha: float = 1.5
    burst_max_mult: float = 8.0
    # within-process inter-arrival burstiness (Gamma renewal CV)
    cv: float = 1.6
    # tenants: (app_kind, group, weight) — weight is relative popularity
    tenants: Sequence[Tuple[str, str, float]] = (
        ("QA", "G+M", 3.0), ("RG", "TQ", 1.0), ("CG", "HE", 1.0))

    def __post_init__(self):
        assert self.duration > 0 and self.base_rate > 0
        assert 0.0 <= self.diurnal_amplitude < 1.0
        assert self.pareto_alpha > 1.0 and self.burst_max_mult >= 1.0
        assert self.tenants and all(w > 0 for _, _, w in self.tenants)


@dataclasses.dataclass
class Trace:
    """An explicit arrival list plus the tenant apps it indexes into."""
    events: List[Tuple[float, int]]   # (arrival time, app index), sorted
    apps: List[AppSpec]
    config: TraceConfig

    @property
    def n_workflows(self) -> int:
        return len(self.events)

    def rate_profile(self, bin_s: float = 1.0) -> np.ndarray:
        """Arrivals-per-second histogram (for plots and burst asserts)."""
        n = int(np.ceil(self.config.duration / bin_s))
        hist = np.zeros(n)
        for t, _ in self.events:
            hist[min(n - 1, int(t / bin_s))] += 1.0 / bin_s
        return hist

    def sim_config(self, serving=None, **overrides):
        """A :class:`~repro.sim.simulator.SimConfig` replaying this
        trace — from a :class:`ServingConfig` when given (field-parity
        path), else from sim defaults."""
        from repro.sim.simulator import SimConfig
        common = dict(arrivals=list(self.events),
                      duration=self.config.duration,
                      seed=self.config.seed)
        common.update(overrides)
        if serving is not None:
            return SimConfig.from_serving_config(serving, self.apps, **common)
        return SimConfig(apps=self.apps, **common)


def _burst_windows(rng: np.random.Generator,
                   cfg: TraceConfig) -> List[Tuple[float, float, float]]:
    """(start, end, multiplier) burst elevations over the trace."""
    n = rng.poisson(cfg.burst_rate * cfg.duration)
    starts = np.sort(rng.uniform(0.0, cfg.duration, n))
    mults = 1.0 + np.minimum(rng.pareto(cfg.pareto_alpha, n),
                             cfg.burst_max_mult - 1.0)
    return [(float(s), float(s + cfg.burst_duration), float(m))
            for s, m in zip(starts, mults)]


def _intensity(t: np.ndarray, cfg: TraceConfig,
               bursts: List[Tuple[float, float, float]]) -> np.ndarray:
    rate = cfg.base_rate * (
        1.0 + cfg.diurnal_amplitude
        * np.sin(2.0 * np.pi * t / cfg.diurnal_period))
    for s, e, m in bursts:
        rate = np.where((t >= s) & (t < e), rate * m, rate)
    return rate


def generate_trace(cfg: TraceConfig = TraceConfig()) -> Trace:
    """Deterministic trace synthesis (same seed => identical events).

    Thinning against the exact intensity ceiling keeps the process
    non-homogeneous Poisson; a final Gamma-CV jitter perturbs each
    arrival within a fraction of its local inter-arrival gap to mimic
    renewal burstiness without reordering across burst boundaries."""
    rng = np.random.default_rng(cfg.seed)
    bursts = _burst_windows(rng, cfg)
    lam_max = cfg.base_rate * (1.0 + cfg.diurnal_amplitude) \
        * max([m for _, _, m in bursts], default=1.0)
    # Lewis-Shedler: candidate homogeneous process at lam_max, thin to rate(t)
    n_cand = rng.poisson(lam_max * cfg.duration) + 8
    cand = np.sort(rng.uniform(0.0, cfg.duration, n_cand))
    keep = rng.uniform(0.0, 1.0, n_cand) * lam_max \
        <= _intensity(cand, cfg, bursts)
    times = cand[keep]
    if cfg.cv != 1.0 and len(times) > 1:
        # renewal-style jitter: move each arrival within its local gap by
        # a Gamma(1/cv^2) factor, clamped so ordering survives
        shape = 1.0 / (cfg.cv ** 2)
        gaps = np.diff(np.concatenate([[0.0], times]))
        jitter = rng.gamma(shape, 1.0 / shape, len(gaps))
        times = np.cumsum(gaps * np.clip(jitter, 0.25, 4.0))
        times = times[times < cfg.duration]
    weights = np.array([w for _, _, w in cfg.tenants])
    weights = weights / weights.sum()
    app_idx = rng.choice(len(cfg.tenants), size=len(times), p=weights)
    apps = [make_app(kind, group) for kind, group, _ in cfg.tenants]
    events = [(float(t), int(a)) for t, a in zip(times, app_idx)]
    return Trace(events=events, apps=apps, config=cfg)


def bursty_trace(seed: int = 0, duration: float = 60.0,
                 base_rate: float = 4.0,
                 burst_mult: float = 6.0,
                 burst_at: Optional[float] = None,
                 burst_duration: float = 8.0) -> Trace:
    """A trace with ONE guaranteed burst window — the committed
    benchmark workload (``benchmarks/autoscale_burst.py``) uses this so
    the burst is always present regardless of seed, while all arrival
    randomness stays seed-deterministic."""
    cfg = TraceConfig(seed=seed, duration=duration, base_rate=base_rate,
                      burst_rate=0.0, burst_duration=burst_duration,
                      burst_max_mult=burst_mult)
    rng = np.random.default_rng(cfg.seed)
    s = duration * 0.4 if burst_at is None else burst_at
    bursts = [(s, s + burst_duration, burst_mult)]
    lam_max = base_rate * (1.0 + cfg.diurnal_amplitude) * burst_mult
    n_cand = rng.poisson(lam_max * duration) + 8
    cand = np.sort(rng.uniform(0.0, duration, n_cand))
    keep = rng.uniform(0.0, 1.0, n_cand) * lam_max \
        <= _intensity(cand, cfg, bursts)
    times = cand[keep]
    weights = np.array([w for _, _, w in cfg.tenants])
    weights = weights / weights.sum()
    app_idx = rng.choice(len(cfg.tenants), size=len(times), p=weights)
    apps = [make_app(kind, group) for kind, group, _ in cfg.tenants]
    events = [(float(t), int(a)) for t, a in zip(times, app_idx)]
    return Trace(events=events, apps=apps, config=cfg)
