"""Multi-pod dry-run: prove the distribution config lowers + compiles for
every (architecture × input shape × mesh) combination, and extract the
memory / FLOP / collective numbers that feed the roofline analysis.

The two os.environ lines below MUST run before ANY other import (jax locks
the device count on first init).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape decode_32k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun.json
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
import traceback
from typing import Dict, List

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, ModelConfig, ShapeConfig, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.sharding import (
    batch_pspec,
    cache_pspecs,
    param_shardings,
    should_fsdp,
)
from repro.training.optimizer import make_train_step

ARCHS_DEFAULT = list(__import__("repro.configs", fromlist=["ARCH_IDS"]).ARCH_IDS)

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SHAPE_RE = re.compile(r"(f32|f16|bf16|f64|s32|u32|s8|u8|pred|s16|u16)\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
          "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def enc_len_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if shape.kind in ("train", "prefill"):
        return shape.seq_len // 2
    return min(4096, shape.seq_len // 2)


def dec_len_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if cfg.is_encdec and shape.kind in ("train", "prefill"):
        return shape.seq_len // 2
    return shape.seq_len


def input_specs(arch: str, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    b = shape.global_batch
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        s = dec_len_for(cfg, shape)
        spec = {"tokens": sds((b, s), jnp.int32), "labels": sds((b, s), jnp.int32)}
        if cfg.is_encdec:
            spec["frames"] = sds((b, enc_len_for(cfg, shape), cfg.d_model), jnp.bfloat16)
        return spec
    if shape.kind == "prefill":
        s = dec_len_for(cfg, shape)
        spec = {"tokens": sds((b, s), jnp.int32)}
        if cfg.is_encdec:
            spec["frames"] = sds((b, enc_len_for(cfg, shape), cfg.d_model), jnp.bfloat16)
        return spec
    # decode: ONE new token against a cache of seq_len
    return {"tokens": sds((b, 1), jnp.int32)}


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its body lines (post-SPMD HLO text)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*\(.*\)\s*->.*\{", line) \
            or re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->", line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
        elif line.startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur].append(line.strip())
    return comps


def _trip_factors(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """Multiplier for each computation = product of enclosing while trip
    counts (lax.scan layer stacks under-count otherwise)."""
    # while edges: parent computation -> (body computation, trip count)
    edges: Dict[str, List] = {}
    for name, lines in comps.items():
        for ln in lines:
            if " while(" not in ln:
                continue
            mb = re.search(r"body=%?([\w\.\-]+)", ln)
            mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ln)
            if mb:
                edges.setdefault(name, []).append(
                    (mb.group(1), int(mt.group(1)) if mt else 1))
    factor = {name: 1 for name in comps}
    roots = [n for n in comps if n == "__entry__" or n not in
             {b for es in edges.values() for b, _ in es}]
    seen = set()
    stack = [(r, 1) for r in roots]
    while stack:
        name, f = stack.pop()
        if name in seen and factor.get(name, 1) >= f:
            continue
        seen.add(name)
        factor[name] = max(factor.get(name, 1), f)
        for body, trip in edges.get(name, ()):
            stack.append((body, f * trip))
    return factor


def _collective_bytes(hlo: str) -> Dict[str, int]:
    """Per-device collective operand bytes, scaled by enclosing while-loop
    trip counts (so per-layer collectives inside the layer scan count
    num_layers times)."""
    comps = _split_computations(hlo)
    factor = _trip_factors(comps)
    out = {op: 0 for op in COLLECTIVE_OPS}
    for cname, lines in comps.items():
        f = factor.get(cname, 1)
        for stripped in lines:
            m = re.search(r"=\s*\(?([a-z0-9\[\],{}() ]+?)\)?\s+([a-z\-]+)\(", stripped)
            if not m:
                continue
            op = m.group(2)
            opn = op.replace("-start", "").replace("-done", "")
            if opn not in COLLECTIVE_OPS or op.endswith("-done"):
                continue
            nbytes = 0
            for dt, dims in _SHAPE_RE.findall(m.group(1)):
                n = 1
                if dims:
                    for d in dims.split(","):
                        n *= int(d)
                nbytes += n * _BYTES[dt]
            out[opn] += nbytes * f
    return out


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def build_step(arch: str, shape_name: str, mesh):
    """Returns (step_fn, example_inputs (abstract), in_shardings)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    fsdp = should_fsdp(cfg, shape.kind)
    specs = input_specs(arch, shape_name)
    bspec = batch_pspec(shape, mesh)
    ns = lambda p: NamedSharding(mesh, p)
    # pin (B, S, d) activations at every layer boundary (see model.py)
    model.act_sharding = ns(P(*bspec, None))

    if shape.kind == "train":
        opt = "adafactor" if cfg.param_count() > 100e9 else "adam"
        init_state, train_step = make_train_step(model, opt)
        state_shape = jax.eval_shape(init_state, key)
        state_sh = param_shardings(state_shape, cfg, mesh, fsdp=fsdp)
        batch_sh = {k: ns(bspec) if v.ndim == 2 else ns(P(*bspec, None))
                    for k, v in specs.items()}
        fn = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
        return fn, (state_shape, specs), cfg

    params_shape = jax.eval_shape(model.init_params, key)
    params_sh = param_shardings(params_shape, cfg, mesh, fsdp=fsdp)

    if shape.kind == "prefill":
        if cfg.is_encdec:
            def prefill_step(params, tokens, frames):
                return model.prefill(params, tokens, frames)
            in_sh = (params_sh, ns(bspec), ns(P(*bspec, None)))
            args = (params_shape, specs["tokens"], specs["frames"])
        else:
            def prefill_step(params, tokens):
                return model.prefill(params, tokens)
            in_sh = (params_sh, ns(bspec))
            args = (params_shape, specs["tokens"])
        fn = jax.jit(prefill_step, in_shardings=in_sh)
        return fn, args, cfg

    # decode (serve_step): one token, full-context cache
    b = shape.global_batch
    s = dec_len_for(cfg, shape)
    if cfg.is_encdec:
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(b, s, enc_len_for(cfg, shape)))
    else:
        cache_shape = jax.eval_shape(lambda: model.init_cache(b, s))
    cache_sh = {k: ns(p) for k, p in
                cache_pspecs(cfg, shape, mesh, cache_shape).items()}
    # decoding starts at position s-1 (cache holds s-1 tokens of context)
    def serve_step(params, cache, tokens):
        cache = dict(cache, pos=jnp.asarray(s - 1, jnp.int32))
        return model.decode_step(params, cache, tokens)

    fn = jax.jit(serve_step, in_shardings=(params_sh, cache_sh, ns(bspec)),
                 donate_argnums=(1,))
    return fn, (params_shape, cache_shape, specs["tokens"]), cfg


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               with_hlo: bool = True) -> Dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "n_devices": mesh.size}
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        rec["status"] = "skipped (sub-quadratic required, see DESIGN.md §4)"
        rec["elapsed_s"] = 0.0
        return rec
    try:
        with mesh:
            fn, args, cfg = build_step(arch, shape_name, mesh)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
            try:
                mem = compiled.memory_analysis()
                rec["memory"] = {
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                    "output_bytes": getattr(mem, "output_size_in_bytes", None),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                    "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
                }
                # XLA CPU (and some wheel versions) report peak as None
                # even when the per-category sizes are present; synthesize
                # a conservative upper bound so downstream consumers (the
                # roofline, the dry-run regression test) keep a usable
                # number — flagged so nobody mistakes it for a measurement
                parts = [rec["memory"][k] for k in
                         ("argument_bytes", "output_bytes", "temp_bytes")]
                if rec["memory"]["peak_bytes"] is None and \
                        any(p is not None for p in parts):
                    rec["memory"]["peak_bytes"] = sum(p or 0 for p in parts)
                    rec["memory"]["peak_bytes_estimated"] = True
            except Exception as e:  # CPU backend may not support it
                rec["memory"] = {"error": str(e)}
            try:
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0]
                rec["cost"] = {k: float(v) for k, v in ca.items()
                               if isinstance(v, (int, float)) and
                               k in ("flops", "bytes accessed", "transcendentals",
                                     "optimal_seconds")}
            except Exception as e:
                rec["cost"] = {"error": str(e)}
            if with_hlo:
                hlo = compiled.as_text()
                rec["collectives"] = _collective_bytes(hlo)
                rec["hlo_lines"] = hlo.count("\n")
            rec["status"] = "ok"
    except Exception as e:
        rec["status"] = f"FAILED: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["elapsed_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = args.arch or (ARCHS_DEFAULT if args.all else ["llama3.2-3b"])
    shapes = args.shape or (list(INPUT_SHAPES) if args.all else ["decode_32k"])
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = dryrun_one(arch, shape, mp)
                records.append(rec)
                mem = rec.get("memory", {}) or {}
                peak = mem.get("peak_bytes")
                peak_s = f"{peak/2**30:.2f}GiB/dev" if peak else "n/a"
                flops = (rec.get("cost", {}) or {}).get("flops")
                fl_s = f"{flops:.3g}F/dev" if flops else ""
                print(f"[{rec['status'][:40]:40s}] {arch:22s} {shape:12s} "
                      f"{rec['mesh']:8s} {peak_s:14s} {fl_s} ({rec['elapsed_s']}s)",
                      flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    n_fail = sum(1 for r in records if r["status"].startswith("FAILED"))
    print(f"\n{len(records) - n_fail}/{len(records)} combinations compiled")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
