"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e, 16x16 = 256 chips
per pod; the multi-pod mesh adds a leading "pod" axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Degenerate mesh on the locally available devices (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
