"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e, 16x16 = 256 chips
per pod; the multi-pod mesh adds a leading "pod" axis (2 pods = 512 chips).

The serving stack uses *host-level* meshes: :func:`make_local_mesh` for
one tensor-parallel instance, :func:`make_slice_meshes` to carve the
local devices into disjoint same-size slices (data-parallel instances x
tensor-parallel shards — the production serving topology).  On CPU CI
the local "devices" are forced with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1,
                    devices: Optional[Sequence] = None):
    """("data", "model") mesh over the locally available devices.

    ``devices`` overrides the device set (sub-slice construction: a
    cluster carves ``jax.devices()`` into disjoint groups and builds one
    mesh per group).  ``model_parallel`` must be a positive factor of
    the device count — a non-factor used to silently floor-divide into
    a broken (0- or short-row) mesh; now it raises.
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    n = len(devs)
    if model_parallel < 1:
        raise ValueError(
            f"model_parallel must be >= 1, got {model_parallel}")
    if n == 0 or n % model_parallel != 0:
        raise ValueError(
            f"model_parallel={model_parallel} does not divide the "
            f"{n} available device(s); pick a factor of the device count "
            f"(or pass an explicit `devices=` slice)")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"), devices=devs)


def make_slice_meshes(n_slices: int, model_parallel: int = 1,
                      devices: Optional[Sequence] = None) -> List:
    """Disjoint ("data", "model") sub-meshes for data-parallel serving.

    Carves the device list into ``n_slices`` consecutive groups of
    ``model_parallel`` devices each — one tensor-parallel instance per
    slice, no device shared between slices.  Raises when the device
    count cannot supply ``n_slices * model_parallel`` devices.
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    if n_slices < 1:
        raise ValueError(f"n_slices must be >= 1, got {n_slices}")
    need = n_slices * model_parallel
    if need > len(devs):
        raise ValueError(
            f"{n_slices} slice(s) x {model_parallel}-way model parallel "
            f"needs {need} devices; only {len(devs)} available")
    return [make_local_mesh(model_parallel,
                            devices=devs[i * model_parallel:
                                         (i + 1) * model_parallel])
            for i in range(n_slices)]
