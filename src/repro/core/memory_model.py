"""Per-request dynamic memory model (§6, Eq. 1–3).

KV usage of request *i* is a linear ramp in token units:

    f_i(t) = P_i + k * (t - t_start)   for t_start < t < t_end,  else 0

P_i = prompt KV tokens (known at dispatch), k = decode speed (tokens/s,
from hardware profiling), t_end = t_start + T_i with T_i the mode of the
agent's single-request latency distribution (Eq. 2).

Architecture adaptation (DESIGN.md §4): attention-free archs have slope 0
and a constant state footprint; hybrids scale the slope by the fraction
of attention layers.
"""
from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass
class MemoryRamp:
    p_tokens: float          # prompt KV (token units)
    slope: float             # tokens/sec during decode
    t_start: float
    t_end: float

    def usage(self, t: float) -> float:
        if self.t_start < t < self.t_end:
            return self.p_tokens + self.slope * (t - self.t_start)
        return 0.0

    @property
    def peak(self) -> float:
        return self.p_tokens + self.slope * max(self.t_end - self.t_start, 0.0)

    def slot_usage(self, slot_starts, slot_len: float) -> List[float]:
        """Max usage within each slot (ramp is increasing -> slot end)."""
        out = []
        for s0 in slot_starts:
            s1 = s0 + slot_len
            if s1 <= self.t_start or s0 >= self.t_end:
                out.append(0.0)
            else:
                out.append(self.usage(min(s1, self.t_end) - 1e-9))
        return out


def make_ramp(prompt_len: int, expected_exec_time: float, decode_tok_per_s: float,
              t_start: float, kv_ratio: float = 1.0, state_tokens: float = 0.0,
              shared_prefix_tokens: int = 0) -> MemoryRamp:
    """kv_ratio: fraction of layers holding KV (1.0 dense, 4/32 jamba,
    0.0 rwkv); state_tokens: constant recurrent-state footprint expressed
    in KV-token-equivalents.

    ``shared_prefix_tokens``: prompt tokens expected to be served by the
    engine's shared-prefix KV cache (``serving/prefix_cache.py``).  Their
    pages are held once per instance, not once per request, so per-request
    ramps must not count them — otherwise the time-slot dispatcher
    double-counts the shared pages for every concurrent agent call and
    under-packs the instance."""
    eff_prompt = max(prompt_len - max(shared_prefix_tokens, 0), 1)
    return MemoryRamp(
        p_tokens=eff_prompt * kv_ratio + state_tokens,
        slope=decode_tok_per_s * kv_ratio,
        t_start=t_start,
        t_end=t_start + max(expected_exec_time, 1e-6),
    )
