"""Request priority scheduling policies (§5 + baselines).

* ``KairosScheduler`` — agent-level priority from the Wasserstein+MDS
  table (§5.1), intra-agent ordering by application-level start time
  (§5.2).
* ``FCFSScheduler`` — Parrot: arrival order at the load balancer.
* ``TopoScheduler`` — Ayo: fewer remaining workflow-topology stages first.
* ``OracleScheduler`` — knows each request's true remaining execution
  time (motivation Fig. 7 / sorting-accuracy upper bound).
"""
from __future__ import annotations

from typing import Callable, List

from repro.serving.request import Request


class SchedulerPolicy:
    """Shared by the load balancer (cluster queue, Fig. 10 ②) and by the
    instance-level :class:`~repro.serving.batch_scheduler.BatchScheduler`
    (waiting-queue order + preemption-victim choice)."""
    name = "base"

    def sort_key(self, req: Request):
        raise NotImplementedError

    def order(self, queue: List[Request]) -> List[Request]:
        return sorted(queue, key=self.sort_key)

    def victim_key(self, req: Request):
        """Preemption picks ``max(running, key=victim_key)``.  Default:
        the latest-arrived request — the classic vLLM recompute victim,
        which has accumulated the least decode progress, so recompute
        wastes the least work.  (Preempting by admission priority instead
        repeatedly kills the most-progressed long-output requests and
        measurably inflates preemption counts.)  Policies may override to
        couple victim choice to their ordering."""
        return (req.arrival_time, req.req_id)


class FCFSScheduler(SchedulerPolicy):
    name = "fcfs"  # Parrot

    def sort_key(self, req: Request):
        return (req.arrival_time, req.req_id)


class TopoScheduler(SchedulerPolicy):
    """Ayo: priority = remaining stage count in the workflow topology."""
    name = "topo"

    def __init__(self, remaining_stages: Callable[[str, str], int]):
        self._stages = remaining_stages

    def sort_key(self, req: Request):
        return (self._stages(req.app_name, req.agent_name),
                req.arrival_time, req.req_id)


class KairosScheduler(SchedulerPolicy):
    name = "kairos"

    def __init__(self, priority_score: Callable[[str, str], float]):
        self._score = priority_score

    def sort_key(self, req: Request):
        # agent-level first (shorter remaining latency first), then
        # application-level start time (earlier == more accumulated delay)
        return (self._score(req.app_name, req.agent_name),
                req.app_start_time, req.req_id)


class OracleScheduler(SchedulerPolicy):
    name = "oracle"

    def __init__(self, true_remaining: Callable[[Request], float]):
        self._rem = true_remaining

    def sort_key(self, req: Request):
        return (self._rem(req), req.req_id)
