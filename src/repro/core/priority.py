"""Agent-level priority determination (§5.1).

Pairwise Wasserstein distances between the agents' *remaining execution
latency* distributions (plus the ideal "zero latency" anchor) are embedded
into a 1-D coordinate space with classical MDS.  The coordinate is
oriented so the anchor sits at the low end; agents closer to the anchor
have shorter remaining work and get higher priority (smaller score).
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np


ANCHOR = ("__anchor__", "__zero_latency__")


def classical_mds_1d(dist: np.ndarray) -> np.ndarray:
    """Classical (Torgerson) MDS to 1 dimension.

    dist: (n, n) symmetric distance matrix -> (n,) coordinates.
    Only the TOP eigenvector is needed, so beyond n=512 we use power
    iteration (O(n^2) per sweep) instead of a full O(n^3) eigh — this is
    what keeps the §7.7 large-agent-count overhead in the paper's 0.1–4.3 s
    envelope (full eigh measured 132 s at n=5000).
    """
    n = dist.shape[0]
    d2 = dist ** 2
    # double centering without the O(n^3) J @ D2 @ J matmuls
    rm = d2.mean(axis=1, keepdims=True)
    cm = d2.mean(axis=0, keepdims=True)
    b = -0.5 * (d2 - rm - cm + d2.mean())
    if n <= 512:
        w, v = np.linalg.eigh(b)
        i = int(np.argmax(w))
        return v[:, i] * np.sqrt(max(w[i], 0.0))
    rng = np.random.default_rng(0)
    x = rng.normal(size=n)
    lam = 0.0
    for _ in range(100):
        y = b @ x
        lam = float(np.linalg.norm(y))
        if lam < 1e-12:
            break
        y /= lam
        if np.linalg.norm(y - x) < 1e-9:
            x = y
            break
        x = y
    return x * np.sqrt(max(lam, 0.0))


def agent_priorities(samples: Dict[Tuple[str, str], Sequence[float]]) -> Dict[Tuple[str, str], float]:
    """Map (app, agent) -> priority score; LOWER = scheduled first.

    ``samples`` holds remaining-latency samples per (app, agent).  The
    zero-latency anchor orients the MDS axis (§5.1).
    """
    keys = [k for k, v in samples.items() if len(v) > 0]
    if not keys:
        return {}
    if len(keys) == 1:
        return {keys[0]: 0.0}
    # W1 between empirical dists = mean |quantile difference|: precompute
    # each agent's quantile vector once, then the full pairwise matrix is
    # one broadcasted subtraction — O(n^2 * 256) vectorized (the naive
    # per-pair np.quantile version took 37 s at n=500; this takes ~0.1 s,
    # within the paper's §7.7 envelope).
    grid = 64 if len(keys) > 512 else 256   # coarser grid at scale (~1% W1 err)
    q = np.linspace(0.0, 1.0, grid)
    quants = np.stack(
        [np.quantile(np.asarray(samples[k], np.float64), q) for k in keys]
        + [np.zeros_like(q)]).astype(np.float32)                # anchor
    n = quants.shape[0]
    dist = np.empty((n, n), np.float32)
    blk = max(1, int(256e6 // (n * grid * 4)))  # ~256 MB working blocks
    for i in range(0, n, blk):
        dist[i:i + blk] = np.mean(
            np.abs(quants[i:i + blk, None, :] - quants[None, :, :]), axis=2)
    coord = classical_mds_1d(dist.astype(np.float64))
    # orient: anchor at the minimum end
    anchor_c = coord[-1]
    if anchor_c > np.median(coord):
        coord = -coord
        anchor_c = -anchor_c
    return {k: float(coord[i] - anchor_c) for i, k in enumerate(keys)}


class PriorityTable:
    """Incrementally refreshed agent priorities with background-style updates.

    Real deployment recomputes on a fixed interval / asynchronously (§7.7);
    here `maybe_refresh` recomputes when `interval` new completions landed.
    """

    def __init__(self, interval: int = 64):
        self.interval = interval
        self._since = 0
        self.scores: Dict[Tuple[str, str], float] = {}
        self.n_refreshes = 0

    def tick_completion(self):
        self._since += 1

    def maybe_refresh(self, samples: Dict[Tuple[str, str], Sequence[float]], force=False):
        if not force and self._since < self.interval and self.scores:
            return False
        self.scores = agent_priorities(samples)
        self._since = 0
        self.n_refreshes += 1
        return True

    def score(self, app: str, agent: str, default: float = float("inf")) -> float:
        return self.scores.get((app, agent), default)
