"""Latency distribution analysis (§4.3).

Kairos maintains, per agent, (1) the single-request execution latency
distribution — convergence detected with the Wasserstein distance each
time the sample count doubles — and (2) the remaining end-to-end latency
distribution derived from reconstructed workflows.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


def wasserstein_1d(a, b) -> float:
    """W1 distance between two 1-D empirical distributions.

    Equals the integral of |F_a^{-1}(q) - F_b^{-1}(q)| dq, evaluated on a
    common quantile grid (no scipy dependency).
    """
    a = np.sort(np.asarray(a, np.float64))
    b = np.sort(np.asarray(b, np.float64))
    if len(a) == 0 or len(b) == 0:
        return float("inf")
    q = np.linspace(0.0, 1.0, 256)
    qa = np.quantile(a, q)
    qb = np.quantile(b, q)
    return float(np.mean(np.abs(qa - qb)))


@dataclasses.dataclass
class EmpiricalDistribution:
    samples: List[float] = dataclasses.field(default_factory=list)

    def add(self, x: float):
        self.samples.append(float(x))

    def __len__(self):
        return len(self.samples)

    def mean(self) -> float:
        return float(np.mean(self.samples)) if self.samples else 0.0

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.samples, p)) if self.samples else 0.0

    def mode(self) -> float:
        """Highest-probability-density point (§6: expected execution time).

        Histogram-based density estimate with Freedman–Diaconis-ish bins.
        """
        if not self.samples:
            return 0.0
        xs = np.asarray(self.samples, np.float64)
        if len(xs) < 8 or np.ptp(xs) == 0:
            return float(np.median(xs))
        nbins = max(8, min(64, int(np.sqrt(len(xs)))))
        hist, edges = np.histogram(xs, bins=nbins)
        i = int(np.argmax(hist))
        return float(0.5 * (edges[i] + edges[i + 1]))


class ConvergenceTracker:
    """Exponential doubling + Wasserstein convergence test (§4.3)."""

    def __init__(self, threshold: float = 0.15, min_samples: int = 8):
        self.threshold = threshold
        self.min_samples = min_samples
        self._snapshot: Optional[np.ndarray] = None
        self._next_check = min_samples
        self.converged = False
        self.last_distance = float("inf")

    def observe(self, samples: List[float]):
        n = len(samples)
        if n < self._next_check:
            return
        cur = np.asarray(samples, np.float64)
        if self._snapshot is not None:
            d = wasserstein_1d(cur, self._snapshot)
            scale = max(float(np.mean(cur)), 1e-9)
            self.last_distance = d / scale          # relative W1
            self.converged = self.last_distance < self.threshold
        self._snapshot = cur
        self._next_check = n * 2                    # doubling strategy


class DistributionProfiler:
    """Per-agent single-request execution latency + output-length profiles."""

    def __init__(self, convergence_threshold: float = 0.15):
        self.latency: Dict[str, EmpiricalDistribution] = {}
        self.output_len: Dict[str, EmpiricalDistribution] = {}
        self._trackers: Dict[str, ConvergenceTracker] = {}
        self._threshold = convergence_threshold

    def record(self, agent: str, latency: float, output_len: int):
        self.latency.setdefault(agent, EmpiricalDistribution()).add(latency)
        self.output_len.setdefault(agent, EmpiricalDistribution()).add(output_len)
        tr = self._trackers.setdefault(agent, ConvergenceTracker(self._threshold))
        tr.observe(self.latency[agent].samples)

    def converged(self, agent: str) -> bool:
        tr = self._trackers.get(agent)
        return bool(tr and tr.converged)

    def expected_exec_time(self, agent: str, default: float = 1.0) -> float:
        d = self.latency.get(agent)
        return d.mode() if d and len(d) else default

    def expected_output_len(self, agent: str, default: int = 128) -> int:
        d = self.output_len.get(agent)
        return int(d.mode()) if d and len(d) else default

    def agents(self) -> List[str]:
        return list(self.latency)
