"""Memory-aware time-slot dispatcher (§6) + baselines.

The future timeline is discretized into fixed 0.5 s slots.  Each instance
accumulates the expected KV usage of its in-flight ramps per slot
(Eq. 3).  A request is dispatchable to an instance iff no spanned slot
exceeds capacity; among feasible instances the one with the lowest
expected total **peak** usage wins.  Adaptive corrections: early
finishers release their future slots immediately; an instance reporting a
real OOM/preemption is fenced for a cooldown.

Every dispatcher implements the same contract —
``dispatch(req, ramp, now, force=False) -> Optional[int]`` plus the
``on_finish`` / ``on_oom`` feedback hooks — so the load balancer calls
them uniformly, with no signature probing.

Role-typed clusters (prefill/decode disaggregation) add one routing
axis: every :class:`InstanceModel` carries its instance's ``role`` and
:func:`role_accepts` gates placement by the request's
:class:`~repro.serving.request.RequestPhase` — new (prefill-phase) work
never lands on a decode instance, decode-phase work never on a prefill
instance.  The gate is a *hard* admission rule, so it holds even under
``force`` (the starvation valve may override memory feasibility, never
the role topology).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.memory_model import MemoryRamp
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.request import Request, RequestPhase

SLOT_LEN = 0.5  # seconds (§6: empirically favourable trade-off)


def role_accepts(role: str, req: Request) -> bool:
    """Whether an instance of ``role`` may receive ``req`` in its current
    phase.  General instances take anything; prefill instances take only
    prefill-phase work (their decode capacity exists solely as the
    stranded-handoff fallback); decode instances take only decode-phase
    work (arriving via handoff/migration adopt, never the balancer)."""
    if role == "general":
        return True
    if role == "prefill":
        return req.phase is not RequestPhase.DECODE
    return req.phase is RequestPhase.DECODE


def _slot_usage_matrix(ramps: List[MemoryRamp], slot_starts: np.ndarray,
                       slot_len: float) -> np.ndarray:
    """Vectorized Eq. 3: (n_ramps, n_slots) expected usage (ramp max in slot)."""
    if not ramps:
        return np.zeros((0, len(slot_starts)))
    p = np.array([r.p_tokens for r in ramps])[:, None]
    k = np.array([r.slope for r in ramps])[:, None]
    t0 = np.array([r.t_start for r in ramps])[:, None]
    t1 = np.array([r.t_end for r in ramps])[:, None]
    s0 = slot_starts[None, :]
    s1 = s0 + slot_len
    active = (s1 > t0) & (s0 < t1)
    usage = p + k * (np.minimum(s1, t1) - t0)
    return np.where(active, usage, 0.0)


@dataclasses.dataclass
class InstanceModel:
    """Dispatcher-side view of one LLM instance."""
    instance_id: int
    capacity_tokens: float
    ramps: Dict[int, MemoryRamp] = dataclasses.field(default_factory=dict)
    fenced_until: float = -1.0
    role: str = "general"          # disaggregation role (see role_accepts)

    def current_usage(self, now: float) -> float:
        return sum(r.usage(now) for r in self.ramps.values())

    def gc(self, now: float):
        dead = [k for k, r in self.ramps.items() if r.t_end <= now]
        for k in dead:
            del self.ramps[k]


class TimeSlotDispatcher:
    name = "kairos"

    def __init__(self, instances: List[InstanceModel], slot_len: float = SLOT_LEN,
                 oom_cooldown: float = 2.0, admit_probe=None,
                 tracer: Tracer = NULL_TRACER):
        self.instances = {i.instance_id: i for i in instances}
        self.slot_len = slot_len
        self.oom_cooldown = oom_cooldown
        self.admit_probe = admit_probe
        self.tracer = tracer
        self.n_rejected = 0
        # per-round occupancy cache: recomputed when `now` changes, updated
        # in place on accept — keeps a scheduling round at O(ramps) total.
        self._cache_now: float = float("nan")
        self._slot_starts: Optional[np.ndarray] = None
        self._occ: Dict[int, np.ndarray] = {}

    # --------------------------------------------------------------- elasticity
    def add_instance(self, inst: InstanceModel):
        """Autoscaler scale-up: start routing to a new instance."""
        assert inst.instance_id not in self.instances
        self.instances[inst.instance_id] = inst
        self._cache_now = float("nan")

    def remove_instance(self, instance_id: int) -> InstanceModel:
        """Autoscaler scale-down: stop routing to an instance.  Returns
        the popped model so the cluster can re-home surviving ramps via
        :meth:`adopt_ramp`.  Any OOM fence dies with the model — a later
        ``add_instance`` under the same id starts unfenced (the
        scale-down-while-fenced regression test pins this)."""
        inst = self.instances.pop(instance_id)
        self._occ.pop(instance_id, None)
        self._cache_now = float("nan")
        return inst

    def adopt_ramp(self, instance_id: int, req_id: int, ramp):
        """Live migration: re-home one in-flight request's memory ramp to
        its new instance (None ramps — e.g. already expired — are
        dropped)."""
        if ramp is not None:
            self.instances[instance_id].ramps[req_id] = ramp
            self._cache_now = float("nan")

    # ---------------------------------------------------------------- feedback
    def on_finish(self, instance_id: int, req_id: int):
        """Early/normal finish: drop the ramp's future slots (§6 adaptive).
        The instance may have been scaled away since dispatch."""
        inst = self.instances.get(instance_id)
        if inst is not None:
            inst.ramps.pop(req_id, None)
        self._cache_now = float("nan")

    def on_oom(self, instance_id: int, now: float):
        self.instances[instance_id].fenced_until = now + self.oom_cooldown
        self._cache_now = float("nan")
        if self.tracer.enabled:
            self.tracer.emit("oom-fence", instance_id=instance_id, ts=now,
                             until=now + self.oom_cooldown)

    def is_fenced(self, instance_id: int, now: float) -> bool:
        """True while the instance sits in its post-OOM cooldown — the
        cluster runtime and tests introspect fencing through this instead
        of poking at ``InstanceModel.fenced_until``.  An instance that has
        been scaled away is not fenced (its fence died with its model)."""
        inst = self.instances.get(instance_id)
        return inst is not None and now < inst.fenced_until

    # ---------------------------------------------------------------- internals
    def _refresh_cache(self, now: float, min_end: float):
        horizon_end = min_end
        for inst in self.instances.values():
            inst.gc(now)
            for r in inst.ramps.values():
                horizon_end = max(horizon_end, r.t_end)
        n_slots = min(max(1, int(math.ceil((horizon_end - now) / self.slot_len)) + 1), 4096)
        self._slot_starts = now + np.arange(n_slots) * self.slot_len
        self._occ = {
            iid: _slot_usage_matrix(list(inst.ramps.values()),
                                    self._slot_starts, self.slot_len).sum(0)
            for iid, inst in self.instances.items()}
        self._cache_now = now

    # ---------------------------------------------------------------- dispatch
    def dispatch(self, req: Request, ramp: MemoryRamp, now: float,
                 force: bool = False) -> Optional[int]:
        """Pick an instance; None => stay queued for the next round.
        ``force`` (starvation valve): ignore feasibility, pick min peak —
        the engine's own preemption handles the overflow."""
        if self._cache_now != now or self._slot_starts is None or \
                ramp.t_end > self._slot_starts[-1] + self.slot_len:
            self._refresh_cache(now, ramp.t_end)
        req_slots = _slot_usage_matrix([ramp], self._slot_starts, self.slot_len)[0]

        best_id, best_peak = None, float("inf")
        for iid, inst in self.instances.items():
            if not role_accepts(inst.role, req):
                continue           # hard topology rule, force included
            if now < inst.fenced_until and not force:
                continue
            if (self.admit_probe is not None and not force
                    and not self.admit_probe(iid, req)):
                continue
            total = self._occ[iid] + req_slots
            peak = float(total.max())
            if peak > inst.capacity_tokens and not force:
                continue
            if peak < best_peak:
                best_peak, best_id = peak, iid
        if best_id is None:
            self.n_rejected += 1
            return None
        self.instances[best_id].ramps[req.req_id] = ramp
        self._occ[best_id] = self._occ[best_id] + req_slots
        return best_id


class RoundRobinDispatcher:
    """Parrot / Ayo baseline: memory-oblivious rotation.

    An optional ``admit_probe(iid, req) -> bool`` gates dispatch on the
    engine's *current* admission capacity (batch slot + prompt memory),
    i.e. vLLM semantics — but with no awareness of future memory growth,
    which is exactly the §2.2.3 failure mode."""
    name = "round_robin"

    def __init__(self, instances: List[InstanceModel], admit_probe=None):
        self.instances = {i.instance_id: i for i in instances}
        self._order = sorted(self.instances)
        self._ptr = 0
        self.admit_probe = admit_probe

    def add_instance(self, inst: InstanceModel):
        assert inst.instance_id not in self.instances
        self.instances[inst.instance_id] = inst
        self._order = sorted(self.instances)

    def remove_instance(self, instance_id: int) -> InstanceModel:
        inst = self.instances.pop(instance_id)
        self._order = sorted(self.instances)
        if self._order:
            self._ptr %= len(self._order)
        else:
            self._ptr = 0
        return inst

    def adopt_ramp(self, instance_id: int, req_id: int, ramp):
        if ramp is not None:
            self.instances[instance_id].ramps[req_id] = ramp

    def on_finish(self, instance_id: int, req_id: int):
        inst = self.instances.get(instance_id)
        if inst is not None:
            inst.ramps.pop(req_id, None)

    def on_oom(self, instance_id: int, now: float):
        pass

    def dispatch(self, req: Request, ramp: MemoryRamp, now: float,
                 force: bool = False) -> Optional[int]:
        n = len(self._order)
        for k in range(n):
            iid = self._order[(self._ptr + k) % n]
            if not role_accepts(self.instances[iid].role, req):
                continue
            if force or self.admit_probe is None or self.admit_probe(iid, req):
                self._ptr = (self._ptr + k + 1) % n
                self.instances[iid].ramps[req.req_id] = ramp
                return iid
        return None


class BestFitOracleDispatcher:
    """Motivation §2.2.3 Oracle: knows the true output length; packs to the
    instance with the smallest resulting expected peak (no slot error)."""
    name = "oracle"

    def __init__(self, instances: List[InstanceModel], admit_probe=None):
        self.instances = {i.instance_id: i for i in instances}
        self.admit_probe = admit_probe

    def add_instance(self, inst: InstanceModel):
        assert inst.instance_id not in self.instances
        self.instances[inst.instance_id] = inst

    def remove_instance(self, instance_id: int) -> InstanceModel:
        return self.instances.pop(instance_id)

    def adopt_ramp(self, instance_id: int, req_id: int, ramp):
        if ramp is not None:
            self.instances[instance_id].ramps[req_id] = ramp

    def on_finish(self, instance_id: int, req_id: int):
        inst = self.instances.get(instance_id)
        if inst is not None:
            inst.ramps.pop(req_id, None)

    def on_oom(self, instance_id: int, now: float):
        pass

    def dispatch(self, req: Request, ramp: MemoryRamp, now: float,
                 force: bool = False) -> Optional[int]:
        best_id, best_peak = None, float("inf")
        for inst in self.instances.values():
            inst.gc(now)
            if not role_accepts(inst.role, req):
                continue
            if (self.admit_probe is not None and not force
                    and not self.admit_probe(inst.instance_id, req)):
                continue
            cur = sum(r.peak for r in inst.ramps.values())
            if cur + ramp.peak > inst.capacity_tokens and not force:
                continue
            if cur + ramp.peak < best_peak:
                best_peak, best_id = cur + ramp.peak, inst.instance_id
        if best_id is None:
            return None
        self.instances[best_id].ramps[req.req_id] = ramp
        return best_id
