"""Workflow Orchestrator (§4): collects execution info online, updates the
workflow analyzer and the distribution profiler, and serves the derived
signals (agent priorities, expected execution times, memory ramps) to the
scheduler and dispatcher.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.distributions import DistributionProfiler
from repro.core.memory_model import MemoryRamp, make_ramp
from repro.core.priority import PriorityTable
from repro.core.workflow import WorkflowAnalyzer
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.request import CompletionRecord, Request

# EMA smoothing for the measured per-agent TTFT / TPOT feeds (tracing
# mode): recent completions dominate, old load regimes decay in ~10
# completions — the same spirit as the paper's online profile updates.
_EMA_ALPHA = 0.2


@dataclasses.dataclass
class HardwareProfile:
    """Prior profiling constants (paper: A40; here: v5e-class, DESIGN.md §3)."""
    decode_tok_per_s: float = 30.0        # per-request decode speed (Eq.1 `k`)
    kv_capacity_tokens: int = 8192        # per instance


@dataclasses.dataclass
class ArchMemoryTraits:
    """Architecture adaptation of Eq. 1 (DESIGN.md §4)."""
    kv_ratio: float = 1.0                 # fraction of layers with KV growth
    state_tokens: float = 0.0             # constant recurrent state (token-equiv)


class Orchestrator:
    def __init__(self, hardware: Optional[HardwareProfile] = None,
                 arch_traits: Optional[ArchMemoryTraits] = None,
                 priority_refresh: int = 64,
                 prefix_caching: bool = False,
                 tracer: Tracer = NULL_TRACER):
        self.hw = hardware or HardwareProfile()
        self.traits = arch_traits or ArchMemoryTraits()
        self.analyzer = WorkflowAnalyzer()
        self.profiler = DistributionProfiler()
        self.priorities = PriorityTable(interval=priority_refresh)
        # engines run the shared-prefix KV cache: memory ramps discount the
        # declared shared prefix so the dispatcher doesn't double-count it
        self.prefix_caching = prefix_caching
        # with tracing enabled, expected_exec_time feeds from *measured*
        # first-token/decode timings (EMA per agent) instead of the
        # static mode-of-distribution guess; the static path stays the
        # fallback for agents with no measured spans yet
        self.tracer = tracer
        self._ttft_ema: dict = {}
        self._tpot_ema: dict = {}

    # ------------------------------------------------------------------ intake
    def on_completion(self, rec: CompletionRecord):
        self.analyzer.add_record(rec)
        # single-request distribution uses pure execution latency (Eq. 2)
        self.profiler.record(rec.agent_name, rec.exec_latency, rec.output_len)
        if self.tracer.enabled and rec.first_token_time >= 0 \
                and rec.exec_start_time >= 0:
            ttft = rec.first_token_time - rec.exec_start_time
            tpot = (rec.end_time - rec.first_token_time) \
                / max(rec.output_len - 1, 1)
            if ttft >= 0 and tpot >= 0:
                a = rec.agent_name
                old_f, old_p = self._ttft_ema.get(a), self._tpot_ema.get(a)
                self._ttft_ema[a] = ttft if old_f is None \
                    else old_f + _EMA_ALPHA * (ttft - old_f)
                self._tpot_ema[a] = tpot if old_p is None \
                    else old_p + _EMA_ALPHA * (tpot - old_p)
        self.priorities.tick_completion()

    def on_workflow_complete(self, msg_id: str):
        self.analyzer.finalize_trace(msg_id)
        self.priorities.maybe_refresh(
            {k: v.samples for k, v in self.analyzer.remaining.items()})

    def refresh_priorities(self):
        self.priorities.maybe_refresh(
            {k: v.samples for k, v in self.analyzer.remaining.items()}, force=True)

    # ------------------------------------------------------------------ queries
    def priority_score(self, app: str, agent: str) -> float:
        s = self.priorities.score(app, agent)
        if s == float("inf"):
            # cold start: fall back to single-request expected latency
            return 1e6 + self.profiler.expected_exec_time(agent, default=1.0)
        return s

    def remaining_stages(self, app: str, agent: str) -> int:
        return self.analyzer.remaining_stages(app, agent)

    def expected_exec_time(self, agent: str) -> float:
        """Expected single-request execution latency for one agent call.

        Traced mode composes it from measured spans — EMA(TTFT) +
        EMA(TPOT) x expected output length — which tracks load shifts
        (queue-free TTFT vs congested TTFT) the static
        mode-of-distribution estimate averages away.  Untraced, or for
        an agent with no measured completions yet, the profiler's mode
        estimate answers exactly as before."""
        if self.tracer.enabled and agent in self._ttft_ema:
            return self._ttft_ema[agent] + self._tpot_ema[agent] \
                * max(self.profiler.expected_output_len(agent) - 1, 1)
        return self.profiler.expected_exec_time(agent)

    def memory_ramp(self, req: Request, now: float) -> MemoryRamp:
        # conservative reservation: P75 of the agent's exec-latency samples
        # (the paper's mode estimate under-reserves for heavy-tailed agents;
        # EXPERIMENTS.md §Perf records this beyond-paper refinement)
        d = self.profiler.latency.get(req.agent_name)
        t = d.percentile(75) if d and len(d) >= 8 else self.expected_exec_time(req.agent_name)
        return make_ramp(
            prompt_len=req.prompt_len,
            expected_exec_time=t,
            decode_tok_per_s=self.hw.decode_tok_per_s,
            t_start=now,
            kv_ratio=self.traits.kv_ratio,
            state_tokens=self.traits.state_tokens,
            shared_prefix_tokens=(req.shared_prefix_len
                                  if self.prefix_caching else 0),
        )
