"""Workflow Orchestrator (§4): collects execution info online, updates the
workflow analyzer and the distribution profiler, and serves the derived
signals (agent priorities, expected execution times, memory ramps) to the
scheduler and dispatcher.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.distributions import DistributionProfiler
from repro.core.memory_model import MemoryRamp, make_ramp
from repro.core.priority import PriorityTable
from repro.core.workflow import WorkflowAnalyzer
from repro.serving.request import CompletionRecord, Request


@dataclasses.dataclass
class HardwareProfile:
    """Prior profiling constants (paper: A40; here: v5e-class, DESIGN.md §3)."""
    decode_tok_per_s: float = 30.0        # per-request decode speed (Eq.1 `k`)
    kv_capacity_tokens: int = 8192        # per instance


@dataclasses.dataclass
class ArchMemoryTraits:
    """Architecture adaptation of Eq. 1 (DESIGN.md §4)."""
    kv_ratio: float = 1.0                 # fraction of layers with KV growth
    state_tokens: float = 0.0             # constant recurrent state (token-equiv)


class Orchestrator:
    def __init__(self, hardware: Optional[HardwareProfile] = None,
                 arch_traits: Optional[ArchMemoryTraits] = None,
                 priority_refresh: int = 64,
                 prefix_caching: bool = False):
        self.hw = hardware or HardwareProfile()
        self.traits = arch_traits or ArchMemoryTraits()
        self.analyzer = WorkflowAnalyzer()
        self.profiler = DistributionProfiler()
        self.priorities = PriorityTable(interval=priority_refresh)
        # engines run the shared-prefix KV cache: memory ramps discount the
        # declared shared prefix so the dispatcher doesn't double-count it
        self.prefix_caching = prefix_caching

    # ------------------------------------------------------------------ intake
    def on_completion(self, rec: CompletionRecord):
        self.analyzer.add_record(rec)
        # single-request distribution uses pure execution latency (Eq. 2)
        self.profiler.record(rec.agent_name, rec.exec_latency, rec.output_len)
        self.priorities.tick_completion()

    def on_workflow_complete(self, msg_id: str):
        self.analyzer.finalize_trace(msg_id)
        self.priorities.maybe_refresh(
            {k: v.samples for k, v in self.analyzer.remaining.items()})

    def refresh_priorities(self):
        self.priorities.maybe_refresh(
            {k: v.samples for k, v in self.analyzer.remaining.items()}, force=True)

    # ------------------------------------------------------------------ queries
    def priority_score(self, app: str, agent: str) -> float:
        s = self.priorities.score(app, agent)
        if s == float("inf"):
            # cold start: fall back to single-request expected latency
            return 1e6 + self.profiler.expected_exec_time(agent, default=1.0)
        return s

    def remaining_stages(self, app: str, agent: str) -> int:
        return self.analyzer.remaining_stages(app, agent)

    def expected_exec_time(self, agent: str) -> float:
        return self.profiler.expected_exec_time(agent)

    def memory_ramp(self, req: Request, now: float) -> MemoryRamp:
        # conservative reservation: P75 of the agent's exec-latency samples
        # (the paper's mode estimate under-reserves for heavy-tailed agents;
        # EXPERIMENTS.md §Perf records this beyond-paper refinement)
        d = self.profiler.latency.get(req.agent_name)
        t = d.percentile(75) if d and len(d) >= 8 else self.expected_exec_time(req.agent_name)
        return make_ramp(
            prompt_len=req.prompt_len,
            expected_exec_time=t,
            decode_tok_per_s=self.hw.decode_tok_per_s,
            t_start=now,
            kv_ratio=self.traits.kv_ratio,
            state_tokens=self.traits.state_tokens,
            shared_prefix_tokens=(req.shared_prefix_len
                                  if self.prefix_caching else 0),
        )
