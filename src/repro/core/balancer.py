"""Load balancer: the queue where scheduling policy × dispatch policy meet
(Kairos Fig. 10 ①–③).  Shared verbatim by the real-engine harness and the
discrete-event simulator — only the instance objects differ.
"""
from __future__ import annotations

from typing import Callable, List

from repro.core.orchestrator import Orchestrator
from repro.core.scheduler import SchedulerPolicy
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.request import Request, RequestState


class LoadBalancer:
    def __init__(self, scheduler: SchedulerPolicy, dispatcher,
                 orchestrator: Orchestrator,
                 submit_fn: Callable[[int, Request], None],
                 max_dispatch_per_tick: int = 64,
                 strict_head: bool = False,
                 tracer: Tracer = NULL_TRACER):
        self.scheduler = scheduler
        self.dispatcher = dispatcher
        self.orch = orchestrator
        self.submit_fn = submit_fn
        self.queue: List[Request] = []
        self.max_dispatch_per_tick = max_dispatch_per_tick
        # strict_head: FCFS/vLLM semantics — the head of the ordered queue
        # blocks everything behind it (Parrot/Ayo).  Kairos instead skips
        # undispatchable requests ("remains in the queue awaiting the next
        # scheduling round", §6), which avoids dispatch-level HoL.
        self.strict_head = strict_head
        self.tracer = tracer
        self.n_scheduled = 0

    def enqueue(self, req: Request):
        req.state = RequestState.QUEUED
        self.queue.append(req)
        if self.tracer.enabled:
            self.tracer.emit("submit", req_id=req.req_id,
                             agent=req.agent_name, msg_id=req.msg_id,
                             ts=req.arrival_time,
                             upstream=req.upstream_name)

    def tick(self, now: float):
        """One scheduling round: order queue by policy (§5), dispatch in
        order with memory awareness (§6).  Requests the dispatcher rejects
        stay queued for the next round."""
        if not self.queue:
            return
        ordered = self.scheduler.order(self.queue)
        dispatched = []
        for req in ordered[: self.max_dispatch_per_tick * 4]:
            ramp = self.orch.memory_ramp(req, now)
            # starvation valve: a request stuck for a long time is force-
            # placed on the min-peak instance (engine preemption absorbs it)
            force = (now - req.arrival_time) > 30.0
            iid = self.dispatcher.dispatch(req, ramp, now, force=force)
            if iid is None:
                if self.strict_head:
                    break
                continue
            if self.tracer.enabled:
                if force:
                    self.tracer.emit("migrate-candidate", req_id=req.req_id,
                                     agent=req.agent_name, msg_id=req.msg_id,
                                     ts=now, waited=now - req.arrival_time,
                                     to=iid)
                self.tracer.emit("dispatch", req_id=req.req_id,
                                 agent=req.agent_name, msg_id=req.msg_id,
                                 ts=now, to=iid)
            self.submit_fn(iid, req)
            dispatched.append(req)
            self.n_scheduled += 1
            if len(dispatched) >= self.max_dispatch_per_tick:
                break
        if dispatched:
            gone = {r.req_id for r in dispatched}
            self.queue = [r for r in self.queue if r.req_id not in gone]

    @property
    def queued(self) -> int:
        return len(self.queue)
