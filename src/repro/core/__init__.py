# Kairos core — the paper's primary contribution: workflow orchestrator,
# workflow-aware priority scheduler, memory-aware time-slot dispatcher.
from repro.core.balancer import LoadBalancer
from repro.core.dispatcher import (
    BestFitOracleDispatcher,
    InstanceModel,
    RoundRobinDispatcher,
    TimeSlotDispatcher,
)
from repro.core.distributions import (
    ConvergenceTracker,
    DistributionProfiler,
    EmpiricalDistribution,
    wasserstein_1d,
)
from repro.core.memory_model import MemoryRamp, make_ramp
from repro.core.orchestrator import ArchMemoryTraits, HardwareProfile, Orchestrator
from repro.core.priority import PriorityTable, agent_priorities, classical_mds_1d
from repro.core.scheduler import (
    FCFSScheduler,
    KairosScheduler,
    OracleScheduler,
    SchedulerPolicy,
    TopoScheduler,
)
from repro.core.workflow import WorkflowAnalyzer, WorkflowGraph

__all__ = [
    "LoadBalancer", "BestFitOracleDispatcher", "InstanceModel",
    "RoundRobinDispatcher", "TimeSlotDispatcher", "ConvergenceTracker",
    "DistributionProfiler", "EmpiricalDistribution", "wasserstein_1d",
    "MemoryRamp", "make_ramp", "ArchMemoryTraits", "HardwareProfile",
    "Orchestrator", "PriorityTable", "agent_priorities", "classical_mds_1d",
    "FCFSScheduler", "KairosScheduler", "OracleScheduler", "SchedulerPolicy",
    "TopoScheduler", "WorkflowAnalyzer", "WorkflowGraph",
]
