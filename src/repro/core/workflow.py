"""Automated online workflow analysis (§4.2).

From completed-request records (grouped by Message ID) Kairos rebuilds the
application call graph using upstream->downstream causal edges, then
classifies each node's multiple outgoing edges as *parallel* or
*sequential* with a sweep-line over the downstream execution time spans.
It also derives the per-agent **remaining end-to-end latency** samples
that drive the priority scheduler (§5).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.core.distributions import EmpiricalDistribution
from repro.serving.request import CompletionRecord


@dataclasses.dataclass
class EdgeInfo:
    count: int = 0
    parallel: int = 0      # times this edge ran concurrently with a sibling


@dataclasses.dataclass
class WorkflowGraph:
    """Aggregated call graph for one application."""
    nodes: Set[str] = dataclasses.field(default_factory=set)
    edges: Dict[Tuple[str, str], EdgeInfo] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(EdgeInfo))
    roots: collections.Counter = dataclasses.field(default_factory=collections.Counter)

    def downstream(self, agent: str) -> List[str]:
        return [b for (a, b) in self.edges if a == agent]

    def edge_kind(self, a: str, b: str) -> str:
        e = self.edges.get((a, b))
        if e is None or e.count == 0:
            return "unknown"
        return "parallel" if e.parallel * 2 >= e.count else "sequential"

    def remaining_stages(self, agent: str) -> int:
        """Topology depth to a sink (Ayo's priority signal). Longest
        downstream path, cycle-safe."""
        seen: Set[str] = set()

        def depth(n: str) -> int:
            if n in seen:
                return 0
            seen.add(n)
            ds = self.downstream(n)
            d = 1 + max((depth(m) for m in ds), default=0)
            seen.discard(n)
            return d

        return depth(agent) if agent in self.nodes else 1


def _sweepline_parallel(spans: List[Tuple[str, float, float]]) -> Set[str]:
    """Given sibling downstream spans (name, start, end), return names that
    overlap some sibling (= parallel calls).  Classic sweep-line."""
    events = []
    for i, (_, s, e) in enumerate(spans):
        events.append((s, 1, i))   # close (0) before open (1) at the same
        events.append((e, 0, i))   # coordinate: touching spans are sequential
    events.sort()
    active: Set[int] = set()
    parallel: Set[int] = set()
    for _, kind, i in events:
        if kind == 1:              # open
            if active:
                parallel.add(i)
                parallel.update(active)
            active.add(i)
        else:                      # close
            active.discard(i)
    return {spans[i][0] for i in parallel}


class WorkflowAnalyzer:
    """Online call-graph reconstruction + remaining-latency collection."""

    def __init__(self):
        self.graphs: Dict[str, WorkflowGraph] = collections.defaultdict(WorkflowGraph)
        # per (app, agent) remaining end-to-end latency samples
        self.remaining: Dict[Tuple[str, str], EmpiricalDistribution] = \
            collections.defaultdict(EmpiricalDistribution)
        self._traces: Dict[str, List[CompletionRecord]] = collections.defaultdict(list)

    # ------------------------------------------------------------------ intake
    def add_record(self, rec: CompletionRecord):
        self._traces[rec.msg_id].append(rec)

    def finalize_trace(self, msg_id: str):
        """Workflow finished: fold its records into the graph + distributions."""
        recs = self._traces.pop(msg_id, [])
        if not recs:
            return
        app = recs[0].app_name
        g = self.graphs[app]
        by_upstream: Dict[Optional[str], List[CompletionRecord]] = collections.defaultdict(list)
        for r in recs:
            g.nodes.add(r.agent_name)
            by_upstream[r.upstream_name].append(r)
            if r.upstream_name is None:
                g.roots[r.agent_name] += 1
            else:
                g.edges[(r.upstream_name, r.agent_name)].count += 1
            # remaining end-to-end *execution* latency from this stage (§4.3-2):
            # this request's execution plus everything that starts at/after it.
            # Queue-independent, so congestion cannot feed back into the
            # priority signal (DESIGN.md §7 notes this refinement).
            remaining = sum(x.exec_latency for x in recs
                            if x.start_time >= r.start_time)
            self.remaining[(app, r.agent_name)].add(remaining)
        # sweep-line classification of multi-downstream fan-outs (§4.2)
        for up, children in by_upstream.items():
            if up is None or len(children) < 2:
                continue
            spans = [(c.agent_name, c.start_time, c.end_time) for c in children]
            for name in _sweepline_parallel(spans):
                g.edges[(up, name)].parallel += 1

    # ------------------------------------------------------------------ queries
    def remaining_samples(self, app: str, agent: str) -> List[float]:
        return self.remaining[(app, agent)].samples

    def agent_keys(self) -> List[Tuple[str, str]]:
        return [k for k, v in self.remaining.items() if len(v)]

    def remaining_stages(self, app: str, agent: str) -> int:
        return self.graphs[app].remaining_stages(agent)
