"""End-to-end driver at the paper's scale: co-located QA+RG+CG workloads
on a 4-instance shared-LLM fleet, comparing Kairos against Parrot and Ayo
with the production scheduling/dispatching code (paper §7.3).

    PYTHONPATH=src python examples/cluster_sim.py --rate 2.8
"""
import argparse
import sys

from repro.sim import colocated_apps, run_policy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=2.8)
    ap.add_argument("--duration", type=float, default=150.0)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()

    apps = colocated_apps()
    print(f"co-located workload: {[a.name for a in apps]} @ {args.rate} wf/s\n")
    print(f"{'policy':14s} {'avg':>9s} {'p90':>9s} {'p95':>9s} {'p99':>9s} "
          f"{'preempt':>8s} {'queue%':>7s}")
    summaries = {}
    for pol in ("parrot", "ayo", "kairos", "w/o-priority", "w/o-packing"):
        r = run_policy(apps, pol, rate=args.rate, duration=args.duration,
                       seed=args.seed)
        s = r.summary()
        summaries[pol] = s
        print(f"{pol:14s} {s['avg']*1e3:8.1f}ms {s['p90']*1e3:8.1f}ms "
              f"{s['p95']*1e3:8.1f}ms {s['p99']*1e3:8.1f}ms "
              f"{int(s['preempted']):8d} {s['queueing_ratio']*100:6.1f}%")

    k, p, a = (summaries[x]["avg"] for x in ("kairos", "parrot", "ayo"))
    print(f"\nKairos vs Parrot: {(p-k)/p*100:+.1f}% avg "
          f"(paper co-located: -45.1%..-72.8%)")
    print(f"Kairos vs Ayo:    {(a-k)/a*100:+.1f}% avg (paper: -6.1%..-37.9%)")
    ok = k < p and k < a * 1.05
    print("\nCLUSTER-SIM", "OK" if ok else "UNEXPECTED ORDERING")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
