"""Quickstart (paper Listing 1): a Question-Answer multi-agent app served
by Kairos over a real JAX paged-KV engine on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

from repro.agents import BaseAgent, Workflow

ROUTER_PROMPT = "You're a router assistant. Classify the question: {q}"
MATH_PROMPT = "You're a math expert. Solve step by step: {q}"
HUM_PROMPT = "You're a humanities expert. Answer with context: {q}"


class Router(BaseAgent):
    def _run_impl(self, input_data, metadata):
        q = input_data["question"]
        prompt = self.encode_prompt(ROUTER_PROMPT.format(q=q), length=12)
        result = self.generate(prompt, metadata, max_new_tokens=2)
        # route by content (synthetic: parity of the first generated token)
        next_agent = "MathAgent" if (result and result[0] % 2 == 0) else "HumanitiesAgent"
        return {"question": q}, next_agent


class MathAgent(BaseAgent):
    def _run_impl(self, input_data, metadata):
        prompt = self.encode_prompt(MATH_PROMPT.format(q=input_data["question"]), length=20)
        result = self.generate(prompt, metadata, max_new_tokens=10)
        return {"answer": result, "by": self.name}, None


class HumanitiesAgent(BaseAgent):
    def _run_impl(self, input_data, metadata):
        prompt = self.encode_prompt(HUM_PROMPT.format(q=input_data["question"]), length=28)
        result = self.generate(prompt, metadata, max_new_tokens=16)
        return {"answer": result, "by": self.name}, None


def main():
    wf = Workflow(app_name="QA", n_instances=1, num_blocks=128, block_size=8)
    wf.add_engine("vllm-0", model="qwen3-1.7b")           # reduced variant on CPU
    wf.add_agent("Router", Router, use_model="qwen3-1.7b")
    wf.add_agent("MathAgent", MathAgent, use_model="qwen3-1.7b")
    wf.add_agent("HumanitiesAgent", HumanitiesAgent, use_model="qwen3-1.7b")

    questions = [f"question number {i}: what is {i}*{i+1}?" for i in range(6)]
    ids = [wf.submit_task("Router", {"question": q}) for q in questions]
    results = wf.run(timeout=180)

    print(f"\ncompleted {len(results)}/{len(ids)} workflows")
    for mid in ids:
        r = results.get(mid, {})
        print(f"  {mid}: answered_by={r.get('by')} tokens={len(r.get('answer', []))}")

    print("\nlearned agent profiles (output-length modes):")
    for a in wf.orch.profiler.agents():
        print(f"  {a:18s} out_len~{wf.orch.profiler.expected_output_len(a)} "
              f"exec~{wf.orch.profiler.expected_exec_time(a):.3f}s")
    wf.orch.refresh_priorities()
    print("\nworkflow-aware priorities (lower = scheduled first):")
    for k, v in sorted(wf.orch.priorities.scores.items(), key=lambda kv: kv[1]):
        print(f"  {k[1]:18s} {v:.3f}")
    ok = len(results) == len(ids)
    print("\nQUICKSTART", "OK" if ok else "INCOMPLETE")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
