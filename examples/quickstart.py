"""Quickstart (paper Listing 1): a Question-Answer multi-agent app served
by Kairos over a real JAX paged-KV engine on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

from repro.agents import BaseAgent, Workflow
from repro.serving import ServingConfig

# Each agent's fixed preamble is declared as a ``system_prompt``: with
# ``prefix_caching=True`` its KV is computed once per instance and shared
# across every call (see src/repro/serving/prefix_cache.py).
ROUTER_SYS = "You're a router assistant. Classify the incoming question into math or humanities."
MATH_SYS = "You're a math expert. Solve the problem step by step, showing your work."
HUM_SYS = "You're a humanities expert. Answer with historical and cultural context."


class Router(BaseAgent):
    system_prompt = ROUTER_SYS

    def _run_impl(self, input_data, metadata):
        q = input_data["question"]
        prompt = self.encode_prompt(q, length=12)
        result = self.generate(prompt, metadata, max_new_tokens=2)
        # route by content (synthetic: parity of the first generated token)
        next_agent = "MathAgent" if (result and result[0] % 2 == 0) else "HumanitiesAgent"
        return {"question": q}, next_agent


class MathAgent(BaseAgent):
    system_prompt = MATH_SYS

    def _run_impl(self, input_data, metadata):
        prompt = self.encode_prompt(input_data["question"], length=20)
        result = self.generate(prompt, metadata, max_new_tokens=10)
        return {"answer": result, "by": self.name}, None


class HumanitiesAgent(BaseAgent):
    system_prompt = HUM_SYS

    def _run_impl(self, input_data, metadata):
        prompt = self.encode_prompt(input_data["question"], length=28)
        result = self.generate(prompt, metadata, max_new_tokens=16)
        return {"answer": result, "by": self.name}, None


def main():
    # prefix_caching: shared-prefix KV reuse across agent calls (the knob
    # also teaches the dispatcher's memory ramps about the discount)
    wf = Workflow(app_name="QA", config=ServingConfig(
        n_instances=1, num_blocks=128, block_size=8, max_batch=4,
        prefix_caching=True))
    wf.add_engine("vllm-0", model="qwen3-1.7b")           # reduced variant on CPU
    wf.add_agent("Router", Router, use_model="qwen3-1.7b")
    wf.add_agent("MathAgent", MathAgent, use_model="qwen3-1.7b")
    wf.add_agent("HumanitiesAgent", HumanitiesAgent, use_model="qwen3-1.7b")

    questions = [f"question number {i}: what is {i}*{i+1}?" for i in range(6)]
    ids = [wf.submit_task("Router", {"question": q}) for q in questions]
    results = wf.run(timeout=180)

    print(f"\ncompleted {len(results)}/{len(ids)} workflows")
    for mid in ids:
        r = results.get(mid, {})
        print(f"  {mid}: answered_by={r.get('by')} tokens={len(r.get('answer', []))}")

    print("\nlearned agent profiles (output-length modes):")
    for a in wf.orch.profiler.agents():
        print(f"  {a:18s} out_len~{wf.orch.profiler.expected_output_len(a)} "
              f"exec~{wf.orch.profiler.expected_exec_time(a):.3f}s")
    wf.orch.refresh_priorities()
    print("\nworkflow-aware priorities (lower = scheduled first):")
    for k, v in sorted(wf.orch.priorities.scores.items(), key=lambda kv: kv[1]):
        print(f"  {k[1]:18s} {v:.3f}")

    pc = wf.prefix_cache_stats()
    print(f"\nprefix cache: {pc['prefill_tokens_saved']} of "
          f"{pc['prefill_tokens'] + pc['prefill_tokens_saved']} prompt tokens "
          f"served from shared KV ({pc['savings']:.0%} prefill saved)")
    ok = len(results) == len(ids)
    print("\nQUICKSTART", "OK" if ok else "INCOMPLETE")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
