"""Train a small decoder LM with the full stack: synthetic packed data
pipeline -> model zoo -> Adam train step, on CPU.

    PYTHONPATH=src python examples/train_small.py --steps 60
"""
import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.training.data import DataConfig, PackedStream
from repro.training.optimizer import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    init_state, train_step = make_train_step(model, "adam")
    state = init_state(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    data = PackedStream(DataConfig(cfg.vocab_size, args.seq, args.batch))
    step_fn = jax.jit(train_step, donate_argnums=(0,))

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = data.batch(step)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'OK — decreasing' if last < first else 'NOT decreasing'})")
    return 0 if last < first else 1


if __name__ == "__main__":
    sys.exit(main())
