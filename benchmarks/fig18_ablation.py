"""Fig. 18 / §7.6: ablations — w/o priority scheduling and w/o memory-aware
packing, across request rates.

Paper: priority gives 1.63x at the 50%-queueing point (38.8–69.6% across
rates); packing gives 1.12x (9.5–10.6%)."""
from __future__ import annotations

from benchmarks.common import Row, row, sim
from repro.sim import colocated_apps


def run(quick: bool = True):
    apps = colocated_apps()
    rates = [2.8] if quick else [2.0, 2.4, 2.8, 3.2]
    rows: list[Row] = []
    for rate in rates:
        s = {p: sim(apps, p, rate=rate).summary()
             for p in ("kairos", "w/o-priority", "w/o-packing")}
        k = s["kairos"]["avg"]
        rows.append(row(f"fig18.rate{rate}.priority_effect",
                        s["w/o-priority"]["avg"] / k,
                        f"{s['w/o-priority']['avg']/k:.2f}x slower w/o priority "
                        f"(paper: 1.63x @50% queueing)"))
        rows.append(row(f"fig18.rate{rate}.packing_effect",
                        s["w/o-packing"]["avg"] / k,
                        f"{s['w/o-packing']['avg']/k:.2f}x slower w/o packing "
                        f"(paper: 1.12x)"))
    return rows
