"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Per (arch × shape × mesh):
    compute term    = HLO_FLOPs_per_dev / peak_FLOP/s
    memory term     = HLO_bytes_per_dev / HBM_bw
    collective term = collective_bytes_per_dev / link_bw
plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the useful-
compute ratio MODEL_FLOPS / (HLO_FLOPs × n_dev).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment brief).
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

HEADER = ("arch", "shape", "mesh", "t_compute", "t_memory", "t_collective",
          "bottleneck", "model_flops", "useful_ratio", "peak_GiB_dev")


def model_flops(arch: str, shape: str) -> float:
    """MODEL_FLOPS = 6·N·D with N = active params, D = tokens processed."""
    from repro.configs import INPUT_SHAPES, get_config
    cfg = get_config(arch)
    sc = INPUT_SHAPES[shape]
    n = cfg.active_param_count()
    if sc.kind == "train":
        d = sc.global_batch * sc.seq_len
        return 6.0 * n * d                       # fwd + bwd
    if sc.kind == "prefill":
        d = sc.global_batch * sc.seq_len
        return 2.0 * n * d
    return 2.0 * n * sc.global_batch             # decode: one token per seq


def analyze_record(rec: Dict) -> Optional[Dict]:
    """Blend of sources (see module docstring + EXPERIMENTS.md §Roofline):
    compute/memory terms from the exact analytic model (XLA cost_analysis
    under-counts lax.scan bodies); collective term from the compiled HLO
    with while-trip-count correction; peak memory from buffer assignment
    (loop-correct)."""
    if rec.get("status") != "ok":
        return None
    from benchmarks.analytic import roofline_terms
    coll = rec.get("collectives", {}) or {}
    coll_bytes = float(sum(v for v in coll.values() if isinstance(v, (int, float))))
    n_dev = rec.get("n_devices", 256)

    at = roofline_terms(rec["arch"], rec["shape"], n_dev, PEAK_FLOPS, HBM_BW)
    t_c, t_m = at["t_compute"], at["t_memory"]
    t_x = coll_bytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (at["flops_dev"] * n_dev) if at["flops_dev"] else float("nan")
    peak = ((rec.get("memory") or {}).get("peak_bytes") or 0) / 2 ** 30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "bottleneck": bottleneck, "model_flops": mf, "useful_ratio": useful,
        "peak_GiB_dev": peak, "collective_bytes_dev": coll_bytes,
        "hlo_flops_dev": (rec.get("cost", {}) or {}).get("flops"),
        "analytic_flops_dev": at["flops_dev"], "analytic_bytes_dev": at["bytes_dev"],
    }


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def render_table(rows: List[Dict]) -> str:
    out = []
    out.append(f"{'arch':22s} {'shape':12s} {'mesh':8s} {'compute':>9s} "
               f"{'memory':>9s} {'collect':>9s} {'bound':>10s} {'useful':>7s} "
               f"{'GiB/dev':>8s}")
    for r in rows:
        out.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
            f"{fmt_s(r['t_compute']):>9s} {fmt_s(r['t_memory']):>9s} "
            f"{fmt_s(r['t_collective']):>9s} {r['bottleneck']:>10s} "
            f"{r['useful_ratio']*100:6.1f}% {r['peak_GiB_dev']:8.2f}")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-json", default="experiments/dryrun.json")
    ap.add_argument("--out", default="experiments/roofline.json")
    ap.add_argument("--mesh", default=None, help="filter: 16x16 or 2x16x16")
    args = ap.parse_args(argv)
    with open(args.dryrun_json) as f:
        records = json.load(f)
    rows = [r for r in (analyze_record(rec) for rec in records) if r]
    if args.mesh:
        rows = [r for r in rows if r["mesh"] == args.mesh]
    print(render_table(rows))
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
