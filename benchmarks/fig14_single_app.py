"""Fig. 14: end-to-end program-level token latency per individual
application × dataset, Kairos vs Parrot vs Ayo (avg + P90).

Paper: Kairos cuts avg latency 17.8–28.4% vs Parrot, 5.8–10.8% vs Ayo.
"""
from __future__ import annotations

from benchmarks.common import RATE_SINGLE, Row, pct_gain, row, sim
from repro.sim import make_app

GROUPS = {"QA": ["G+M", "M+W", "S+S"], "RG": ["TQ", "NCD", "NQ"],
          "CG": ["HE", "MBPP", "APPS"]}


def run(quick: bool = True):
    rows: list[Row] = []
    for app, groups in GROUPS.items():
        for g in (groups[:1] if quick else groups):
            res = {p: sim([make_app(app, g)], p, rate=RATE_SINGLE[app])
                   for p in ("parrot", "ayo", "kairos")}
            s = {p: r.summary() for p, r in res.items()}
            for metric in ("avg", "p90"):
                k, pa, ay = (s[p][metric] for p in ("kairos", "parrot", "ayo"))
                rows.append(row(
                    f"fig14.{app}[{g}].{metric}", k,
                    f"kairos={k*1e3:.1f}ms vs parrot {pct_gain(pa, k):+.1f}% "
                    f"vs ayo {pct_gain(ay, k):+.1f}% (paper avg: 17.8-28.4%/5.8-10.8%)"))
    return rows
