"""Instance-level priority + chunked prefill under a long-prompt mix.

The §2.2 pathology the unified batch scheduler targets: a long prompt
admitted monolithically stalls every running decode for a whole
iteration, and FCFS instance queues let low-priority long prompts sit in
front of high-priority short work.  This benchmark runs a decode-heavy
multi-agent workload (QA + RG) co-located with a long-prompt ingestion
app through the discrete-event simulator and compares

  * ``baseline``  — FCFS instance queues + monolithic prefill (the
    pre-refactor engine behaviour),
  * ``+priority`` — Kairos-ordered instance queues, monolithic prefill,
  * ``+chunked``  — FCFS instance queues, chunked prefill (``CHUNK`` =
    512-token per-iteration budget),
  * ``kairos``    — both: priority-ordered instance queues + chunked
    prefill (the full batch-scheduler configuration).

Headline target: **p99 workflow token latency** of the full
configuration beats the FCFS/monolithic baseline.

Run: ``PYTHONPATH=src python -m benchmarks.chunked_prefill``
"""
from __future__ import annotations

import math
from typing import List

import numpy as np

from benchmarks.common import Row, pct_gain, row
from repro.sim import (
    AgentProfile,
    AppSpec,
    SimConfig,
    Simulation,
    make_app,
)

CHUNK = 512     # per-iteration prefill token budget (Sarathi-style)


def long_prompt_app() -> AppSpec:
    """Document-ingestion agent: ~2.2k-token prompts, tiny outputs —
    each monolithic admission stalls the whole batch ~0.3 s."""
    agents = {"Ingestor": AgentProfile(
        "Ingestor", out_mu=math.log(12), out_sigma=0.3,
        prompt_mu=math.log(2200), prompt_sigma=0.25)}
    return AppSpec("Ingest", agents, "Ingestor",
                   lambda agent, rng, hops: [], "sequential")


def mixed_workload() -> List[AppSpec]:
    return [make_app("QA", "G+M"), make_app("RG", "TQ"), long_prompt_app()]


def _pooled(apps, seeds, duration, **kw) -> dict:
    """Workflow token latencies pooled across seeds (stable tail at
    moderate run lengths), plus summed preemptions."""
    lats, preempted = [], 0
    for seed in seeds:
        cfg = SimConfig(apps=apps, policy="kairos", rate=2.5,
                        duration=duration, n_instances=2, seed=seed, **kw)
        res = Simulation(cfg).run()
        lats.append(res.token_latencies())
        preempted += res.n_preempted
    t = np.concatenate(lats)
    return {"avg": float(np.mean(t)), "p95": float(np.percentile(t, 95)),
            "p99": float(np.percentile(t, 99)), "n": len(t),
            "preempted": preempted}


def run(quick: bool = True) -> List[Row]:
    apps = mixed_workload()
    dur = 160.0 if quick else 300.0
    seeds = (0, 1, 2)
    variants = {
        "baseline": dict(instance_priority=False, prefill_chunk_tokens=None),
        "+priority": dict(instance_priority=True, prefill_chunk_tokens=None),
        "+chunked": dict(instance_priority=False, prefill_chunk_tokens=CHUNK),
        "kairos": dict(instance_priority=True, prefill_chunk_tokens=CHUNK),
    }
    res = {name: _pooled(apps, seeds, dur, **kw)
           for name, kw in variants.items()}

    rows: List[Row] = []
    base = res["baseline"]
    for name in ("+priority", "+chunked", "kairos"):
        s = res[name]
        rows.append(row(
            f"chunked_prefill.{name}", s["p99"],
            f"p99 {base['p99']*1e3:.1f}ms->{s['p99']*1e3:.1f}ms "
            f"({pct_gain(base['p99'], s['p99']):+.1f}%) "
            f"avg {pct_gain(base['avg'], s['avg']):+.1f}% "
            f"p95 {pct_gain(base['p95'], s['p95']):+.1f}% "
            f"preempt {base['preempted']}->{s['preempted']} n={s['n']}"))
    gain = pct_gain(base["p99"], res["kairos"]["p99"])
    rows.append(row(
        "chunked_prefill.headline", res["kairos"]["p99"],
        f"p99 token latency gain vs FCFS/monolithic: {gain:+.1f}% "
        f"(target: > 0)"))
    assert res["kairos"]["p99"] < base["p99"], (
        "instance priority + chunked prefill must improve p99 workflow "
        f"token latency: {res['kairos']['p99']:.4f} vs {base['p99']:.4f}")
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for n, us, derived in run(quick=True):
        print(f"{n},{us:.2f},{derived}")
