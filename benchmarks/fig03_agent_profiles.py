"""Figs 3–6: inter-agent output-length / latency differences.

Validates the motivating observation: agents differ strongly (Router vs
Math/Humanities up to ~25x in latency) while each agent is stable across
dataset groups.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, row, sim
from repro.sim import make_app


def run(quick: bool = True):
    rows: list[Row] = []
    groups = ["G+M"] if quick else ["G+M", "M+W", "S+S"]
    for g in groups:
        res = sim([make_app("QA", g)], "parrot", rate=6.0, duration=100.0)
        by_agent = {}
        for r in res.requests:
            by_agent.setdefault(r.agent_name, []).append(r)
        lat = {a: np.mean([x.exec_latency for x in rs]) for a, rs in by_agent.items()}
        out = {a: np.mean([x.output_len for x in rs]) for a, rs in by_agent.items()}
        spread = max(lat.values()) / max(min(lat.values()), 1e-9)
        for a in sorted(lat):
            rows.append(row(f"fig03.QA[{g}].{a}", lat[a],
                            f"out_len={out[a]:.0f},exec_s={lat[a]:.2f}"))
        rows.append(row(f"fig04.QA[{g}].latency_spread", 0.0,
                        f"max/min={spread:.1f}x (paper: up to 25.1x)"))
    return rows
