"""Chaos drain: planned instance crashes mid-drain + overload shedding.

Two halves, one BENCH JSON (gated by ``check_regression.py`` under
``chaos_drain``):

**A. Crash recovery losslessness (real cluster, CI-gated EXACT).**  A
shared-prefix workload drains through a real 3-instance
:class:`ServingCluster` while a seeded :class:`FaultPlan` kills
instances mid-drain (one spared survivor).  Every in-flight request on
a dead instance is reconstructed — re-queued with prompt + emitted
tokens so the argmax decode replays bit-identically — and the drained
token streams must equal a fault-free drain of the same workload:
``lost_requests``, ``recovered_token_mismatch`` and
``chaos_failed_requests`` are all gated at exactly 0.  The replay tax
(``recovery_replay_overhead``: re-prefilled tokens per baseline output
token) is hardware-independent and gated by a ceiling.

**B. SLO-aware shedding under overload (deterministic sim).**  The same
seeded overload trace runs twice through the discrete-event simulator —
valve off and valve on (``slo_e2e_s`` set).  Shedding the requests
least likely to meet their deadline must keep goodput-under-SLO
*strictly above* the no-shedding collapse
(``shed_vs_noshed_goodput_ratio`` ratio-floor >= 1.0, measured ~1.7x)
and must not drop below the committed baseline (``goodput_slo_shed``).
A small faulted sim rides along: ``sim_faulted_lost`` and the
workflow-count delta vs its fault-free twin are gated at exactly 0.

Run: ``PYTHONPATH=src python -m benchmarks.chaos_drain [--smoke]``
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Row, row, write_bench_json

CHAOS_SEED = 5          # names the crash plan (2 crashes, instance 0 spared)
SIM_FAULT_SEED = 3      # names the sim's crash+straggle+oom plan
SLO_E2E_S = 12.0        # request arrival->finish deadline (sim, part B)


# =============================================================================
# part A: crashed drain on a real cluster
# =============================================================================


def _model_and_params():
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


def _workload(n_reqs: int, max_new: int) -> List:
    """Shared-prefix requests with varying unique tails, so recovery
    re-prefills hit surviving prefix caches."""
    from repro.serving import Request
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, 500, 16).astype(np.int32)
    reqs = []
    for i in range(n_reqs):
        toks = np.concatenate(
            [prefix, rng.integers(0, 500, 5 + (i % 9)).astype(np.int32)])
        reqs.append(Request(
            agent_name=f"a{i % 3}", msg_id=f"m{i}", prompt_len=len(toks),
            prompt_tokens=toks, max_new_tokens=max_new,
            arrival_time=float(i)))
    return reqs


def _cluster_cfg():
    from repro.serving import ServingConfig
    return ServingConfig(num_blocks=64, block_size=8, max_batch=4,
                         n_instances=3, policy="fcfs", prefix_caching=True,
                         recovery_retries=3)


def _orch():
    from repro.core import Orchestrator
    from repro.core.orchestrator import HardwareProfile
    return Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0, kv_capacity_tokens=64 * 8))


def _drain(model, params, wl_cfg: Dict, faults=None):
    from repro.serving import ServingCluster, reset_request_ids
    reset_request_ids()
    cluster = ServingCluster.from_config(model, params, _orch(),
                                         _cluster_cfg(), faults=faults)
    for q in _workload(wl_cfg["n_reqs"], wl_cfg["max_new"]):
        cluster.submit(q)
    done = []
    for _ in range(100_000):
        done.extend(cluster.step())
        if not cluster.has_work:
            break
    snap = cluster.metrics_snapshot()
    cluster.close()
    return done, snap


def measure_chaos(smoke: bool) -> Dict:
    from repro.serving import FaultPlan, RequestState
    model, params = _model_and_params()
    wl = {"n_reqs": 8 if smoke else 16, "max_new": 10 if smoke else 14}
    base_done, _ = _drain(model, params, wl)
    base = {q.msg_id: list(q.output_tokens) for q in base_done}
    base_tokens = sum(len(v) for v in base.values())

    plan = FaultPlan.generate(CHAOS_SEED, [0, 1, 2], horizon=10,
                              n_crashes=2, spare=(0,))
    done, snap = _drain(model, params, wl, faults=plan)
    failed = [q for q in done if q.state is RequestState.FAILED]
    chaos = {q.msg_id: list(q.output_tokens) for q in done
             if q.state is not RequestState.FAILED}
    lost = len(set(base) - set(chaos))
    mismatch = sum(chaos.get(k) != base[k] for k in base if k in chaos)
    return {
        "lost_requests": float(lost),
        "recovered_token_mismatch": float(mismatch),
        "chaos_failed_requests": float(len(failed)),
        "chaos_crashes": snap["n_crashes"],
        "chaos_reconstructed": snap["n_reconstructed"],
        "chaos_replayed_tokens": snap["n_replayed_tokens"],
        "chaos_surviving_instances": snap["n_instances"],
        "recovery_replay_overhead": snap["n_replayed_tokens"]
        / max(base_tokens, 1),
    }


# =============================================================================
# part B: shedding under overload + a faulted sim (deterministic)
# =============================================================================


def _sim_kw(smoke: bool, **over):
    from repro.sim.workload import make_app
    kw = dict(apps=[make_app("QA", "G+M")], policy="kairos", rate=4.0,
              duration=10.0 if smoke else 30.0, n_instances=3,
              kv_capacity_tokens=4096, block_size=16, max_batch=8, seed=1)
    kw.update(over)
    return kw


def measure_shed(smoke: bool) -> Dict:
    from repro.sim.simulator import SimConfig, Simulation
    kw = _sim_kw(smoke, rate=12.0, duration=20.0 if smoke else 45.0,
                 n_instances=2, kv_capacity_tokens=3072, seed=3)
    res_off = Simulation(SimConfig(**kw)).run()
    res_on = Simulation(SimConfig(slo_e2e_s=SLO_E2E_S, shed_queue_high=4.0,
                                  **kw)).run()
    g_off = res_off.goodput(SLO_E2E_S)
    g_on = res_on.goodput(SLO_E2E_S)
    return {
        "goodput_slo_shed": g_on,
        "goodput_slo_noshed": g_off,
        "shed_vs_noshed_goodput_ratio": g_on / max(g_off, 1e-9),
        "n_shed": float(res_on.n_shed),
        "shed_p99_s": res_on.summary()["p99"],
        "noshed_p99_s": res_off.summary()["p99"],
    }


def measure_sim_faults(smoke: bool) -> Dict:
    from repro.serving import FaultPlan
    from repro.sim.simulator import SimConfig, Simulation
    plan = FaultPlan.generate(SIM_FAULT_SEED, [0, 1, 2], horizon=12,
                              n_crashes=1, n_straggles=1, n_ooms=1,
                              spare=(0,))
    kw = _sim_kw(smoke)
    res = Simulation(SimConfig(faults=plan, recovery_backoff_s=0.1,
                               **kw)).run()
    res0 = Simulation(SimConfig(**kw)).run()
    return {
        "sim_faulted_lost": float(res.n_lost),
        "sim_faulted_workflows_delta": float(
            abs(len(res.workflows) - len(res0.workflows))),
        "sim_crashes": float(res.n_crashes),
        "sim_reconstructed": float(res.n_reconstructed),
    }


# =============================================================================
# driver
# =============================================================================


def measure(smoke: bool = True) -> Dict:
    cfg = {"smoke": smoke, "chaos_seed": CHAOS_SEED,
           "sim_fault_seed": SIM_FAULT_SEED, "slo_e2e_s": SLO_E2E_S}
    t0 = time.time()
    metrics = {}
    metrics.update(measure_chaos(smoke))
    metrics.update(measure_shed(smoke))
    metrics.update(measure_sim_faults(smoke))
    metrics["wall_total_s"] = time.time() - t0
    return {"config": cfg, "metrics": metrics}


def run(quick: bool = True) -> List[Row]:
    m = measure(smoke=quick)["metrics"]
    return [
        row("chaos_lost_requests", m["lost_requests"] * 1e-6,
            f"crashes={m['chaos_crashes']:.0f}"
            f" replayed={m['chaos_replayed_tokens']:.0f}"),
        row("chaos_recovered_mismatch",
            m["recovered_token_mismatch"] * 1e-6,
            f"reconstructed={m['chaos_reconstructed']:.0f}"),
        row("chaos_goodput_shed", m["goodput_slo_shed"] * 1e-6,
            f"noshed={m['goodput_slo_noshed']:.3f}"
            f" shed={m['n_shed']:.0f}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI smoke job")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    doc = measure(smoke=args.smoke)
    for k in sorted(doc["metrics"]):
        print(f"{k} = {doc['metrics'][k]}")
    m = doc["metrics"]
    bad = (m["lost_requests"] + m["recovered_token_mismatch"]
           + m["chaos_failed_requests"] + m["sim_faulted_lost"])
    if bad:
        raise SystemExit(
            f"FAIL: chaos oracle violated (lost={m['lost_requests']:.0f}"
            f" mismatch={m['recovered_token_mismatch']:.0f}"
            f" failed={m['chaos_failed_requests']:.0f}"
            f" sim_lost={m['sim_faulted_lost']:.0f})")
    if m["shed_vs_noshed_goodput_ratio"] <= 1.0:
        raise SystemExit(
            "FAIL: shedding did not improve goodput under SLO "
            f"(ratio {m['shed_vs_noshed_goodput_ratio']:.3f})")
    if args.json:
        write_bench_json(args.json, "chaos_drain", doc["config"],
                         doc["metrics"])
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
