"""Shared-prefix KV reuse: prefill-token savings and latency deltas.

Three parts:

  1. **Real engine, correctness + savings** — a multi-agent workload where
     every agent resends its system prompt (the quickstart pattern) is
     served twice by the paged JAX engine: cache-off and cache-on.  The
     generated tokens must be identical; the prefill-token reduction must
     clear 30%.
  2. **Real engine, hit-rate sweep** — system-prompt length sweeps the
     shareable fraction of each prompt; reports measured savings and
     engine wall-time per point.
  3. **Simulator** — the same scenario through the discrete-event sim
     (identical PrefixCache/BlockManager code, calibrated cache-hit
     prefill cost), with/without reuse, at Fig-14 scale.

Run: ``PYTHONPATH=src python -m benchmarks.prefix_reuse``
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, pct_gain, row
from repro.sim import SimConfig, Simulation, make_app, with_shared_prefixes


def _make_engine(prefix_caching: bool, num_blocks: int = 192, block_size: int = 8):
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import LLMEngine, PagedModelRunner

    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    runner = PagedModelRunner(model, params, num_blocks=num_blocks,
                              block_size=block_size, max_batch=4)
    return LLMEngine(runner, instance_id=0, max_batch=4,
                     enable_prefix_cache=prefix_caching), cfg.vocab_size


def _agent_requests(vocab: int, sys_len: int, n_per_agent: int,
                    uniq_len: int = 10, n_agents: int = 3) -> List:
    from repro.serving import Request

    rng = np.random.default_rng(7)
    sys_prompts = [rng.integers(0, vocab, sys_len).astype(np.int32)
                   for _ in range(n_agents)]
    reqs = []
    for i in range(n_per_agent * n_agents):
        a = i % n_agents
        toks = np.concatenate(
            [sys_prompts[a], rng.integers(0, vocab, uniq_len).astype(np.int32)]) \
            if sys_len else rng.integers(0, vocab, uniq_len).astype(np.int32)
        reqs.append(Request(
            agent_name=f"agent{a}", msg_id=f"m{i}", prompt_len=len(toks),
            prompt_tokens=toks, max_new_tokens=4, shared_prefix_len=sys_len,
            arrival_time=float(i)))
    return reqs


def _serve(prefix_caching: bool, sys_len: int, n_per_agent: int = 4):
    eng, vocab = _make_engine(prefix_caching)
    for r in _agent_requests(vocab, sys_len, n_per_agent):
        eng.submit(r)
    t0 = time.time()
    done = eng.run_until_drained(max_steps=20_000)
    wall = time.time() - t0
    outputs = sorted((r.msg_id, tuple(r.output_tokens)) for r in done)
    return eng, outputs, wall


def run(quick: bool = True) -> List[Row]:
    rows: List[Row] = []

    # -- 1. correctness + headline savings (engine) --------------------------
    sys_len = 64
    eng_off, out_off, wall_off = _serve(False, sys_len)
    eng_on, out_on, wall_on = _serve(True, sys_len)
    identical = out_off == out_on
    prefilled = eng_on.stats.prefill_tokens
    saved = eng_on.stats.prefill_tokens_saved
    savings = saved / max(prefilled + saved, 1)
    rows.append(row(
        "prefix_reuse.engine", wall_on,
        f"identical_tokens={identical} prefill_saved={savings:.1%} "
        f"({saved}/{prefilled + saved} tok) hit_rate="
        f"{eng_on.prefix_cache.stats.hit_rate():.0%} "
        f"wall {wall_off:.2f}s->{wall_on:.2f}s (target: identical, >=30%)"))
    assert identical, "cache-on run must generate identical tokens"
    assert savings >= 0.30, f"prefill savings {savings:.1%} below 30% target"

    # -- 2. hit-rate sweep (engine) ------------------------------------------
    for s in ([32, 96] if quick else [0, 16, 32, 64, 96, 128]):
        eng, _, wall = _serve(True, s, n_per_agent=2 if quick else 4)
        st = eng.stats
        sv = st.prefill_tokens_saved / max(st.prefill_tokens
                                           + st.prefill_tokens_saved, 1)
        rows.append(row(
            f"prefix_reuse.sweep.sys{s}", wall,
            f"saved={sv:.1%} hit_rate={eng.prefix_cache.stats.hit_rate():.0%} "
            f"evicted={eng.prefix_cache.stats.n_evicted}"))

    # -- 3. simulator with cache-hit cost modeling ---------------------------
    apps = [with_shared_prefixes(make_app("QA", "G+M"), 128)]
    dur = 60.0 if quick else 150.0
    res = {}
    for pc in (False, True):
        cfg = SimConfig(apps=apps, policy="kairos", rate=5.0, duration=dur,
                        n_instances=2, prefix_caching=pc, seed=1)
        res[pc] = Simulation(cfg).run()
    s_off, s_on = res[False].summary(), res[True].summary()
    rows.append(row(
        "prefix_reuse.sim.kairos", s_on["avg"],
        f"avg {s_off['avg']*1e3:.1f}ms->{s_on['avg']*1e3:.1f}ms "
        f"({pct_gain(s_off['avg'], s_on['avg']):+.1f}%) "
        f"p95 {pct_gain(s_off['p95'], s_on['p95']):+.1f}% "
        f"prefill_saved={res[True].prefill_savings:.1%} "
        f"preempt {int(s_off['preempted'])}->{int(s_on['preempted'])}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for n, us, derived in run(quick=True):
        print(f"{n},{us:.2f},{derived}")
