"""§7.7: Kairos overheads — MDS priority recomputation vs agent count,
queue sorting, time-slot packing evaluation.

Paper: MDS 0.1s..4.3s for 10..5000 agents; sort ~3.6ms; packing ~4.1ms.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, row
from repro.core import InstanceModel, KairosScheduler, TimeSlotDispatcher, agent_priorities, make_ramp
from repro.serving.request import Request


def _time(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    rows: list[Row] = []
    for n_agents in ([10, 100, 500] if quick else [10, 100, 500, 2000, 5000]):
        samples = {("app", f"a{i}"): rng.lognormal(rng.uniform(0, 3), 0.5, 64)
                   for i in range(n_agents)}
        t = _time(lambda: agent_priorities(samples), reps=1 if n_agents > 500 else 3)
        rows.append(row(f"overhead.mds.{n_agents}_agents", t,
                        f"{t:.3f}s (paper: 0.1-4.3s for 10-5000)"))

    # queue sorting (paper: ~3.6 ms)
    scores = {f"a{i}": float(i) for i in range(50)}
    sched = KairosScheduler(lambda app, a: scores[a])
    queue = [Request(agent_name=f"a{rng.integers(50)}", msg_id=str(i),
                     arrival_time=float(i), app_start_time=float(i))
             for i in range(1000)]
    t = _time(lambda: sched.order(queue))
    rows.append(row("overhead.sort.1000_requests", t, f"{t*1e3:.2f}ms (paper ~3.6ms)"))

    # time-slot packing evaluation (paper: ~4.1 ms)
    insts = [InstanceModel(i, 100_000) for i in range(4)]
    disp = TimeSlotDispatcher(insts)
    for i in range(200):
        disp.instances[i % 4].ramps[i] = make_ramp(300, 20.0, 25.0, float(i % 17))
    req = Request(agent_name="x", msg_id="m")
    ramp = make_ramp(300, 20.0, 25.0, 20.0)

    def pack():
        disp._cache_now = float("nan")
        disp.dispatch(req, ramp, 20.0)
        for inst in disp.instances.values():
            inst.ramps.pop(req.req_id, None)

    t = _time(pack)
    rows.append(row("overhead.packing.4x200_ramps", t, f"{t*1e3:.2f}ms (paper ~4.1ms)"))
    return rows
