"""Fig. 15: co-located QA+RG+CG on the shared 8B-class LLM fleet.

Paper: Kairos vs Parrot -45.1..-72.8% avg, -69.6..-81.9% P99;
vs Ayo -6.1..-37.9% avg."""
from __future__ import annotations

from benchmarks.common import RATE_COLOC, Row, pct_gain, row, sim
from repro.sim import colocated_apps


def run(quick: bool = True):
    apps = colocated_apps()
    rates = [RATE_COLOC] if quick else [2.4, 2.8, 3.2]
    rows: list[Row] = []
    for rate in rates:
        s = {p: sim(apps, p, rate=rate).summary()
             for p in ("parrot", "ayo", "kairos")}
        for metric in ("avg", "p90", "p95", "p99"):
            k = s["kairos"][metric]
            rows.append(row(
                f"fig15.rate{rate}.{metric}", k,
                f"kairos={k*1e3:.1f}ms vs parrot {pct_gain(s['parrot'][metric], k):+.1f}% "
                f"vs ayo {pct_gain(s['ayo'][metric], k):+.1f}%"))
    return rows
