"""Fig. 8: under FCFS/Topo there is no useful correlation between queueing
order and inference latency — the motivation for latency-aware priorities."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, row, sim
from repro.sim import colocated_apps


def _spearman(a, b) -> float:
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    ra -= ra.mean()
    rb -= rb.mean()
    return float((ra * rb).sum() / np.sqrt((ra ** 2).sum() * (rb ** 2).sum()))


def run(quick: bool = True):
    rows: list[Row] = []
    for pol in ("parrot", "ayo"):
        res = sim(colocated_apps(), pol, rate=2.8)
        reqs = [r for r in res.requests if r.exec_start_time >= 0]
        qrank = [r.exec_start_time for r in reqs]
        lat = [r.exec_latency for r in reqs]
        rho = _spearman(np.asarray(qrank), np.asarray(lat))
        rows.append(row(f"fig08.{pol}.spearman", abs(rho),
                        f"rho={rho:+.3f} (≈0 -> scheduling ignores latency)"))
    return rows
