"""Fig. 9 / §2.2.3: preemption & recompute waste under memory-oblivious
Round-Robin vs memory-aware dispatching (paper: 18.4% of requests
preempted, 14.2% of memory wasted at 8 req/s)."""
from __future__ import annotations

from benchmarks.common import row, sim
from repro.sim import colocated_apps


def _waste(res) -> float:
    """Fraction of decoded tokens thrown away by preemption-recompute."""
    wasted = sum(r.n_preemptions * max(r.output_len, 1) for r in res.requests)
    total = sum(r.output_len for r in res.requests) + wasted
    return wasted / max(total, 1)


def run(quick: bool = True):
    apps = colocated_apps()
    rr = sim(apps, "parrot", rate=3.0)
    ka = sim(apps, "kairos", rate=3.0)
    n_rr = len(rr.requests)
    frac_rr = rr.n_preempted / max(n_rr, 1)
    frac_ka = ka.n_preempted / max(len(ka.requests), 1)
    return [
        row("fig09.roundrobin.preempt_frac", frac_rr,
            f"{frac_rr*100:.1f}% preempted (paper: 18.4%)"),
        row("fig09.roundrobin.mem_waste", _waste(rr),
            f"{_waste(rr)*100:.1f}% tokens recomputed (paper: 14.2% mem waste)"),
        row("fig09.kairos.preempt_frac", frac_ka,
            f"{frac_ka*100:.1f}% preempted ({frac_rr/max(frac_ka,1e-9):.1f}x fewer)"),
    ]
