"""Kernel microbenchmark: paged-attention ref backend (what the engine runs
on CPU) + arithmetic-intensity figures for the TPU-target kernel."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row, row
from repro.kernels.ref import paged_attention_ref


def run(quick: bool = True):
    rows: list[Row] = []
    b, kv, g, hd, bs, nb = 8, 8, 4, 128, 16, 64      # 1k context
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, kv, g, hd), jnp.bfloat16)
    kp = jax.random.normal(key, (512, bs, kv, hd), jnp.bfloat16)
    vp = jax.random.normal(key, (512, bs, kv, hd), jnp.bfloat16)
    bt = jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb) % 512
    cl = jnp.full((b,), nb * bs, jnp.int32)

    f = jax.jit(paged_attention_ref)
    f(q, kp, vp, bt, cl).block_until_ready()
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        out = f(q, kp, vp, bt, cl)
    out.block_until_ready()
    t = (time.perf_counter() - t0) / reps
    ctx = nb * bs
    flops = 4 * b * kv * g * ctx * hd
    bytes_moved = 2 * b * ctx * kv * hd * 2          # K+V reads, bf16
    ai = flops / bytes_moved
    rows.append(row("kernel.paged_attn.ref_cpu", t,
                    f"ctx={ctx},ai={ai:.2f}flop/B (memory-bound on TPU: "
                    f"{bytes_moved/819e9*1e6:.1f}us HBM-limited)"))
    return rows
