"""Prefill/decode disaggregation: lossless handoff + decode-tail win.

Two halves, one BENCH JSON (gated by ``check_regression.py`` under
``disagg``):

**A. Handoff losslessness (real engines, CI-gated EXACT).**  A
long-prompt, decode-heavy workload — shared cached prefix, chunked
prefill — drains through a role-typed pair (one prefill instance, one
decode instance, handoffs swept by ``drive_handoffs`` after every
synced step) and must produce token streams bit-identical to the flat
single-engine drain: ``handoff_tokens_mismatch`` and
``handoff_unfinished`` are gated at exactly 0.  The transfer cost is
witnessed, not assumed: each handoff sweep may spend at most ONE
gathered donated ``write_blocks`` dispatch on the decode target
(``handoff_dispatch_excess`` pinned 0) and neither engine's pool buffer
may ever move (``handoff_pool_moves`` pinned 0 — donation survived).

**B. Disaggregated vs colocated decode tail (deterministic sim).**  A
seeded long-prompt + decode-heavy mix replays through the discrete-event
simulator twice at identical capacity — two general instances
(colocated: prompt prefills stall the iterations that also carry decode
steps, the §2.2 head-of-line pathology) vs one prefill + one decode
instance (decode iterations never share a batch with a prefill).
Disaggregation must keep its decode-tail win:
``disagg_vs_colocated_p99_tpot_ratio`` (colocated p99 TPOT / disagg
p99 TPOT) ratio-floor >= 1.0.

Run: ``PYTHONPATH=src python -m benchmarks.disagg [--smoke]``
"""
from __future__ import annotations

import argparse
import math
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Row, row, write_bench_json


# =============================================================================
# part A: role-typed drain on real engines vs the flat baseline
# =============================================================================


def _model_and_params():
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


def _workload(n_reqs: int, max_new: int) -> List:
    """Shared 16-token system prefix + long unique tails: long prompts
    (relative to the reduced model's pool) that cut mid-block under the
    chunked prefill budget, then a decode-heavy phase."""
    from repro.serving import Request
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, 500, 16).astype(np.int32)
    reqs = []
    for i in range(n_reqs):
        toks = np.concatenate(
            [prefix, rng.integers(0, 500, 13 + (i % 7)).astype(np.int32)])
        reqs.append(Request(
            agent_name=f"a{i % 3}", msg_id=f"m{i}", prompt_len=len(toks),
            prompt_tokens=toks, max_new_tokens=max_new,
            arrival_time=float(i)))
    return reqs


def _engine(model, params, iid, *, role="general"):
    from repro.serving import LLMEngine, PagedModelRunner
    r = PagedModelRunner(model, params, num_blocks=64, block_size=8,
                         max_batch=4)
    return LLMEngine(r, instance_id=iid, max_batch=4, role=role,
                     enable_prefix_cache=True, prefill_chunk_tokens=8)


def _flat_drain(model, params, cfg: Dict) -> Dict[str, List[int]]:
    from repro.serving import reset_request_ids
    reset_request_ids()
    eng = _engine(model, params, 0)
    pending = _workload(cfg["n_reqs"], cfg["max_new"])
    done = []
    for _ in range(100_000):
        if pending:
            eng.submit(pending.pop(0))
        done.extend(eng.step())
        if not pending and not eng.sched.has_work:
            break
    return {q.msg_id: list(q.output_tokens) for q in done}


class _Cluster:
    """The surface ``drive_handoffs`` needs: engines, tracer, fencing."""

    class _Dispatcher:
        @staticmethod
        def is_fenced(instance_id, now):
            return False

    def __init__(self, engines):
        from repro.obs.trace import NULL_TRACER
        self.engines = list(engines)
        self.tracer = NULL_TRACER
        self.dispatcher = self._Dispatcher()


def _disagg_drain(model, params, cfg: Dict) -> Dict:
    """Drain the same workload through a prefill+decode pair, sweeping
    handoffs after every synced step and witnessing the transfer cost."""
    from repro.serving import drive_handoffs, reset_request_ids
    reset_request_ids()
    e0 = _engine(model, params, 0, role="prefill")
    e1 = _engine(model, params, 1, role="decode")
    addrs = (e0.runner.pool_address(), e1.runner.pool_address())
    cluster = _Cluster([e0, e1])
    pending = _workload(cfg["n_reqs"], cfg["max_new"])
    done = []
    n_handoffs = n_stranded = dispatch_excess = 0
    handoff_bytes = 0
    for it in range(100_000):
        if pending:
            e0.submit(pending.pop(0))
        for e in cluster.engines:
            done.extend(e.step())
        hs = drive_handoffs(cluster, now=float(it))
        n_handoffs += hs["n_handoffs"]
        n_stranded += hs["n_stranded"]
        handoff_bytes += hs["handoff_bytes"]
        # one decode target: a sweep that moves anything may cost at most
        # one gathered donated write_blocks dispatch
        dispatch_excess += max(
            0, hs["handoff_dispatches"] - (1 if hs["n_handoffs"] else 0))
        if not pending and not any(e.sched.has_work for e in cluster.engines):
            break
    pool_moves = sum(
        1 for e, a in zip(cluster.engines, addrs)
        if a is not None and e.runner.pool_address() != a)
    # per-role load attribution from the role-prefixed snapshot labels
    from benchmarks.latency_breakdown import queue_attribution_by_role
    from repro.obs import merge_snapshots
    from repro.serving import ServingCluster
    roles = queue_attribution_by_role(merge_snapshots(
        {ServingCluster.metrics_label(e): e.metrics_snapshot()
         for e in cluster.engines}))
    toks = {q.msg_id: list(q.output_tokens) for q in done}
    return {"tokens": toks, "n_handoffs": n_handoffs,
            "n_stranded": n_stranded, "handoff_bytes": handoff_bytes,
            "dispatch_excess": dispatch_excess, "pool_moves": pool_moves,
            "n_on_decode": sum(q.instance_id == 1 for q in done),
            "roles": roles}


def measure_handoff(smoke: bool) -> Dict:
    model, params = _model_and_params()
    cfg = {"n_reqs": 6 if smoke else 20, "max_new": 10 if smoke else 16}
    base = _flat_drain(model, params, cfg)
    dis = _disagg_drain(model, params, cfg)
    assert set(base) == set(dis["tokens"]), "drains finished different sets"
    mismatch = sum(base[k] != dis["tokens"][k] for k in base)
    return {
        "handoff_tokens_mismatch": float(mismatch),
        "handoff_unfinished": float(len(base) - len(dis["tokens"])),
        "handoff_dispatch_excess": float(dis["dispatch_excess"]),
        "handoff_pool_moves": float(dis["pool_moves"]),
        "n_handoffs": float(dis["n_handoffs"]),
        "n_stranded": float(dis["n_stranded"]),
        "n_finished_on_decode": float(dis["n_on_decode"]),
        "handoff_mbytes": dis["handoff_bytes"] / 1e6,
        **dis["roles"],
    }


# =============================================================================
# part B: disaggregated vs colocated decode tail (sim)
# =============================================================================


def _disagg_apps():
    """Long-prompt + decode-heavy mix: a Reader whose huge prompts stall
    colocated iterations, feeding a Writer whose long decode runs are
    what the stalls victimize."""
    from repro.sim.workload import AgentProfile, AppSpec
    agents = {
        "Reader": AgentProfile("Reader", math.log(40), 0.35,
                               prompt_mu=math.log(1800), prompt_sigma=0.25),
        "Writer": AgentProfile("Writer", math.log(320), 0.4,
                               prompt_mu=math.log(160), prompt_sigma=0.3),
    }

    def route(agent, rng, hops):
        return ["Writer"] if agent == "Reader" else []

    return [AppSpec("LongDoc", agents, "Reader", route, "sequential")]


def _p99_tpot(res) -> float:
    from repro.obs.slo import request_samples
    tpots = [s.tpot for s in request_samples(res.requests)
             if s.tpot == s.tpot and s.output_len > 1]
    return float(np.percentile(np.asarray(tpots), 99))


def measure_tail(smoke: bool) -> Dict:
    import dataclasses

    from repro.serving import ServingConfig
    from repro.sim.simulator import SimConfig, Simulation

    serving = ServingConfig(num_blocks=768, block_size=16, max_batch=32,
                            policy="kairos", n_instances=2)
    apps = _disagg_apps()
    # operating points picked below decode-pool saturation (0 stranded):
    # the colocated/disagg p99 TPOT ratio measures ~1.35-1.4 at both
    common = dict(rate=1.1 if smoke else 1.0,
                  duration=60.0 if smoke else 150.0, seed=3,
                  # monolithic prefill: a 1400-token prompt stalls the
                  # whole colocated iteration, the pathology the
                  # disaggregated decode instance is immune to
                  prefill_chunk_tokens=None)
    out: Dict[str, float] = {}
    runs = {}
    for name, roles in (("colocated", None),
                        ("disagg", ("prefill", "decode"))):
        cfg = SimConfig.from_serving_config(
            dataclasses.replace(serving, roles=roles), apps, **common)
        res = Simulation(cfg).run()
        runs[name] = res
        out[f"p99_tpot_{name}"] = _p99_tpot(res)
        out[f"p99_token_latency_{name}"] = res.summary()["p99"]
    out["sim_n_handoffs"] = float(runs["disagg"].n_handoffs)
    out["sim_n_stranded"] = float(runs["disagg"].n_stranded)
    out["disagg_vs_colocated_p99_tpot_ratio"] = (
        out["p99_tpot_colocated"] / max(out["p99_tpot_disagg"], 1e-9))
    return out


# =============================================================================
# driver
# =============================================================================


def measure(smoke: bool = True) -> Dict:
    cfg = {"smoke": smoke}
    t0 = time.time()
    metrics = {}
    metrics.update(measure_handoff(smoke))
    metrics.update(measure_tail(smoke))
    metrics["wall_total_s"] = time.time() - t0
    return {"config": cfg, "metrics": metrics}


def run(quick: bool = True) -> List[Row]:
    m = measure(smoke=quick)["metrics"]
    return [
        row("disagg_handoff_mismatch", m["handoff_tokens_mismatch"] * 1e-6,
            f"handoffs={m['n_handoffs']:.0f}"
            f" excess_dispatches={m['handoff_dispatch_excess']:.0f}"),
        row("disagg_p99_tpot", m["p99_tpot_disagg"],
            f"colocated={m['p99_tpot_colocated']*1e3:.1f}ms"
            f" ratio={m['disagg_vs_colocated_p99_tpot_ratio']:.2f}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI smoke job")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    doc = measure(smoke=args.smoke)
    for k in sorted(doc["metrics"]):
        print(f"{k} = {doc['metrics'][k]}")
    m = doc["metrics"]
    bad = (m["handoff_tokens_mismatch"] + m["handoff_unfinished"]
           + m["handoff_dispatch_excess"] + m["handoff_pool_moves"])
    if bad:
        raise SystemExit("FAIL: handoff losslessness/cost witness violated "
                         f"(mismatch={m['handoff_tokens_mismatch']:.0f} "
                         f"unfinished={m['handoff_unfinished']:.0f} "
                         f"excess={m['handoff_dispatch_excess']:.0f} "
                         f"pool_moves={m['handoff_pool_moves']:.0f})")
    if args.json:
        write_bench_json(args.json, "disagg", doc["config"], doc["metrics"])
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
