"""Per-stage latency decomposition from traces + tracing-overhead gate.

Two measurements, both consuming the ``repro.obs`` event streams:

* **real path** — drains a deterministic short+long workload through a
  2-instance paged-JAX cluster twice, tracing disabled
  (``NULL_TRACER``) vs enabled (``Tracer``).  Token streams are asserted
  identical (emission must not change a single scheduling or sampling
  decision), and the wall-clock-per-token delta is reported as
  ``tracing_overhead_pct`` — the CI gate holds it <= 5 %.  ``--trace
  PATH`` additionally exports the traced drain as Chrome/Perfetto JSON.
* **sim path** — runs the colocated-apps workload under ``parrot``
  (FCFS, the Fig. 15 baseline) and ``kairos`` with ``tracing=True``,
  stitches agent-stage spans from the identical event schema, and
  reports the queue/prefill/decode decomposition (mean + p99 seconds
  per stage) plus SLO attainment and ``goodput_slo``
  (workflows meeting their deadline with every member request in SLO).
  This is where the paper's claim becomes visible in one table: Kairos
  moves latency out of the *queue* component, decode is invariant.

``queue_attribution_by_role`` additionally regroups a cluster
``metrics_snapshot()`` by instance role (the ``prefill<i>.`` /
``decode<i>.`` / ``engine<i>.`` prefixes): on a disaggregated cluster
it attributes queueing and load to the causing role — admissions and
preemptions land on the prefill pool, finishes on the decode pool —
and ``benchmarks/disagg.py`` ships the per-role totals in its BENCH
JSON.

Emits BENCH JSON (``--json``) under tag ``latency_breakdown``;
``--smoke`` shrinks both paths for the CI smoke job.

Run: ``PYTHONPATH=src python -m benchmarks.latency_breakdown [--smoke]``
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Row, row, write_bench_json

CHUNK = 32
N_INSTANCES = 2
SIM_POLICIES = ("parrot", "kairos")
# calibrated to the reduced-model sim operating point: tight enough that
# the FCFS baseline misses a visible fraction, slack enough that Kairos
# attains most (keeps goodput_slo a meaningful, gateable signal)
SIM_SLO = dict(ttft_s=8.0, tpot_s=1.0, workflow_deadline_s=45.0)


# =============================================================================
# real path: traced vs untraced drain
# =============================================================================


def _workload(cfg: Dict) -> List:
    from repro.serving import Request
    rng = np.random.default_rng(cfg["seed"])
    reqs = []
    for i in range(cfg["n_short"]):
        plen = int(rng.integers(16, 40))
        reqs.append(Request(
            agent_name="qa", msg_id=f"s{i}", prompt_len=plen,
            prompt_tokens=rng.integers(0, 500, plen).astype(np.int32),
            max_new_tokens=cfg["short_out"]))
    for i in range(cfg["n_long"]):
        plen = cfg["long_prompt"]
        reqs.append(Request(
            agent_name="ingest", msg_id=f"l{i}", prompt_len=plen,
            prompt_tokens=rng.integers(0, 500, plen).astype(np.int32),
            max_new_tokens=cfg["long_out"]))
    return reqs


def _drive(runner0, cfg: Dict, tracer) -> Dict:
    """One full drain with the given tracer; returns raw counters + events."""
    from repro.core import Orchestrator
    from repro.core.orchestrator import HardwareProfile
    from repro.serving import LLMEngine, ServingCluster, reset_request_ids
    reset_request_ids()
    engines = [
        LLMEngine(runner0.clone(), instance_id=i, max_batch=cfg["max_batch"],
                  prefill_chunk_tokens=CHUNK, tracer=tracer)
        for i in range(N_INSTANCES)]
    orch = Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0,
        kv_capacity_tokens=cfg["num_blocks"] * cfg["block_size"]))
    cluster = ServingCluster(engines, orch, tracer=tracer)
    pending = _workload(cfg)
    t0 = time.perf_counter()
    done: List = []
    for _ in range(100_000):
        for _k in range(min(2 * N_INSTANCES, len(pending))):
            r = pending.pop(0)
            r.arrival_time = time.monotonic()
            cluster.submit(r)
        done.extend(cluster.step())
        if not pending and not cluster.has_work:
            break
    wall = time.perf_counter() - t0
    snapshot = cluster.metrics_snapshot()
    cluster.close()
    tokens = sum(r.output_len for r in done)
    assert len(pending) == 0 and tokens > 0
    return {"wall_s": wall, "tokens": tokens, "snapshot": snapshot,
            "events": list(tracer.events()) if tracer.enabled else [],
            "dropped": tracer.dropped() if tracer.enabled else 0,
            "outputs": sorted((r.msg_id, tuple(r.output_tokens))
                              for r in done)}


def measure_overhead(smoke: bool, trace_path: str = None) -> Dict:
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.obs import NULL_TRACER, Tracer, write_chrome_trace
    from repro.serving import PagedModelRunner

    cfg = dict(seed=0, n_short=16, n_long=4, short_out=10, long_out=4,
               long_prompt=96, max_batch=4, num_blocks=96, block_size=8)
    if not smoke:
        cfg.update(n_short=20, n_long=6, short_out=16, long_out=6,
                   long_prompt=192, num_blocks=192)

    mcfg = get_config("qwen3-1.7b").reduced()
    model = build_model(mcfg)
    params = model.init_params(jax.random.PRNGKey(0))
    runner0 = PagedModelRunner(model, params, num_blocks=cfg["num_blocks"],
                               block_size=cfg["block_size"],
                               max_batch=cfg["max_batch"])

    # two warmup drains: the first compiles, the second flushes residual
    # shape-specialisations (first timed drain was otherwise 10-30x slow)
    _drive(runner0, cfg, NULL_TRACER)
    _drive(runner0, cfg, Tracer())
    repeats = 10 if smoke else 12
    runs = {True: [], False: []}
    for rep in range(repeats):
        # alternate within-pair order so slow drift on a shared host
        # penalizes neither side systematically
        order = (False, True) if rep % 2 == 0 else (True, False)
        for traced in order:
            tr = Tracer() if traced else NULL_TRACER
            runs[traced].append(_drive(runner0, cfg, tr))
    best = {t: min(rs, key=lambda x: x["wall_s"]) for t, rs in runs.items()}
    assert best[True]["outputs"] == best[False]["outputs"], \
        "tracing must be token-identical to the untraced drain"
    out = {"config": {**cfg, "chunk": CHUNK, "instances": N_INSTANCES,
                      "smoke": smoke, "model": "qwen3-1.7b/reduced"}}
    for traced, key in ((True, "traced"), (False, "untraced")):
        r = best[traced]
        out[f"wall_per_token_{key}_ms"] = 1e3 * r["wall_s"] / r["tokens"]
    # overhead from the median of PAIRED ratios (each repeat runs the
    # untraced drain back-to-back with the traced one, so a slow phase
    # of a noisy shared host hits both sides of its pair): a min-of-N or
    # unpaired-median ratio swings +-6% on a loaded 2-cpu host, the
    # paired median stays well inside the 5% CI ceiling
    ratios = [t["wall_s"] / u["wall_s"]
              for u, t in zip(runs[False], runs[True])]
    out["tracing_overhead_pct"] = 100.0 * (float(np.median(ratios)) - 1)
    out["trace_events"] = float(len(best[True]["events"]))
    # per-role load attribution (a flat cluster rolls up as "general")
    out.update(queue_attribution_by_role(best[True]["snapshot"]))
    if trace_path:
        write_chrome_trace(trace_path, best[True]["events"],
                           dropped=best[True]["dropped"])
    return out


def queue_attribution_by_role(snapshot: Dict) -> Dict[str, float]:
    """Attribute a cluster snapshot's queueing/load to the causing role.

    Consumes the per-role instance prefixes ``ServingCluster.
    metrics_label`` writes (``prefill0.``, ``decode1.``; flat clusters'
    ``engine<i>.`` rolls up as ``general``) and returns flat
    ``<role>_<metric>`` totals: on a disaggregated cluster, admissions /
    preemptions / waiting depth sit on the prefill pool (recompute and
    queueing are prefill-caused) while finishes sit on the decode pool —
    so a queue backlog is attributable to the pool that owns it."""
    from repro.obs import rollup_by_role
    out: Dict[str, float] = {}
    for role, m in sorted(rollup_by_role(snapshot).items()):
        for metric in ("n_admitted", "n_finished", "n_preempted",
                       "queue_depth", "running"):
            out[f"{role}_{metric}"] = m.get(metric, 0.0)
    return out


# =============================================================================
# sim path: FCFS vs Kairos stage decomposition + goodput under SLO
# =============================================================================


def measure_stages(smoke: bool) -> Dict:
    from repro.obs import (SLO, request_samples, slo_report,
                           spans_from_events, stage_breakdown)
    from repro.sim.simulator import SimConfig, Simulation
    from repro.sim.workload import colocated_apps

    duration, rate = (25.0, 2.0) if smoke else (120.0, 2.8)
    slo = SLO(**SIM_SLO)
    out: Dict = {}
    for pol in SIM_POLICIES:
        cfg = SimConfig(apps=colocated_apps(), policy=pol, rate=rate,
                        duration=duration, seed=1, n_instances=2,
                        tracing=True)
        s = Simulation(cfg)
        res = s.run()
        spans = spans_from_events(s.tracer.events())
        bd = stage_breakdown(spans)
        tag = "fcfs" if pol == "parrot" else pol
        for cat in ("queue", "prefill", "decode"):
            out[f"{tag}_{cat}_mean_s"] = bd[cat]["mean"]
            out[f"{tag}_{cat}_p99_s"] = bd[cat]["p99"]
        rep = slo_report(request_samples(res.requests), slo,
                         duration_s=duration)
        out[f"{tag}_goodput_slo"] = rep["goodput_slo"]
        out[f"{tag}_request_attainment"] = rep["request_attainment"]
        out[f"{tag}_n_workflows"] = rep["n_workflows"]
        # preemption wastage rides along: recompute cost already shows up
        # as inflated prefill in the spans; the count makes it attributable
        out[f"{tag}_n_preempted"] = float(res.n_preempted)
    out["goodput_slo"] = out["kairos_goodput_slo"]       # headline (gated)
    out["queue_mean_gain_pct"] = 100.0 * (
        1 - out["kairos_queue_mean_s"] / max(out["fcfs_queue_mean_s"], 1e-9))
    return out


# =============================================================================
# drivers
# =============================================================================


def measure(smoke: bool = True, trace_path: str = None) -> Dict:
    ov = measure_overhead(smoke, trace_path)
    config = ov.pop("config")
    config.update(sim_policies=list(SIM_POLICIES), slo=SIM_SLO)
    st = measure_stages(smoke)
    return {"config": config, **ov, **st}


def run(quick: bool = True) -> List[Row]:
    m = measure(smoke=quick)
    return [
        row("latency_breakdown.traced_drain",
            m["wall_per_token_traced_ms"] * 1e-3,
            f"+{m['tracing_overhead_pct']:.1f}% vs untraced (gate <= 5%)"),
        row("latency_breakdown.queue_stage",
            m["kairos_queue_mean_s"],
            f"kairos queue mean; -{m['queue_mean_gain_pct']:.0f}% vs FCFS"),
        row("latency_breakdown.goodput",
            m["kairos_goodput_slo"],
            f"goodput_slo kairos={m['kairos_goodput_slo']:.2f} "
            f"fcfs={m['fcfs_goodput_slo']:.2f}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI smoke job")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH JSON (schema: benchmarks/common.py)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the traced drain as Chrome/Perfetto JSON")
    args = ap.parse_args()

    m = measure(smoke=args.smoke, trace_path=args.trace)
    config = m.pop("config")
    print("name,value")
    for k, v in sorted(m.items()):
        print(f"{k},{v:.4f}")
    if args.trace:
        print(f"# wrote {args.trace}")
    if args.json:
        write_bench_json(args.json, "latency_breakdown", config, m)
        print(f"# wrote {args.json}")
    if m["tracing_overhead_pct"] > 5.0:
        # reported, not asserted: check_regression.py owns the gate
        print(f"# WARNING: tracing overhead {m['tracing_overhead_pct']:.1f}% "
              "above 5% target")


if __name__ == "__main__":
    main()
