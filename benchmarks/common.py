"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.sim import SimConfig, Simulation, colocated_apps, make_app, run_policy

Row = Tuple[str, float, str]   # (name, us_per_call, derived)

# calibrated operating points (see EXPERIMENTS.md §Setup)
RATE_SINGLE = {"QA": 7.0, "RG": 3.2, "CG": 1.9}
RATE_COLOC = 2.8
DUR = 150.0
SEED = 1


def sim(apps, policy: str, rate: float, duration: float = DUR, seed: int = SEED,
        **kw):
    t0 = time.time()
    res = run_policy(apps, policy, rate=rate, duration=duration, seed=seed, **kw)
    res.wall_s = time.time() - t0
    return res


def pct_gain(base: float, ours: float) -> float:
    return 100.0 * (base - ours) / base


def row(name: str, seconds_per_call: float, derived: str) -> Row:
    return (name, seconds_per_call * 1e6, derived)
