"""Shared helpers for the per-figure benchmarks, including the
machine-readable BENCH JSON schema the CI perf pipeline consumes:

    {"bench": <suite name>,
     "config": {<knobs the run used>},
     "metrics": {<flat name -> number | {...}>},
     "commit": <git HEAD or "unknown">}

``BENCH_baseline.json`` (committed) is the reference trajectory;
``BENCH_ci.json`` (uploaded as a CI artifact on every PR) is checked
against it by ``benchmarks/check_regression.py``.
"""
from __future__ import annotations

import json
import os
import platform
import subprocess
import time
from typing import Dict, Tuple

from repro.sim import run_policy

Row = Tuple[str, float, str]   # (name, us_per_call, derived)

# calibrated operating points (see EXPERIMENTS.md §Setup)
RATE_SINGLE = {"QA": 7.0, "RG": 3.2, "CG": 1.9}
RATE_COLOC = 2.8
DUR = 150.0
SEED = 1


def sim(apps, policy: str, rate: float, duration: float = DUR, seed: int = SEED,
        **kw):
    t0 = time.time()
    res = run_policy(apps, policy, rate=rate, duration=duration, seed=seed, **kw)
    res.wall_s = time.time() - t0
    return res


def pct_gain(base: float, ours: float) -> float:
    return 100.0 * (base - ours) / base


def row(name: str, seconds_per_call: float, derived: str) -> Row:
    return (name, seconds_per_call * 1e6, derived)


# =============================================================================
# BENCH JSON (perf-tracking CI)
# =============================================================================


def git_commit() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"], text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            stderr=subprocess.DEVNULL).strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def bench_host() -> str:
    """Coarse hardware-class tag: wall-clock numbers are only comparable
    between runs that share it (the regression gate downgrades wall
    comparisons across different hosts to advisory)."""
    return f"{platform.system()}-{platform.machine()}-{os.cpu_count()}cpu"


def bench_json(bench: str, config: Dict, metrics: Dict) -> Dict:
    return {"bench": bench, "config": config, "metrics": metrics,
            "commit": git_commit(), "host": bench_host()}


def write_bench_json(path: str, bench: str, config: Dict, metrics: Dict) -> Dict:
    doc = bench_json(bench, config, metrics)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc
