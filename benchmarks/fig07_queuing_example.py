"""Fig. 7: queuing time under FCFS / Topology-aware / Oracle on the
illustrative single-server example (HumanitiesAgent=5u, Router=1u,
MathAgent=2u, Router=1u, all arriving at t=0).

Oracle (true-remaining SJF) must be strictly best; Topo in between.
"""
from __future__ import annotations

from benchmarks.common import row

# (name, exec_units, topo_remaining_stages)
REQS = [("H", 5.0, 1), ("R1", 1.0, 2), ("M", 2.0, 1), ("R2", 1.0, 2)]


def total_wait(order) -> float:
    t, wait = 0.0, 0.0
    for name, ex, _ in order:
        wait += t
        t += ex
    return wait


def run(quick: bool = True):
    fcfs = total_wait(REQS)
    topo = total_wait(sorted(REQS, key=lambda r: r[2]))
    oracle = total_wait(sorted(REQS, key=lambda r: r[1]))
    assert oracle <= fcfs and oracle <= topo
    return [
        row("fig07.fcfs_total_wait", fcfs, f"{fcfs:.0f} units (paper diagram: 13)"),
        row("fig07.topo_total_wait", topo,
            f"{topo:.0f} units — on a single server, depth even loses to "
            f"FCFS here: stage count is a poor latency proxy (paper: 12)"),
        row("fig07.oracle_total_wait", oracle,
            f"{oracle:.0f} units = SJF on true remaining time (paper: 7)"),
    ]
