"""Pipelined multi-instance cluster runtime vs the legacy serial loop.

Runs the real paged JAX engine cluster (CPU ref backend, reduced config)
on a short+long prompt mix at 1/2/4 instances, twice per point:

* **serial** — ``ServingCluster(pipelined=False)``: step one engine at a
  time, blocking on its device->host transfer before touching the next —
  exactly the hand-rolled driver loop ``Workflow.run`` used to run;
* **pipelined** — breadth-first: every engine's fused iteration is
  dispatched before the first collect, one worker thread per engine, so
  planning/flattening of engine *i+1* overlaps device compute of engine
  *i* and the engines' computations themselves run concurrently (XLA CPU
  executes on the calling thread, GIL released); collects run on the
  control-plane thread against already-host-resident token buffers.

Measured per instance count: wall-clock per generated token for both
modes and their ratio (``overlap_speedup_N``, target >= 1.15 at 4
instances).  Token streams are asserted identical between modes.

Emits the machine-readable BENCH JSON the CI perf pipeline consumes
(``--json PATH``); ``--smoke`` shrinks the workload for the CI smoke job.

Run: ``PYTHONPATH=src python -m benchmarks.cluster_overlap [--smoke]``
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Row, row, write_bench_json

CHUNK = 32          # per-iteration prefill token budget
INSTANCES = (1, 2, 4)


def _workload(cfg: Dict) -> List:
    """Deterministic short+long request mix (len scales with instances)."""
    from repro.serving import Request
    rng = np.random.default_rng(cfg["seed"])
    reqs = []
    for i in range(cfg["n_short"]):
        plen = int(rng.integers(16, 40))
        reqs.append(Request(
            agent_name="qa", msg_id=f"s{i}", prompt_len=plen,
            prompt_tokens=rng.integers(0, 500, plen).astype(np.int32),
            max_new_tokens=cfg["short_out"]))
    for i in range(cfg["n_long"]):
        plen = cfg["long_prompt"]
        reqs.append(Request(
            agent_name="ingest", msg_id=f"l{i}", prompt_len=plen,
            prompt_tokens=rng.integers(0, 500, plen).astype(np.int32),
            max_new_tokens=cfg["long_out"]))
    return reqs


def _build_cluster(runner0, cfg: Dict, n_instances: int, pipelined: bool):
    from repro.core import Orchestrator
    from repro.core.orchestrator import HardwareProfile
    from repro.serving import LLMEngine, ServingCluster
    engines = [
        LLMEngine(runner0.clone(), instance_id=i, max_batch=cfg["max_batch"],
                  prefill_chunk_tokens=CHUNK)
        for i in range(n_instances)]
    orch = Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0,
        kv_capacity_tokens=cfg["num_blocks"] * cfg["block_size"]))
    return ServingCluster(engines, orch, pipelined=pipelined)


def _drive(runner0, cfg: Dict, n_instances: int, pipelined: bool) -> Dict:
    """One full drain of the workload; returns raw counters."""
    from repro.serving import reset_request_ids
    reset_request_ids()
    cluster = _build_cluster(runner0, cfg, n_instances, pipelined)
    pending = _workload(cfg)
    t0 = time.perf_counter()
    done: List = []
    for _ in range(100_000):
        # trickle arrivals (a couple per step) so every instance keeps a
        # mixed chunk+decode iteration in flight
        for _k in range(min(2 * n_instances, len(pending))):
            r = pending.pop(0)
            r.arrival_time = time.monotonic()
            cluster.submit(r)
        done.extend(cluster.step())
        if not pending and not cluster.has_work:
            break
    wall = time.perf_counter() - t0
    cluster.close()
    tokens = sum(r.output_len for r in done)
    assert len(pending) == 0 and tokens > 0
    return {"wall_s": wall, "tokens": tokens,
            "outputs": sorted((r.msg_id, tuple(r.output_tokens))
                              for r in done)}


def measure(smoke: bool = True) -> Dict:
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import PagedModelRunner

    cfg = dict(seed=0, n_short=12, n_long=4, short_out=8, long_out=3,
               long_prompt=96, max_batch=4, num_blocks=96, block_size=8)
    if not smoke:
        cfg.update(n_short=24, n_long=8, short_out=16, long_out=6,
                   long_prompt=192, num_blocks=192)

    mcfg = get_config("qwen3-1.7b").reduced()
    model = build_model(mcfg)
    params = model.init_params(jax.random.PRNGKey(0))
    runner0 = PagedModelRunner(model, params, num_blocks=cfg["num_blocks"],
                               block_size=cfg["block_size"],
                               max_batch=cfg["max_batch"])

    out: Dict = {"config": {**cfg, "chunk": CHUNK, "smoke": smoke,
                            "instances": list(INSTANCES),
                            "model": "qwen3-1.7b/reduced"}}
    repeats = 4 if smoke else 6
    _drive(runner0, cfg, max(INSTANCES), True)          # warmup: compile
    for n in INSTANCES:
        runs = {True: [], False: []}
        for _ in range(repeats):
            for pipelined in (True, False):
                runs[pipelined].append(_drive(runner0, cfg, n, pipelined))
        res = {}
        for pipelined, key in ((True, "pipelined"), (False, "serial")):
            r = min(runs[pipelined], key=lambda x: x["wall_s"])
            res[key] = r
            out[f"wall_per_token_{key}_ms_{n}"] = 1e3 * r["wall_s"] / r["tokens"]
        assert res["pipelined"]["outputs"] == res["serial"]["outputs"], \
            f"pipelined cluster must be token-identical to serial (n={n})"
        out[f"overlap_speedup_{n}"] = (out[f"wall_per_token_serial_ms_{n}"]
                                       / out[f"wall_per_token_pipelined_ms_{n}"])
    return out


def run(quick: bool = True) -> List[Row]:
    m = measure(smoke=quick)
    rows = []
    for n in INSTANCES:
        rows.append(row(f"cluster_overlap.pipelined_{n}x",
                        m[f"wall_per_token_pipelined_ms_{n}"] * 1e-3,
                        f"x{m[f'overlap_speedup_{n}']:.2f} vs serial loop"))
    rows.append(row("cluster_overlap.headline",
                    m[f"wall_per_token_pipelined_ms_{max(INSTANCES)}"] * 1e-3,
                    f"{max(INSTANCES)} instances "
                    f"x{m[f'overlap_speedup_{max(INSTANCES)}']:.2f} "
                    "vs serial (target >= 1.15)"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI smoke job")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH JSON (schema: benchmarks/common.py)")
    args = ap.parse_args()

    m = measure(smoke=args.smoke)
    config = m.pop("config")
    print("name,value")
    for k, v in sorted(m.items()):
        print(f"{k},{v:.4f}")
    if args.json:
        write_bench_json(args.json, "cluster_overlap", config, m)
        print(f"# wrote {args.json}")
    top = m[f"overlap_speedup_{max(INSTANCES)}"]
    if top < 1.15:
        # reported, not asserted: the CI gate (check_regression.py) owns
        # the floor so one noisy drain can't hard-fail a run
        print(f"# WARNING: overlap speedup below target (x{top:.2f} < 1.15)")


if __name__ == "__main__":
    main()
