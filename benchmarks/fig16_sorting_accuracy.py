"""Fig. 16: request-pair sorting accuracy of the scheduling order vs the
true remaining execution latency (paper: Kairos 83.5% avg, Ayo 75.9%,
Parrot/FCFS 50%)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import RATE_COLOC, RATE_SINGLE, Row, row
from repro.sim import SimConfig, Simulation, colocated_apps, make_app

SCENARIOS_FULL = ([("QA", g) for g in ("G+M", "M+W", "S+S")]
                  + [("RG", g) for g in ("TQ", "NCD", "NQ")]
                  + [("CG", g) for g in ("HE", "MBPP", "APPS")]
                  + [("COLOC", None)])


def _true_remaining(res):
    """actual remaining workflow latency at each request's stage arrival."""
    done = {w.msg_id: w.done_time for w in res.workflows}
    out = []
    for r in res.requests:
        if r.msg_id in done and done[r.msg_id] >= r.arrival_time:
            out.append((r, done[r.msg_id] - r.arrival_time))
    return out


def _pair_accuracy(keys, truth, max_n: int = 600) -> float:
    n = min(len(keys), max_n)
    keys, truth = np.asarray(keys[:n]), np.asarray(truth[:n])
    ii, jj = np.triu_indices(n, k=1)
    kd = keys[ii] - keys[jj]
    td = truth[ii] - truth[jj]
    valid = (kd != 0) & (td != 0)
    agree = (np.sign(kd) == np.sign(td)) & valid
    ties = ~valid
    # ties count half (random order between equals)
    return float((agree.sum() + 0.5 * ties.sum()) / len(ii))


def _scenario(apps, rate):
    cfg = SimConfig(apps=apps, policy="kairos", rate=rate, duration=120.0, seed=2)
    s = Simulation(cfg)
    res = s.run()
    pairs = _true_remaining(res)
    truth = [t for _, t in pairs]
    acc = {}
    acc["kairos"] = _pair_accuracy(
        [s.orch.priority_score(r.app_name, r.agent_name) for r, _ in pairs], truth)
    acc["ayo"] = _pair_accuracy(
        [s.orch.remaining_stages(r.app_name, r.agent_name) for r, _ in pairs], truth)
    acc["parrot"] = 0.5   # FCFS: either of a pair may arrive first
    return acc


def run(quick: bool = True):
    scen = [("QA", "G+M"), ("COLOC", None)] if quick else SCENARIOS_FULL
    rows: list[Row] = []
    allacc = {"kairos": [], "ayo": [], "parrot": []}
    for app, g in scen:
        if app == "COLOC":
            acc = _scenario(colocated_apps(), RATE_COLOC)
            name = "coloc"
        else:
            acc = _scenario([make_app(app, g)], RATE_SINGLE[app])
            name = f"{app}[{g}]"
        for p, a in acc.items():
            allacc[p].append(a)
        rows.append(row(f"fig16.{name}", acc["kairos"],
                        f"kairos={acc['kairos']*100:.1f}% ayo={acc['ayo']*100:.1f}% "
                        f"fcfs=50.0%"))
    for p in ("kairos", "ayo", "parrot"):
        rows.append(row(f"fig16.mean.{p}", float(np.mean(allacc[p])),
                        f"{np.mean(allacc[p])*100:.1f}% "
                        f"(paper: kairos 83.5, ayo 75.9, fcfs 50)"))
    return rows
