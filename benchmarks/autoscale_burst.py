"""Elastic autoscaling under a bursty trace + live-migration exactness.

Two halves, one BENCH JSON (gated by ``check_regression.py`` under
``autoscale_burst``):

**A. Migration losslessness (real engines, CI-gated EXACT).**  A mixed
workload — shared cached prefixes, chunked prefill, decode — drains
through two real paged engines while every few iterations ALL running
requests are forcibly live-migrated to the other engine (ping-pong, so
each request migrates several times, mid-prefill and mid-decode, warm
and cold target caches).  The drained token streams must be
bit-identical to an unmigrated single-engine run:
``migration_tokens_mismatch`` is gated at exactly 0.

**B. Elastic vs fixed capacity (deterministic sim).**  A seeded bursty
trace (``repro.workloads.traces.bursty_trace``: low baseline + one
guaranteed heavy burst window) replays through the discrete-event
simulator three ways — fixed at the trough size, fixed at the burst
size, and elastic (autoscaler grows/shrinks between the two, retiring
instances through migration).  Elastic must beat trough-sized fixed
capacity on p99 workflow token latency (``elastic_vs_fixed_p99_ratio``
ratio-floor >= 1.0) and hold its goodput under SLO
(``goodput_slo_elastic`` baseline floor), while paying far fewer
instance-seconds than burst-sized fixed capacity.

Run: ``PYTHONPATH=src python -m benchmarks.autoscale_burst [--smoke]``
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Row, row, write_bench_json

MIGRATE_EVERY = 3       # engine iterations between forced ping-pong moves
# SLO constants calibrated to the smoke trace's latency scale (request
# e2e p50 ~8-14 s under load): trough-sized fixed capacity misses the
# deadlines for most burst-window workflows, elastic holds most of them
SLO_E2E_S = 30.0        # per-request arrival->finish deadline (sim, part B)
SLO_WF_S = 60.0         # workflow deadline (sim, part B)


# =============================================================================
# part A: forced-migration drain on real engines
# =============================================================================


def _model_and_params():
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


def _workload(n_reqs: int, max_new: int) -> List:
    """Shared-prefix requests with varying unique tails: exercises the
    prefix cache (warm/cold restores), chunked prefill (mid-prefill
    migrations), and COW-shared blocks."""
    from repro.serving import Request
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, 500, 16).astype(np.int32)
    reqs = []
    for i in range(n_reqs):
        toks = np.concatenate(
            [prefix, rng.integers(0, 500, 5 + (i % 9)).astype(np.int32)])
        reqs.append(Request(
            agent_name=f"a{i % 3}", msg_id=f"m{i}", prompt_len=len(toks),
            prompt_tokens=toks, max_new_tokens=max_new,
            arrival_time=float(i)))
    return reqs


def _baseline_drain(model, params, cfg: Dict) -> Dict[str, List[int]]:
    from repro.serving import LLMEngine, PagedModelRunner, reset_request_ids
    reset_request_ids()
    r = PagedModelRunner(model, params, num_blocks=cfg["num_blocks"],
                         block_size=8, max_batch=cfg["max_batch"])
    eng = LLMEngine(r, instance_id=0, max_batch=cfg["max_batch"],
                    enable_prefix_cache=True, prefill_chunk_tokens=8)
    pending = _workload(cfg["n_reqs"], cfg["max_new"])
    done = []
    for _ in range(100_000):
        if pending:
            eng.submit(pending.pop(0))
        done.extend(eng.step())
        if not pending and not eng.sched.has_work:
            break
    return {q.msg_id: list(q.output_tokens) for q in done}


def _migrated_drain(model, params, cfg: Dict) -> Dict:
    """Drain the same workload through TWO engines, forcibly ping-pong
    live-migrating every running request every MIGRATE_EVERY iterations."""
    from repro.serving import (LLMEngine, PagedModelRunner,
                               migrate, reset_request_ids)
    reset_request_ids()
    r0 = PagedModelRunner(model, params, num_blocks=cfg["num_blocks"],
                          block_size=8, max_batch=cfg["max_batch"])
    engines = [
        LLMEngine(r0, instance_id=0, max_batch=cfg["max_batch"],
                  enable_prefix_cache=True, prefill_chunk_tokens=8),
        LLMEngine(r0.clone(), instance_id=1, max_batch=cfg["max_batch"],
                  enable_prefix_cache=True, prefill_chunk_tokens=8)]
    pending = _workload(cfg["n_reqs"], cfg["max_new"])
    done, it = [], 0
    n_migrations = n_mid_prefill = 0
    migrated_bytes = 0
    for _ in range(100_000):
        if pending:
            engines[it % 2].submit(pending.pop(0))
        for e in engines:
            done.extend(e.step())
        it += 1
        if it % MIGRATE_EVERY == 0:
            # engines are synced after step(): migration is legal now.
            # Move every running request off the busier engine.
            src = max(engines, key=lambda e: len(e.sched.running))
            dst = engines[1 - engines.index(src)]
            for q in list(src.sched.running):
                if not dst.sched.can_adopt(q):
                    continue
                if q.prefilled_len < q.prompt_len:
                    n_mid_prefill += 1
                snap = migrate(src, dst, q)
                n_migrations += 1
                migrated_bytes += snap.n_bytes
        if not pending and not any(e.sched.has_work for e in engines):
            break
    toks = {q.msg_id: list(q.output_tokens) for q in done}
    return {"tokens": toks, "n_migrations": n_migrations,
            "n_mid_prefill": n_mid_prefill, "migrated_bytes": migrated_bytes}


def measure_migration(smoke: bool) -> Dict:
    model, params = _model_and_params()
    cfg = {"n_reqs": 8 if smoke else 24, "max_new": 10 if smoke else 16,
           "num_blocks": 64, "max_batch": 4}
    base = _baseline_drain(model, params, cfg)
    mig = _migrated_drain(model, params, cfg)
    assert set(base) == set(mig["tokens"]), "drains finished different sets"
    mismatch = sum(base[k] != mig["tokens"][k] for k in base)
    return {
        "migration_tokens_mismatch": float(mismatch),
        "migration_unfinished": float(len(base) - len(mig["tokens"])),
        "n_forced_migrations": float(mig["n_migrations"]),
        "n_mid_prefill_migrations": float(mig["n_mid_prefill"]),
        "migrated_mbytes": mig["migrated_bytes"] / 1e6,
    }


# =============================================================================
# part B: elastic vs fixed on the seeded bursty trace (sim)
# =============================================================================


def _sim(trace, serving, n_instances: int, autoscale=None):
    from repro.sim.simulator import Simulation
    cfg = trace.sim_config(serving, n_instances=n_instances,
                           autoscale=autoscale)
    return Simulation(cfg).run()


def measure_burst(smoke: bool) -> Dict:
    from repro.obs.slo import SLO, request_samples, slo_report
    from repro.serving import AutoscalerConfig, ServingConfig
    from repro.workloads.traces import bursty_trace

    trace = bursty_trace(seed=1, duration=30.0 if smoke else 90.0,
                         base_rate=2.0 if smoke else 3.0, burst_mult=6.0)
    serving = ServingConfig(num_blocks=768, block_size=16, max_batch=32,
                            policy="kairos")
    lo, hi = 2, 6
    elastic_cfg = AutoscalerConfig(
        min_instances=lo, max_instances=hi, queue_high=3.0, queue_low=0.5,
        kv_high=0.85, kv_low=0.5, up_patience=2, down_patience=8,
        decision_period_s=0.25, cooldown_s=1.0)
    slo = SLO(e2e_s=SLO_E2E_S, workflow_deadline_s=SLO_WF_S)

    out: Dict[str, float] = {"trace_n_workflows": float(trace.n_workflows),
                             "trace_peak_rate": float(
                                 trace.rate_profile(2.0).max())}
    runs = {}
    for name, n, auto in (("fixed_lo", lo, None), ("fixed_hi", hi, None),
                          ("elastic", lo, elastic_cfg)):
        res = _sim(trace, serving, n, auto)
        rep = slo_report(request_samples(res.requests), slo,
                         duration_s=trace.config.duration)
        s = res.summary()
        runs[name] = s
        out[f"p99_token_latency_{name}"] = s["p99"]
        out[f"goodput_slo_{name}"] = rep["goodput_slo"]
        out[f"instance_seconds_{name}"] = s["instance_seconds"]
        out[f"n_migrated_{name}"] = s["n_migrated"]
    out["elastic_vs_fixed_p99_ratio"] = (
        runs["fixed_lo"]["p99"] / max(runs["elastic"]["p99"], 1e-9))
    out["elastic_capacity_saving_vs_hi"] = (
        1.0 - out["instance_seconds_elastic"]
        / max(out["instance_seconds_fixed_hi"], 1e-9))
    return out


# =============================================================================
# driver
# =============================================================================


def measure(smoke: bool = True) -> Dict:
    cfg = {"smoke": smoke, "migrate_every": MIGRATE_EVERY,
           "slo_e2e_s": SLO_E2E_S, "slo_wf_s": SLO_WF_S}
    t0 = time.time()
    metrics = {}
    metrics.update(measure_migration(smoke))
    metrics.update(measure_burst(smoke))
    metrics["wall_total_s"] = time.time() - t0
    return {"config": cfg, "metrics": metrics}


def run(quick: bool = True) -> List[Row]:
    m = measure(smoke=quick)["metrics"]
    return [
        row("autoscale_migration_mismatch",
            m["migration_tokens_mismatch"] * 1e-6,
            f"forced={m['n_forced_migrations']:.0f}"
            f" mid_prefill={m['n_mid_prefill_migrations']:.0f}"),
        row("autoscale_p99_elastic", m["p99_token_latency_elastic"],
            f"vs fixed {m['p99_token_latency_fixed_lo']*1e3:.1f}ms"),
        row("autoscale_goodput_elastic", m["goodput_slo_elastic"] * 1e-6,
            f"fixed_lo={m['goodput_slo_fixed_lo']:.3f}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI smoke job")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    doc = measure(smoke=args.smoke)
    for k in sorted(doc["metrics"]):
        print(f"{k} = {doc['metrics'][k]}")
    bad = doc["metrics"]["migration_tokens_mismatch"]
    if bad:
        raise SystemExit(f"FAIL: {bad:.0f} migrated token streams diverged")
    if args.json:
        write_bench_json(args.json, "autoscale_burst", doc["config"],
                         doc["metrics"])
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
