"""Tensor-parallel engine scaling: sharded KV pool + shard_map iteration.

Runs the real paged engine's fused iteration at tp in {1, 2, 4} on a
("data", "model") host-level mesh (CPU CI forces the devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; module import
sets the flag when jax is not yet initialized).  One drain per degree of
the same shared-prefix + chunked-prefill + decode mix, plus a 2-instance
x 2-way-TP cluster drain through the full control plane
(``ServingCluster.on_mesh_slices``).

Measured / asserted per degree:

* **dispatches per iteration == 1** — sharding must not re-split the
  fused step (the shard_map lowering lives INSIDE the one jitted call),
* **0 pool-copy bytes per shard per iteration** — donation survives
  sharding, witnessed per shard by ``unsafe_buffer_pointer`` stability
  (every shard's buffer address is sampled after every iteration),
* **token bit-identity vs the tp=1 oracle** — the model runs fp32 here,
  where the sharded step's fp32-accumulated psums make the summation
  order the only difference vs the unsharded einsum and the drained
  token streams match bit-for-bit.  (In bf16 the same reassociation can
  flip rare argmax near-ties; the fp32 differential is the exactness
  oracle, see README "Sharded serving".)  The mesh-placed tp=1 runner is
  additionally pinned bit-identical to the meshless engine: at tp=1 the
  mesh is placement-only, no shard_map in the lowering.
* **wall-clock per generated token** at each degree (compile-warm).

Emits BENCH JSON (``--json``); gated by ``check_regression.py``
(``shard_scale``).  Run:
``XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
PYTHONPATH=src python -m benchmarks.shard_scale [--smoke]``
"""
from __future__ import annotations

import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Row, row, write_bench_json

CHUNK = 8           # per-iteration prefill token budget
TP_DEGREES = (1, 2, 4)


def _model_and_params():
    import jax
    from repro.configs import get_config
    from repro.models import build_model

    # reduced() keeps num_heads=4 / num_kv_heads=2 — widen so 4-way TP
    # divides; fp32 so the tp>1-vs-tp=1 differential is exact (see
    # module docstring)
    cfg = get_config("qwen3-1.7b").reduced()
    cfg = dataclasses.replace(cfg, num_heads=8, num_kv_heads=4,
                              head_dim=64, dtype="float32")
    model = build_model(cfg)
    return model, model.init_params(jax.random.PRNGKey(0))


def _workload(cfg: Dict) -> List:
    """Shared-prefix agent requests: exercises the prefix cache, chunked
    prefill and (at the small pool size) preemption pressure."""
    from repro.serving import Request
    rng = np.random.default_rng(cfg["seed"])
    prefix = rng.integers(0, 500, cfg["prefix_len"]).astype(np.int32)
    reqs = []
    for i in range(cfg["n_reqs"]):
        toks = np.concatenate(
            [prefix, rng.integers(0, 500, 5 + (i % 7)).astype(np.int32)])
        reqs.append(Request(
            agent_name=f"a{i % 3}", msg_id=f"m{i}", prompt_len=len(toks),
            prompt_tokens=toks, max_new_tokens=cfg["max_new"],
            arrival_time=float(i)))
    return reqs


def _addrs(runner):
    a = runner.pool_address()
    return a if isinstance(a, tuple) else (a,)


def _drive(runner, cfg: Dict) -> Dict:
    """One fused drain; counts dispatches and per-shard pool address
    changes (donation witness: every shard's device buffer must stay
    resident at one address for the whole drain)."""
    from repro.serving import LLMEngine, reset_request_ids
    reset_request_ids()
    eng = LLMEngine(runner, max_batch=cfg["max_batch"],
                    enable_prefix_cache=True, prefill_chunk_tokens=CHUNK,
                    fused_iteration=True)
    pending = _workload(cfg)
    d0 = runner.n_dispatches
    prev = _addrs(runner)
    shard_changes = [0] * len(prev)
    t0 = time.perf_counter()
    done, iters = [], 0
    for _ in range(100_000):
        if pending:
            eng.submit(pending.pop(0))
        before = runner.n_dispatches
        done.extend(eng.step())
        if runner.n_dispatches > before:
            iters += 1
            cur = _addrs(runner)
            for s, (a, b) in enumerate(zip(prev, cur)):
                if a != b:
                    shard_changes[s] += 1
            prev = cur
        elif not pending:
            break
    wall = time.perf_counter() - t0
    tokens = sum(r.output_len for r in done)
    return {"wall_s": wall, "tokens": tokens, "iters": max(iters, 1),
            "dispatches": runner.n_dispatches - d0,
            "n_shards": len(prev),
            "shard_addr_changes": max(shard_changes),
            "shard_nbytes": runner.pool.nbytes // max(runner.tp, 1),
            "outputs": sorted((r.msg_id, tuple(int(t) for t in r.output_tokens))
                              for r in done)}


def _cluster_drain(model, params, cfg: Dict) -> Dict:
    """2 instances x 2-way TP on 4 host devices under the full Kairos
    control plane (balancer / time-slot dispatcher / orchestrator)."""
    import jax
    from repro.core.orchestrator import HardwareProfile, Orchestrator
    from repro.serving import Request, ServingCluster, reset_request_ids

    orch = Orchestrator(hardware=HardwareProfile(
        decode_tok_per_s=20.0,
        kv_capacity_tokens=cfg["num_blocks"] * cfg["block_size"]))
    cluster = ServingCluster.on_mesh_slices(
        model, params, orch, n_instances=2, model_parallel=2,
        devices=jax.devices()[:4],
        runner_kwargs=dict(num_blocks=cfg["num_blocks"],
                           block_size=cfg["block_size"],
                           max_batch=cfg["max_batch"]),
        engine_kwargs=dict(max_batch=cfg["max_batch"],
                           enable_prefix_cache=True,
                           prefill_chunk_tokens=CHUNK))
    reset_request_ids()
    rng = np.random.default_rng(cfg["seed"])
    prefix = rng.integers(0, 500, cfg["prefix_len"]).astype(np.int32)
    pending = []
    for i in range(2 * cfg["n_reqs"]):
        toks = np.concatenate(
            [prefix, rng.integers(0, 500, 5 + (i % 7)).astype(np.int32)])
        pending.append(Request(
            agent_name=f"a{i % 3}", msg_id=f"c{i}", prompt_len=len(toks),
            prompt_tokens=toks, max_new_tokens=cfg["max_new"],
            arrival_time=float(i)))
    n_submitted = len(pending)
    done = []
    for _ in range(100_000):
        if pending:
            cluster.submit(pending.pop(0))
        done.extend(cluster.step())
        if not pending and not cluster.has_work:
            break
    cluster.close()
    served = {r.instance_id for r in done}
    return {"finished": len(done), "submitted": n_submitted,
            "instances_used": len(served)}


def measure(smoke: bool = True) -> Dict:
    import jax
    from repro.launch.mesh import make_local_mesh
    from repro.serving import PagedModelRunner

    if jax.device_count() < 4:
        raise RuntimeError(
            f"shard_scale needs >= 4 devices (have {jax.device_count()}); "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=8 before "
            "jax initializes")

    cfg = dict(seed=7, n_reqs=6, prefix_len=16, max_new=6,
               max_batch=4, num_blocks=24, block_size=8)
    if not smoke:
        cfg.update(n_reqs=12, prefix_len=32, max_new=10, num_blocks=48)

    model, params = _model_and_params()
    out: Dict = {"config": {**cfg, "chunk": CHUNK, "smoke": smoke,
                            "model": "qwen3-1.7b/reduced-8h4kv-fp32",
                            "devices": jax.device_count()}}

    runners = {}
    for tp in TP_DEGREES:
        mesh = make_local_mesh(tp, devices=jax.devices()[:tp])
        runners[tp] = PagedModelRunner(
            model, params, num_blocks=cfg["num_blocks"],
            block_size=cfg["block_size"], max_batch=cfg["max_batch"],
            mesh=mesh)
    oracle = PagedModelRunner(model, params, num_blocks=cfg["num_blocks"],
                              block_size=cfg["block_size"],
                              max_batch=cfg["max_batch"])

    base = _drive(oracle, cfg)          # meshless single-device oracle
    for r in runners.values():
        _drive(r, cfg)                  # warmup: compile
    repeats = 3 if smoke else 6
    runs = {tp: [] for tp in TP_DEGREES}
    for _ in range(repeats):
        for tp in TP_DEGREES:
            runs[tp].append(_drive(runners[tp], cfg))
    res = {}
    for tp in TP_DEGREES:
        r = min(runs[tp], key=lambda x: x["wall_s"])
        res[tp] = r
        out[f"wall_per_token_tp{tp}_ms"] = 1e3 * r["wall_s"] / r["tokens"]
        out[f"dispatches_per_iteration_tp{tp}"] = r["dispatches"] / r["iters"]
        # per-shard donation witness: bytes copied == address moves x
        # per-shard buffer size (0 when the donated alias holds)
        worst = max(x["shard_addr_changes"] for x in runs[tp])
        out[f"pool_bytes_copied_per_iter_tp{tp}"] = \
            worst * r["shard_nbytes"] / r["iters"]
        assert r["n_shards"] == tp, \
            f"tp={tp}: pool must expose one buffer per shard"
    assert res[1]["outputs"] == base["outputs"], \
        "mesh-placed tp=1 must be bit-identical to the meshless engine"
    out["tokens_mismatch_tp1"] = 0.0
    for tp in (2, 4):
        mism = sum(1 for a, b in zip(res[tp]["outputs"], base["outputs"])
                   if a != b)
        assert mism == 0, \
            f"tp={tp} token streams diverged from the tp=1 oracle " \
            f"({mism}/{len(base['outputs'])} requests)"
        out[f"tokens_mismatch_tp{tp}"] = float(mism)
    out["tp_speedup_2"] = (out["wall_per_token_tp1_ms"]
                           / out["wall_per_token_tp2_ms"])

    cl = _cluster_drain(model, params, cfg)
    assert cl["finished"] == cl["submitted"], \
        f"cluster drain lost requests ({cl['finished']}/{cl['submitted']})"
    out["cluster_unfinished"] = float(cl["submitted"] - cl["finished"])
    out["cluster_unused_instances"] = float(2 - cl["instances_used"])
    return out


def run(quick: bool = True) -> List[Row]:
    import jax
    if jax.device_count() < 4:
        # the generic figure driver runs without the forced-device flag;
        # the dedicated multi-device CI job owns this benchmark
        return [("shard_scale.skipped", float("nan"),
                 f"needs >= 4 devices, have {jax.device_count()}")]
    m = measure(smoke=quick)
    return [
        row(f"shard_scale.tp{tp}", m[f"wall_per_token_tp{tp}_ms"] * 1e-3,
            f"{m[f'dispatches_per_iteration_tp{tp}']:.2f} dispatches/iter, "
            f"{m[f'pool_bytes_copied_per_iter_tp{tp}']:.0f} pool B/iter")
        for tp in TP_DEGREES
    ] + [
        row("shard_scale.headline", m["wall_per_token_tp2_ms"] * 1e-3,
            f"tokens bit-identical tp2/tp4 vs tp1; cluster 2x2 drained"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI smoke job")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH JSON (schema: benchmarks/common.py)")
    args = ap.parse_args()

    m = measure(smoke=args.smoke)
    config = m.pop("config")
    print("name,value")
    for k, v in sorted(m.items()):
        print(f"{k},{v:.4f}")
    if args.json:
        write_bench_json(args.json, "shard_scale", config, m)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
