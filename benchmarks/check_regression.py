"""CI perf regression gate: compare a fresh BENCH JSON against the
committed baseline.

Wall-clock metrics regress when they exceed baseline * (1 + tolerance),
but only when both runs share a hardware class (the ``host`` tag):
across different hosts the wall comparison is advisory, and the
hardware-independent gates carry the job — exact metrics
(``dispatches_per_iteration_fused``, recompile counts) must not grow at
all, and ratio metrics (``speedup``) must stay >= the floor.  Metrics
missing from either side are reported but only fail with ``--strict`` —
the benchmark set is allowed to grow PR over PR.

Usage:
    python -m benchmarks.check_regression BENCH_ci.json BENCH_baseline.json \
        [--tolerance 0.20] [--strict]
"""
from __future__ import annotations

import argparse
import json
import sys

# one-sided wall-clock gate: larger is a regression (same host only)
WALL_METRICS = ("wall_per_token_fused_ms",)
# algorithmic invariant, environment-independent: must never grow
EXACT_METRICS = ("dispatches_per_iteration_fused",)
# shape-driven but sensitive to jax wheel internals (_cache_size
# semantics): hard only on the same host class, advisory otherwise
HOST_EXACT_METRICS = ("recompiles_fused",)
# hardware-independent ratio: fused must stay faster than per-chunk.
# Floor 0.9, not 1.0: the ratio is wall-clock-derived, and one noisy
# min-of-N drain on a loaded shared runner can dip a true ~1.3x to ~1.0;
# a real fusion regression lands well below 0.9
RATIO_FLOORS = {"speedup": 0.9}


def check(ci: dict, base: dict, tolerance: float, strict: bool) -> int:
    cm, bm = ci.get("metrics", {}), base.get("metrics", {})
    failures, notes = [], []
    # wall-clock is only comparable on the same hardware class: a baseline
    # pinned on a dev box must not fail CI runners (and vice versa) — the
    # comparison downgrades to advisory until the baseline is refreshed
    # from a run on the same host class (see README)
    same_host = ci.get("host") is not None and ci.get("host") == base.get("host")
    if not same_host:
        notes.append(f"host mismatch ({ci.get('host')!r} vs "
                     f"{base.get('host')!r}): wall-clock gates advisory")
    for name in WALL_METRICS:
        if name not in cm or name not in bm:
            notes.append(f"missing wall metric {name!r}")
            continue
        limit = bm[name] * (1.0 + tolerance)
        regressed = cm[name] > limit
        status = "FAIL" if regressed and same_host else \
            ("advisory-fail" if regressed else "ok")
        print(f"{status}: {name} = {cm[name]:.4f} vs baseline {bm[name]:.4f} "
              f"(limit {limit:.4f}, +{tolerance:.0%})")
        if regressed and same_host:
            failures.append(name)
    for name in EXACT_METRICS + HOST_EXACT_METRICS:
        if name not in cm or name not in bm:
            notes.append(f"missing exact metric {name!r}")
            continue
        grew = cm[name] > bm[name]
        hard = name in EXACT_METRICS or same_host
        status = "FAIL" if grew and hard else \
            ("advisory-fail" if grew else "ok")
        print(f"{status}: {name} = {cm[name]:g} vs baseline {bm[name]:g} "
              f"(must not grow)")
        if grew and hard:
            failures.append(name)
    for name, floor in RATIO_FLOORS.items():
        if name not in cm:
            notes.append(f"missing ratio metric {name!r}")
            continue
        status = "FAIL" if cm[name] < floor else "ok"
        print(f"{status}: {name} = {cm[name]:.3f} (floor {floor:g})")
        if cm[name] < floor:
            failures.append(name)
    for n in notes:
        print(f"note: {n}")
    if notes and strict:
        failures.extend(notes)
    if failures:
        print(f"REGRESSION: {len(failures)} gate(s) failed: {failures}")
        return 1
    print(f"perf gates passed (commit {ci.get('commit', '?')[:12]} vs "
          f"baseline {base.get('commit', '?')[:12]})")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("ci_json")
    ap.add_argument("baseline_json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed wall-clock growth (default 20%%)")
    ap.add_argument("--strict", action="store_true",
                    help="missing metrics fail the gate")
    args = ap.parse_args()
    with open(args.ci_json) as f:
        ci = json.load(f)
    with open(args.baseline_json) as f:
        base = json.load(f)
    sys.exit(check(ci, base, args.tolerance, args.strict))


if __name__ == "__main__":
    main()
