"""CI perf regression gate: compare a fresh BENCH JSON against the
committed baseline.

Wall-clock metrics regress when they exceed baseline * (1 + tolerance),
but only when both runs share a hardware class (the ``host`` tag):
across different hosts the wall comparison is advisory, and the
hardware-independent gates carry the job — exact metrics
(``dispatches_per_iteration_fused``, recompile counts) must not grow at
all, and ratio metrics (``speedup``) must stay >= the floor.  Metrics
missing from either side are reported but only fail with ``--strict`` —
the benchmark set is allowed to grow PR over PR.

Gates are keyed by the BENCH ``bench`` tag (``GATES``), so one driver
serves every benchmark the CI perf pipeline tracks; a BENCH JSON whose
tag has no gate entry passes with a note.

Usage:
    python -m benchmarks.check_regression BENCH_ci.json BENCH_baseline.json \
        [--tolerance 0.20] [--strict]
"""
from __future__ import annotations

import argparse
import json
import sys

# Per-bench gate sets:
#   wall       — one-sided wall-clock gate: larger is a regression
#                (hard only when both runs share a host class)
#   exact      — algorithmic invariant, environment-independent: must
#                never grow
#   host_exact — shape-driven but sensitive to jax wheel internals
#                (_cache_size semantics): hard only on the same host
#                class, advisory otherwise
#   ratio_floors — hardware-independent ratios with floors.  Floors sit
#                below the measured steady state (e.g. 0.9 for a true
#                ~1.25x speedup): the ratios are wall-clock-derived and
#                one noisy min-of-N drain on a loaded shared runner can
#                dip them; a real regression lands well below the floor.
#   ceilings   — hardware-independent metrics with absolute ceilings
#                (e.g. tracing overhead in percent must stay <= 5)
#   baseline_floors — metrics that must stay >= the committed baseline's
#                value (e.g. goodput under SLO from the deterministic
#                seeded sim must not drop as the code evolves)
GATES = {
    "iteration_fusion": {
        "wall": ("wall_per_token_fused_ms",),
        # pool_bytes_copied_per_iter_fused: the donated in-place pool
        # must never regress to copying (baseline pins it at 0, and
        # "must not grow" from 0 means stays 0)
        "exact": ("dispatches_per_iteration_fused",
                  "pool_bytes_copied_per_iter_fused",
                  "peak_live_pool_buffers_fused"),
        "host_exact": ("recompiles_fused",),
        "ratio_floors": {"speedup": 0.9},
    },
    "cluster_overlap": {
        "wall": ("wall_per_token_pipelined_ms_4",),
        "exact": (),
        "host_exact": (),
        # pipelined must stay ahead of the serial loop at 4 instances
        # (measured ~1.2x on a 2-cpu host; more on wider CI runners)
        "ratio_floors": {"overlap_speedup_4": 1.0},
    },
    "shard_scale": {
        "wall": ("wall_per_token_tp2_ms",),
        # sharding invariants, all pinned at 0/1 by the baseline and
        # "must not grow":
        #   dispatches/iter == 1 at every degree (the shard_map lowering
        #   lives inside the one jitted call),
        #   0 pool-copy bytes per shard (donation survives sharding,
        #   address-witnessed per shard),
        #   0 token mismatches vs the tp=1 oracle (fp32 differential),
        #   cluster 2x2 drain loses nothing and uses both instances
        "exact": ("dispatches_per_iteration_tp1",
                  "dispatches_per_iteration_tp2",
                  "dispatches_per_iteration_tp4",
                  "pool_bytes_copied_per_iter_tp1",
                  "pool_bytes_copied_per_iter_tp2",
                  "pool_bytes_copied_per_iter_tp4",
                  "tokens_mismatch_tp1",
                  "tokens_mismatch_tp2",
                  "tokens_mismatch_tp4",
                  "cluster_unfinished",
                  "cluster_unused_instances"),
        "host_exact": (),
        # 2-way TP on forced host "devices" shares one CPU's cores — no
        # wall win is expected there; the floor only catches a sharded
        # lowering that collapses (real interconnects measure the gain)
        "ratio_floors": {"tp_speedup_2": 0.25},
    },
    "latency_breakdown": {
        "wall": ("wall_per_token_traced_ms",),
        "exact": (),
        "host_exact": (),
        "ratio_floors": {},
        # the tracer's enabled cost on the real engine path: ring-buffer
        # appends must stay in the noise (measured ~1% on a 2-cpu host;
        # the ceiling leaves room for runner jitter, a real hot-path
        # mistake lands at 10s of percent)
        "ceilings": {"tracing_overhead_pct": 5.0},
        # the seeded sim is deterministic: goodput under SLO moves only
        # when scheduling/dispatch behaviour changes — a drop is a real
        # policy regression, not noise
        "baseline_floors": ("goodput_slo",),
    },
    "autoscale_burst": {
        "wall": (),
        # live migration is lossless BY CONSTRUCTION: every forcibly
        # ping-pong-migrated request's token stream must equal the
        # unmigrated drain bit for bit, and every request must finish —
        # both pinned at 0 by the baseline, "must not grow" means stay 0
        "exact": ("migration_tokens_mismatch", "migration_unfinished"),
        "host_exact": (),
        # on the committed bursty trace, elastic capacity must stay at
        # least as good as trough-sized fixed capacity on p99 workflow
        # token latency (measured ~4x better; 1.0 only trips if
        # elasticity stops helping at all)
        "ratio_floors": {"elastic_vs_fixed_p99_ratio": 1.0},
        # deterministic seeded sim: elastic goodput under SLO must not
        # drop below the committed baseline as the autoscaler evolves
        "baseline_floors": ("goodput_slo_elastic",),
    },
    "chaos_drain": {
        "wall": (),
        # crash recovery is lossless BY CONSTRUCTION, all pinned at 0 by
        # the baseline ("must not grow" from 0 means stays 0):
        #   no request on a crashed instance may be lost, every recovered
        #   stream must equal the fault-free drain bit for bit, nothing
        #   may exhaust its retry budget on the committed plan, and the
        #   faulted sim twin loses nothing either
        "exact": ("lost_requests", "recovered_token_mismatch",
                  "chaos_failed_requests", "sim_faulted_lost",
                  "sim_faulted_workflows_delta"),
        "host_exact": (),
        # the acceptance oracle (ISSUE): under sustained overload,
        # shedding must keep goodput-under-SLO STRICTLY above the
        # no-shedding collapse (measured ~1.7x; 1.0 trips only if the
        # valve stops paying for itself)
        "ratio_floors": {"shed_vs_noshed_goodput_ratio": 1.0},
        # replay tax: re-prefilled tokens per baseline output token on
        # the committed plan (measured ~0.25 — recovery re-derives far
        # less than one drain's worth of work; 1.0 means recovery costs
        # as much as re-running everything)
        "ceilings": {"recovery_replay_overhead": 1.0},
        # deterministic seeded sim: shedding goodput must not drop below
        # the committed baseline as the valve evolves
        "baseline_floors": ("goodput_slo_shed",),
    },
    "disagg": {
        "wall": (),
        # prefill/decode disaggregation is lossless AND cheap BY
        # CONSTRUCTION, all pinned at 0 by the baseline ("must not grow"
        # from 0 means stays 0):
        #   every handed-off request's token stream equals the flat
        #   single-engine drain bit for bit, and every request finishes;
        #   each handoff sweep spends at most one gathered donated
        #   write_blocks dispatch on the decode target;
        #   neither engine's pool buffer ever moves (donation witness)
        "exact": ("handoff_tokens_mismatch", "handoff_unfinished",
                  "handoff_dispatch_excess", "handoff_pool_moves"),
        "host_exact": (),
        # on the seeded long-prompt + decode-heavy mix, disaggregation
        # must keep beating colocation on p99 TPOT at equal capacity
        # (measured ~1.4x; 1.0 only trips if the decode-tail win
        # disappears entirely)
        "ratio_floors": {"disagg_vs_colocated_p99_tpot_ratio": 1.0},
    },
}
EMPTY_GATE = {"wall": (), "exact": (), "host_exact": (), "ratio_floors": {}}


def check(ci: dict, base: dict, tolerance: float, strict: bool) -> int:
    cm, bm = ci.get("metrics", {}), base.get("metrics", {})
    gate = GATES.get(ci.get("bench"))
    if gate is None:
        print(f"note: no gate set for bench {ci.get('bench')!r}")
        gate = EMPTY_GATE
    wall_metrics = gate.get("wall", ())
    exact_metrics = gate.get("exact", ())
    host_exact_metrics = gate.get("host_exact", ())
    ratio_floors = gate.get("ratio_floors", {})
    ceilings = gate.get("ceilings", {})
    baseline_floors = gate.get("baseline_floors", ())
    failures, notes = [], []
    # wall-clock is only comparable on the same hardware class: a baseline
    # pinned on a dev box must not fail CI runners (and vice versa) — the
    # comparison downgrades to advisory until the baseline is refreshed
    # from a run on the same host class (see README)
    same_host = ci.get("host") is not None and ci.get("host") == base.get("host")
    if not same_host:
        notes.append(f"host mismatch ({ci.get('host')!r} vs "
                     f"{base.get('host')!r}): wall-clock gates advisory")
    for name in wall_metrics:
        if name not in cm or name not in bm:
            notes.append(f"missing wall metric {name!r}")
            continue
        limit = bm[name] * (1.0 + tolerance)
        regressed = cm[name] > limit
        status = "FAIL" if regressed and same_host else \
            ("advisory-fail" if regressed else "ok")
        print(f"{status}: {name} = {cm[name]:.4f} vs baseline {bm[name]:.4f} "
              f"(limit {limit:.4f}, +{tolerance:.0%})")
        if regressed and same_host:
            failures.append(name)
    for name in exact_metrics + host_exact_metrics:
        if name not in cm or name not in bm:
            notes.append(f"missing exact metric {name!r}")
            continue
        grew = cm[name] > bm[name]
        hard = name in exact_metrics or same_host
        status = "FAIL" if grew and hard else \
            ("advisory-fail" if grew else "ok")
        print(f"{status}: {name} = {cm[name]:g} vs baseline {bm[name]:g} "
              f"(must not grow)")
        if grew and hard:
            failures.append(name)
    for name, floor in ratio_floors.items():
        if name not in cm:
            notes.append(f"missing ratio metric {name!r}")
            continue
        status = "FAIL" if cm[name] < floor else "ok"
        print(f"{status}: {name} = {cm[name]:.3f} (floor {floor:g})")
        if cm[name] < floor:
            failures.append(name)
    for name, ceiling in ceilings.items():
        if name not in cm:
            notes.append(f"missing ceiling metric {name!r}")
            continue
        status = "FAIL" if cm[name] > ceiling else "ok"
        print(f"{status}: {name} = {cm[name]:.3f} (ceiling {ceiling:g})")
        if cm[name] > ceiling:
            failures.append(name)
    for name in baseline_floors:
        if name not in cm or name not in bm:
            notes.append(f"missing baseline-floor metric {name!r}")
            continue
        dropped = cm[name] < bm[name]
        status = "FAIL" if dropped else "ok"
        print(f"{status}: {name} = {cm[name]:.4f} vs baseline {bm[name]:.4f} "
              f"(must not drop)")
        if dropped:
            failures.append(name)
    for n in notes:
        print(f"note: {n}")
    if notes and strict:
        failures.extend(notes)
    if failures:
        print(f"REGRESSION: {len(failures)} gate(s) failed: {failures}")
        return 1
    print(f"perf gates passed (commit {ci.get('commit', '?')[:12]} vs "
          f"baseline {base.get('commit', '?')[:12]})")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("ci_json")
    ap.add_argument("baseline_json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed wall-clock growth (default 20%%)")
    ap.add_argument("--strict", action="store_true",
                    help="missing metrics fail the gate")
    args = ap.parse_args()
    with open(args.ci_json) as f:
        ci = json.load(f)
    with open(args.baseline_json) as f:
        base = json.load(f)
    sys.exit(check(ci, base, args.tolerance, args.strict))


if __name__ == "__main__":
    main()
