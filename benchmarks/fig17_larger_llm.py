"""Fig. 17 / §7.5: scalability to a 13B-class model (co-located workload).

Paper: Kairos vs Parrot -42.1..-57.4% avg; vs Ayo -21.8..-24.6% avg."""
from __future__ import annotations

from benchmarks.common import Row, pct_gain, row, sim
from repro.sim import LLAMA2_13B, colocated_apps


def run(quick: bool = True):
    apps = colocated_apps()
    rate = 1.7   # 13B-class is ~1.7x slower per token
    s = {p: sim(apps, p, rate=rate, cost=LLAMA2_13B).summary()
         for p in ("parrot", "ayo", "kairos")}
    rows: list[Row] = []
    for metric in ("avg", "p90", "p99"):
        k = s["kairos"][metric]
        rows.append(row(
            f"fig17.13b.{metric}", k,
            f"kairos={k*1e3:.1f}ms vs parrot {pct_gain(s['parrot'][metric], k):+.1f}% "
            f"vs ayo {pct_gain(s['ayo'][metric], k):+.1f}% "
            f"(paper avg: -42..-57%/-22..-25%)"))
    return rows
