"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` runs every dataset
group / rate point (longer); default is the quick representative subset.
``--json PATH`` additionally writes the collected rows in the BENCH JSON
schema every figure benchmark shares with the CI perf pipeline (see
``benchmarks/common.py``).  Roofline (deliverable g) reads the dry-run
artifact: run
``python -m repro.launch.dryrun --all --out experiments/dryrun.json`` first,
then ``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, help="substring filter on module name")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as BENCH JSON")
    args = ap.parse_args()

    from benchmarks import (
        autoscale_burst,
        chaos_drain,
        chunked_prefill,
        cluster_overlap,
        disagg,
        fig03_agent_profiles,
        fig07_queuing_example,
        fig08_rank_correlation,
        fig09_dispatch_preemption,
        fig14_single_app,
        fig15_colocated,
        fig16_sorting_accuracy,
        fig17_larger_llm,
        fig18_ablation,
        iteration_fusion,
        kernel_bench,
        latency_breakdown,
        overhead,
        prefix_reuse,
        shard_scale,
    )

    modules = [fig03_agent_profiles, fig07_queuing_example, fig08_rank_correlation,
               fig09_dispatch_preemption, fig14_single_app, fig15_colocated,
               fig16_sorting_accuracy, fig17_larger_llm, fig18_ablation,
               overhead, kernel_bench, prefix_reuse, chunked_prefill,
               iteration_fusion, cluster_overlap, latency_breakdown,
               shard_scale, autoscale_burst, disagg, chaos_drain]

    print("name,us_per_call,derived")
    failures = 0
    metrics = {}
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows = mod.run(quick=not args.full)
            for n, us, derived in rows:
                print(f"{n},{us:.2f},{derived}", flush=True)
                metrics[n] = {"us_per_call": us, "derived": derived}
        except Exception as e:  # keep the suite going
            failures += 1
            print(f"{name},nan,ERROR: {type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if args.json:
        from benchmarks.common import write_bench_json
        write_bench_json(args.json, "figures",
                         {"full": args.full, "only": args.only}, metrics)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
