"""Single-dispatch fused iteration execution vs the per-chunk path.

Runs the real paged JAX engine (CPU ref backend, reduced config) on a
long-prompt + decode-heavy mix — the §2.2 regime chunked prefill targets
— twice: ``fused_iteration=True`` (one ragged dispatch per iteration)
and the legacy per-chunk path (one jitted call per prefill chunk plus a
decode dispatch, with a blocking argmax round-trip per completed chunk).

Measured per engine configuration:

* **dispatches per iteration** (fused: exactly 1; legacy: K+1 + syncs),
* **jit recompile count** across the whole run (the fused path pads to
  bucketed static shapes; the legacy path specializes per chunk/context
  shape pair and per decode-table width),
* **wall-clock per generated token**, compile-warm (a full warmup pass
  precedes the timed pass),
* **pool bytes copied per iteration** and **peak live pool buffers** —
  witnessed by the KV pool's device buffer address: the donated
  in-place path must copy 0 bytes (one resident pool buffer), while a
  ``donate_pool=False`` differential drive shows the whole-pool copy
  every dispatch used to pay.  A ``ragged_backend="flat_ref"``
  differential drive pins token identity of the native segment-bounded
  ragged attention vs the legacy flatten-and-repeat lowering.

A tiny fig14-style sim (QA app, kairos policy, fused pricing) rides
along so the CI perf trajectory also tracks an end-to-end metric.

Emits the machine-readable BENCH JSON the CI perf pipeline consumes
(``--json PATH``); ``--smoke`` shrinks the workload for the CI smoke job.

Run: ``PYTHONPATH=src python -m benchmarks.iteration_fusion [--smoke]``
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Row, row, write_bench_json

CHUNK = 32          # per-iteration prefill token budget


def _workload(cfg: Dict) -> List:
    """Deterministic long-prompt + decode-heavy request mix."""
    from repro.serving import Request
    rng = np.random.default_rng(cfg["seed"])
    reqs = []
    for i in range(cfg["n_short"]):
        plen = int(rng.integers(16, 40))
        reqs.append(Request(
            agent_name="qa", msg_id=f"s{i}", prompt_len=plen,
            prompt_tokens=rng.integers(0, 500, plen).astype(np.int32),
            max_new_tokens=cfg["short_out"], arrival_time=float(i)))
    for i in range(cfg["n_long"]):
        plen = cfg["long_prompt"]
        reqs.append(Request(
            agent_name="ingest", msg_id=f"l{i}", prompt_len=plen,
            prompt_tokens=rng.integers(0, 500, plen).astype(np.int32),
            max_new_tokens=cfg["long_out"], arrival_time=0.5 + i))
    return reqs


def _drive(runner, cfg: Dict, fused: bool) -> Dict:
    """One full drain of the workload; returns raw counters.

    ``pool_addr_changes`` counts iterations after which the KV pool's
    device buffer address moved — with donation every dispatch updates
    the pool in place (0 changes, 1 live pool buffer); without it each
    dispatch materializes a second full-size pool buffer, witnessed as
    one address change of ``runner.pool.nbytes`` bytes.  Per-step
    sampling is *exact* for every configuration this benchmark emits:
    the donated drives copy nothing (any copy would move the address at
    least once per drain), and the non-donated drive runs fused — one
    pool-threading dispatch per step, whose output buffer is allocated
    while the input is still live, so its address always differs.  (A
    multi-pool-dispatch non-donated step — the legacy path with
    donation off — could alias back across an even number of copies and
    undercount; no emitted metric measures that configuration.)
    ``step()`` force-syncs, so reading the address here never blocks an
    in-flight dispatch.  On a runtime without an address probe
    (``pool_address() is None``) the count is None — the metrics are
    then *omitted*, never fabricated as a gate-passing 0."""
    from repro.serving import LLMEngine, reset_request_ids
    reset_request_ids()
    eng = LLMEngine(runner, max_batch=cfg["max_batch"],
                    prefill_chunk_tokens=CHUNK, fused_iteration=fused)
    pending = _workload(cfg)
    d0 = runner.n_dispatches
    prev_addr = runner.pool_address()
    addr_changes = 0 if prev_addr is not None else None
    t0 = time.perf_counter()
    done, iters = [], 0
    for _ in range(100_000):
        # trickle arrivals so iterations genuinely mix chunks and decodes
        if pending:
            eng.submit(pending.pop(0))
        before = runner.n_dispatches
        done.extend(eng.step())
        if runner.n_dispatches > before:
            iters += 1                    # an iteration actually executed
            if addr_changes is not None:
                addr = runner.pool_address()
                if addr != prev_addr:
                    addr_changes += 1
                prev_addr = addr
        elif not pending:
            break                         # idle and nothing left to arrive
    wall = time.perf_counter() - t0
    tokens = sum(r.output_len for r in done)
    return {"wall_s": wall, "tokens": tokens, "iters": max(iters, 1),
            "dispatches": runner.n_dispatches - d0,
            "pool_addr_changes": addr_changes,
            "pool_nbytes": runner.pool.nbytes,
            "outputs": sorted((r.msg_id, tuple(r.output_tokens)) for r in done)}


def _pool_copy_metrics(r: Dict, key: str) -> Dict:
    """Pool-traffic metrics witnessed by device buffer address changes
    (see ``_drive``): bytes copied per iteration (0 when donation holds)
    and peak simultaneously-live pool buffers (1 in place vs 2 copying).
    Empty when the runtime exposed no address probe — a missing metric
    surfaces in check_regression as a note (a failure under --strict)
    instead of a fabricated gate-passing zero."""
    if r["pool_addr_changes"] is None:
        return {}
    return {
        f"pool_bytes_copied_per_iter_{key}":
            r["pool_addr_changes"] * r["pool_nbytes"] / r["iters"],
        f"peak_live_pool_buffers_{key}":
            1.0 + (1.0 if r["pool_addr_changes"] else 0.0),
    }


def measure(smoke: bool = True) -> Dict:
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import PagedModelRunner

    cfg = dict(seed=0, n_short=4, n_long=2, short_out=10, long_out=3,
               long_prompt=96, max_batch=4, num_blocks=96, block_size=8)
    if not smoke:
        cfg.update(n_short=10, n_long=4, short_out=24, long_out=6,
                   long_prompt=192, max_batch=8, num_blocks=192)

    mcfg = get_config("qwen3-1.7b").reduced()
    model = build_model(mcfg)
    params = model.init_params(jax.random.PRNGKey(0))
    runner = PagedModelRunner(model, params, num_blocks=cfg["num_blocks"],
                              block_size=cfg["block_size"],
                              max_batch=cfg["max_batch"])

    out: Dict = {"config": {**cfg, "chunk": CHUNK, "smoke": smoke,
                            "model": "qwen3-1.7b/reduced"}}
    repeats = 6 if smoke else 8
    _drive(runner, cfg, True)                      # warmup: compile
    recompiles_fused = runner.jit_cache_size()
    _drive(runner, cfg, False)
    recompiles_legacy = runner.jit_cache_size() - recompiles_fused
    compiles_before = runner.jit_cache_size()
    # interleave timed drains and keep the min per path: robust to CPU
    # scheduling noise and slow drift
    runs = {True: [], False: []}
    for _ in range(repeats):
        for fused in (True, False):
            runs[fused].append(_drive(runner, cfg, fused))
    assert runner.jit_cache_size() == compiles_before, \
        "timed passes must be compile-warm"
    res = {}
    for fused, key in ((True, "fused"), (False, "legacy")):
        r = min(runs[fused], key=lambda x: x["wall_s"])
        res[key] = r
        out[f"wall_per_token_{key}_ms"] = 1e3 * r["wall_s"] / r["tokens"]
        out[f"dispatches_per_iteration_{key}"] = r["dispatches"] / r["iters"]
        out.update(_pool_copy_metrics(r, key))
    out["recompiles_fused"] = recompiles_fused
    out["recompiles_legacy"] = recompiles_legacy
    assert res["fused"]["outputs"] == res["legacy"]["outputs"], \
        "fused execution must be token-identical to the per-chunk path"
    assert res["fused"]["tokens"] == res["legacy"]["tokens"] > 0
    out["speedup"] = (out["wall_per_token_legacy_ms"]
                      / out["wall_per_token_fused_ms"])

    # differential configurations (one untimed drain each): the donated
    # in-place pool and the native segment-bounded ragged attention must
    # change buffer traffic only, never the token streams
    nd_runner = PagedModelRunner(model, params, num_blocks=cfg["num_blocks"],
                                 block_size=cfg["block_size"],
                                 max_batch=cfg["max_batch"], donate_pool=False)
    nd = _drive(nd_runner, cfg, True)
    assert nd["outputs"] == res["fused"]["outputs"], \
        "disabling pool donation must not change generated tokens"
    out.update(_pool_copy_metrics(nd, "nondonated"))
    flat_runner = PagedModelRunner(model, params, num_blocks=cfg["num_blocks"],
                                   block_size=cfg["block_size"],
                                   max_batch=cfg["max_batch"],
                                   ragged_backend="flat_ref")
    flat = _drive(flat_runner, cfg, True)
    assert flat["outputs"] == res["fused"]["outputs"], \
        "flatten-and-repeat ragged lowering must be token-identical"
    return out


def tiny_fig14(smoke: bool = True) -> Dict:
    """Fig-14-style single-app sim (kairos policy, fused pricing)."""
    from repro.sim import SimConfig, Simulation, make_app
    cfg = SimConfig(apps=[make_app("QA", "G+M")], policy="kairos",
                    rate=4.0, duration=40.0 if smoke else 150.0,
                    n_instances=2, seed=1, prefill_chunk_tokens=512)
    s = Simulation(cfg).run().summary()
    return {"fig14_qa_avg_ms": 1e3 * s["avg"], "fig14_qa_p99_ms": 1e3 * s["p99"],
            "fig14_qa_n_workflows": s["n_workflows"]}


def run(quick: bool = True) -> List[Row]:
    m = measure(smoke=quick)
    rows = [
        row("iteration_fusion.fused", m["wall_per_token_fused_ms"] * 1e-3,
            f"{m['dispatches_per_iteration_fused']:.2f} dispatches/iter, "
            f"{m['recompiles_fused']} compiles"),
        row("iteration_fusion.legacy", m["wall_per_token_legacy_ms"] * 1e-3,
            f"{m['dispatches_per_iteration_legacy']:.2f} dispatches/iter, "
            f"{m['recompiles_legacy']} compiles"),
        row("iteration_fusion.headline", m["wall_per_token_fused_ms"] * 1e-3,
            f"wall/token x{m['speedup']:.2f} vs per-chunk (target > 1)"),
    ]
    # no hard assert here: the speedup>=1 expectation is enforced once, by
    # benchmarks/check_regression.py's ratio floor in CI — a timing flake
    # on a loaded machine must not fail the whole figure suite
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI smoke job")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH JSON (schema: benchmarks/common.py)")
    ap.add_argument("--no-sim", action="store_true",
                    help="skip the tiny fig14 sim metric")
    args = ap.parse_args()

    m = measure(smoke=args.smoke)
    config = m.pop("config")
    if not args.no_sim:
        m.update(tiny_fig14(smoke=args.smoke))
    print("name,value")
    for k, v in sorted(m.items()):
        print(f"{k},{v:.4f}")
    if args.json:
        write_bench_json(args.json, "iteration_fusion", config, m)
        print(f"# wrote {args.json}")
    if m["speedup"] <= 1.0:
        # reported, not asserted: the CI gate (check_regression.py) owns
        # the speedup>=1 floor so one noisy drain can't hard-fail a run
        print(f"# WARNING: fused slower than per-chunk (x{m['speedup']:.2f})")


if __name__ == "__main__":
    main()
