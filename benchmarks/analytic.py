"""Analytic per-device FLOP/byte model for the roofline (exact formulas
from the architecture configs).

Why not raw ``cost_analysis()``: XLA's HLO cost analysis counts a while-
loop body ONCE, and our layer stacks are lax.scan loops — so compiled
FLOPs under-count by ~num_layers (verified: the 'useful ratio' column of
the naive table landed at ≈ num_layers × 100%).  The compiled artifact
remains the source of truth for (a) does it lower/shard, (b) peak memory
(buffer assignment models loops correctly), (c) which collectives the
partitioner inserted (we scale those by trip count, see roofline.py).
"""
from __future__ import annotations

from typing import Dict

from repro.configs import INPUT_SHAPES, ModelConfig, ShapeConfig, get_config

BF16 = 2
F32 = 4


def _attn_flops(cfg: ModelConfig, n_q: int, n_kv: int, batch: int) -> float:
    """QK^T + PV for n_q query tokens against n_kv keys (per layer)."""
    hd = cfg.resolved_head_dim
    return 4.0 * batch * cfg.num_heads * n_q * n_kv * hd


def _proj_flops(cfg: ModelConfig, tokens: float) -> float:
    """qkvo projections per layer."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return 2.0 * tokens * d * hd * (2 * cfg.num_heads + 2 * cfg.num_kv_heads)


def _ffn_flops(cfg: ModelConfig, tokens: float, layer: int) -> float:
    if cfg.moe is not None and layer in set(cfg.moe_layer_indices()):
        m = cfg.moe
        f = 6.0 * tokens * cfg.d_model * m.d_expert * m.top_k
        if m.num_shared_experts:
            f += 6.0 * tokens * cfg.d_model * (m.d_shared or m.d_expert)
        return f
    return 6.0 * tokens * cfg.d_model * cfg.d_ff


def _mixer_flops(cfg: ModelConfig, kind: str, tokens: float, ctx: float,
                 batch: float, n_q: float) -> float:
    d = cfg.d_model
    if kind == "attn":
        win = cfg.sliding_window
        eff_kv = min(ctx, win) if win else ctx
        return _proj_flops(cfg, tokens) + _attn_flops(cfg, int(n_q), int(eff_kv), int(batch))
    if kind == "rwkv":
        hd = cfg.rwkv_head_dim
        # 5 d^2 projections + state update/query ~ 4*d*hd per token
        return 2.0 * tokens * d * d * 5 + 4.0 * tokens * d * hd
    if kind == "mamba":
        di = cfg.ssm_expand * d
        n = cfg.ssm_state_dim
        rank = max(d // 16, 1)
        return (2.0 * tokens * d * 3 * di              # in/out proj
                + 2.0 * tokens * di * (rank + 2 * n)   # x_proj
                + 2.0 * tokens * rank * di             # dt_proj
                + 6.0 * tokens * di * n)               # scan update + y
    raise ValueError(kind)


def flops_global(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Forward FLOPs for one step of `shape` (train adds backward x2)."""
    b = shape.global_batch
    if shape.kind == "decode":
        n_q, ctx = 1, shape.seq_len
    else:
        n_q = shape.seq_len // 2 if cfg.is_encdec else shape.seq_len
        ctx = n_q
    tokens = float(b * n_q)
    total = 0.0
    for i, kind in enumerate(cfg.layer_kinds):
        if shape.kind == "decode":
            total += _mixer_flops(cfg, kind, tokens, ctx, b, 1)
        else:
            # causal: average kv length = ctx/2
            total += _mixer_flops(cfg, kind, tokens, ctx / 2, b, n_q)
        total += _ffn_flops(cfg, tokens, i)
    if cfg.is_encdec:
        enc_t = float(b * (shape.seq_len // 2 if shape.kind != "decode"
                           else min(4096, shape.seq_len // 2)))
        for _ in range(cfg.num_encoder_layers):
            if shape.kind != "decode":
                total += (_proj_flops(cfg, enc_t)
                          + _attn_flops(cfg, int(enc_t / b), int(enc_t / b), b)
                          + 6.0 * enc_t * cfg.d_model * cfg.d_ff)
        # cross attention
        total += cfg.num_layers * (
            2.0 * tokens * cfg.d_model * cfg.resolved_head_dim * 2 * cfg.num_heads
            + _attn_flops(cfg, int(n_q), int(enc_t / b), int(b)))
    # lm head
    total += 2.0 * tokens * cfg.d_model * cfg.vocab_size
    if shape.kind == "train":
        total *= 3.0            # fwd + 2x bwd
    return total


def bytes_global(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """HBM traffic for one step: weights read + KV/state traffic +
    boundary activations (fusion-optimistic)."""
    b = shape.global_batch
    p_bytes = cfg.param_count() * BF16
    if shape.kind == "decode":
        kv = cfg.kv_bytes_per_token() * float(b) * shape.seq_len  # read cache
        state = cfg.state_bytes() * float(b)
        act = 64 * cfg.num_layers * b * cfg.d_model * BF16
        if cfg.sliding_window and cfg.global_attn_every:
            n_glob = cfg.num_layers // cfg.global_attn_every
            n_loc = len(cfg.attn_layer_indices) - n_glob
            per_layer = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * BF16
            kv = float(b) * per_layer * (n_glob * shape.seq_len
                                         + n_loc * min(cfg.sliding_window, shape.seq_len))
        return p_bytes + kv + state + act
    tokens = float(b) * (shape.seq_len // 2 if cfg.is_encdec else shape.seq_len)
    act = 12 * cfg.num_layers * tokens * cfg.d_model * BF16
    mult = 3.0 if shape.kind == "train" else 1.0
    return mult * (p_bytes + act)


def roofline_terms(arch: str, shape_name: str, n_devices: int,
                   peak_flops: float, hbm_bw: float) -> Dict[str, float]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    f = flops_global(cfg, shape) / n_devices
    by = bytes_global(cfg, shape) / n_devices
    return {"flops_dev": f, "bytes_dev": by,
            "t_compute": f / peak_flops, "t_memory": by / hbm_bw}
